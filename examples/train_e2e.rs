//! End-to-end driver (EXPERIMENTS.md §E2E): train a language model for a
//! few hundred optimizer steps on the synthetic corpus, with online GNS
//! tracking and a GNS-informed linear batch-size ramp, logging the loss
//! curve and GNS series to CSV.
//!
//! ```sh
//! cargo run --release --example train_e2e                 # small, 300 steps
//! cargo run --release --example train_e2e -- micro 50     # quicker smoke
//! ```

use anyhow::Result;
use nanogns::config::TrainConfig;
use nanogns::coordinator::Trainer;
use nanogns::runtime::{BackendFactory, ReferenceFactory};
use nanogns::schedule::{BatchSizeSchedule, LrSchedule};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "small".to_string());
    let steps: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let factory = ReferenceFactory;
    let entry = factory.describe(&model)?;
    let tokens_per_accum = (entry.microbatch * entry.seq_len) as u64;

    let mut cfg = TrainConfig::quickstart(&model, steps);
    cfg.lr = LrSchedule {
        max_lr: 1e-3,
        min_lr: 1e-4,
        warmup_steps: steps / 20 + 1,
        decay_steps: steps,
    };
    cfg.batch_size = BatchSizeSchedule::Linear {
        min_accum: 1,
        max_accum: 4,
        ramp_tokens: steps * 2 * tokens_per_accum,
    };
    cfg.corpus_bytes = 1 << 20;
    cfg.metrics_path = format!("results/e2e_{model}.csv");

    println!(
        "e2e: training {model} ({:.2}M params) for {steps} steps on {}",
        entry.n_params as f64 / 1e6,
        factory.platform()
    );
    let mut trainer = Trainer::new(&factory, cfg)?;
    let t0 = std::time::Instant::now();
    let mut out_records = Vec::new();
    let report_every = (steps / 20).max(1);
    for _ in 0..steps {
        let r = trainer.step()?;
        if r.step % report_every == 0 || r.step == 1 {
            println!(
                "step {:>5} | tokens {:>9} | loss {:>7.4} | batch {:>3} | gns_tot {:>7.2} | \
                 gns_ln {:>7.2} | {:>6.0} ms",
                r.step, r.tokens, r.loss, r.b_big as u64, r.gns_total, r.gns_layernorm, r.step_ms
            );
        }
        out_records.push(r);
    }
    // write CSV (the trainer would do this in run(); we looped manually)
    let mut csv = nanogns::telemetry::CsvLogger::to_file(
        format!("results/e2e_{model}.csv"),
        nanogns::telemetry::TRAIN_HEADER,
    )?;
    for r in &out_records {
        csv.row(&nanogns::coordinator::trainer::record_row(r))?;
    }
    csv.flush()?;

    let wall = t0.elapsed().as_secs_f64();
    let eval = trainer.eval(8)?;
    let first = out_records.first().unwrap().loss;
    let last = out_records.last().unwrap().loss;
    println!("---");
    println!(
        "trained {} tokens in {wall:.1}s ({:.0} tok/s)",
        trainer.tokens(),
        trainer.tokens() as f64 / wall
    );
    println!(
        "loss: {first:.4} -> {last:.4}; held-out {eval:.4} (ln 256 = {:.4} at random)",
        (256f64).ln()
    );
    println!("final GNS: total {:.2}, layernorm {:.2}",
             out_records.last().unwrap().gns_total,
             out_records.last().unwrap().gns_layernorm);
    println!("series -> results/e2e_{model}.csv");
    Ok(())
}
