//! Quickstart: train the nano model for a handful of steps on synthetic
//! text with the hermetic reference backend, printing loss + GNS per step.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use nanogns::config::TrainConfig;
use nanogns::coordinator::Trainer;
use nanogns::runtime::{BackendFactory, ReferenceFactory};

fn main() -> Result<()> {
    let factory = ReferenceFactory;
    println!("platform: {}", factory.platform());

    let cfg = TrainConfig::quickstart("nano", 20);
    let entry = factory.describe(&cfg.model)?;
    println!(
        "model {}: {:.2}M params, microbatch {} x seq {}",
        cfg.model,
        entry.n_params as f64 / 1e6,
        entry.microbatch,
        entry.seq_len
    );

    let mut trainer = Trainer::new(&factory, cfg)?;
    println!("{:>5} {:>9} {:>9} {:>9} {:>8}", "step", "loss", "gns_tot", "gns_ln", "ms");
    for _ in 0..20 {
        let r = trainer.step()?;
        println!(
            "{:>5} {:>9.4} {:>9.2} {:>9.2} {:>8.0}",
            r.step, r.loss, r.gns_total, r.gns_layernorm, r.step_ms
        );
    }
    let eval = trainer.eval(4)?;
    println!("held-out loss after 20 steps: {eval:.4}");
    Ok(())
}
