//! Batch-size schedule case study (paper Section 5.2, Fig. 9): train the
//! same model with a fixed batch and with a GNS-motivated linear ramp at a
//! matched token budget, and report the tokens saved to reach equal loss.
//!
//! ```sh
//! cargo run --release --example batch_size_schedule [model] [steps] [seeds]
//! ```

use anyhow::Result;
use nanogns::figures;
use nanogns::runtime::ReferenceFactory;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "micro".to_string());
    let steps: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(80);
    let seeds: u64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(3);

    let factory = ReferenceFactory;
    figures::training::fig9(&factory, &model, steps, seeds)?;
    figures::training::fig15(&factory, &model, steps)?;
    Ok(())
}
