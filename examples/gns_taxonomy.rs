//! GNS estimation taxonomy demo (paper Appendix A): estimate the same
//! model's GNS from identical sampled gradients with three methods —
//! *per-example* (B_small = 1, minimal variance), *sequential* /
//! gradient-accumulation (B_small = microbatch), and *DDP* (B_small =
//! per-rank batch) — and watch them agree in expectation while differing
//! in variance exactly as Fig. 2 predicts.
//!
//! ```sh
//! cargo run --release --example gns_taxonomy
//! ```

use anyhow::Result;
use nanogns::coordinator::ModelRunner;
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::gns::{gns_components, GnsAccumulator, GnsTracker};
use nanogns::runtime::{BackendFactory, Buffer, ReferenceFactory};
use nanogns::{N_TYPES, STATS_ORDER};

fn main() -> Result<()> {
    let factory = ReferenceFactory;
    let model = "micro";
    let steps = 30u64;
    let ranks = 4usize;
    let accum = 2usize;

    let entry = factory.describe(model)?;
    let mut runner = ModelRunner::new(&factory, model)?;
    runner.init(7)?;
    let text = CorpusGenerator::new(7).generate(1 << 19);
    let base = Loader::new(&text, entry.seq_len, 7);
    let mut loaders: Vec<Loader> = (0..ranks as u64).map(|r| base.for_rank(r)).collect();

    let mb = entry.microbatch;
    let alpha = 0.1;
    let mut perex = GnsTracker::new(&STATS_ORDER, alpha);
    let mut seq = GnsTracker::new(&STATS_ORDER, alpha);
    let mut ddp = GnsTracker::new(&STATS_ORDER, alpha);

    println!("taxonomy comparison on {model} ({ranks} ranks x {accum} accum x {mb} microbatch)");
    println!(
        "{:>5} {:>9} {:>12} {:>12} {:>12}",
        "step", "loss", "per-example", "sequential", "ddp"
    );
    for step in 1..=steps {
        let mut gns_acc = GnsAccumulator::new(N_TYPES, mb);
        let mut micro_sq = [0f64; N_TYPES]; // mean per-microbatch grad sq-norms
        let mut rank_sq = [0f64; N_TYPES]; // mean per-rank grad sq-norms
        let mut total_acc: Option<Vec<Buffer>> = None;
        let mut loss_sum = 0.0;

        for loader in loaders.iter_mut() {
            let mut rank_acc = runner.zero_grads()?;
            for _ in 0..accum {
                let batch = loader.next_batch(mb);
                let out = runner.grad_microbatch(&batch)?;
                loss_sum += out.loss as f64;
                gns_acc.add_microbatch(&out.stats);
                // Sequential method: norm of each microbatch gradient.
                let sums = runner.grad_sqnorms(&out.grads)?;
                for (d, s) in micro_sq.iter_mut().zip(sums) {
                    *d += s;
                }
                rank_acc = runner.accumulate(rank_acc, &out.grads)?;
            }
            // DDP method: per-rank mean-gradient norm before all-reduce.
            let sums = runner.grad_sqnorms(&rank_acc)?;
            for (d, s) in rank_sq.iter_mut().zip(sums) {
                *d += s / (accum * accum) as f64;
            }
            total_acc = Some(match total_acc {
                None => rank_acc,
                Some(prev) => runner.accumulate(prev, &rank_acc)?,
            });
        }

        let n_micro = (ranks * accum) as f64;
        let mean_grads = total_acc.unwrap();
        let sums = runner.grad_sqnorms(&mean_grads)?;
        let mut big = [0f64; N_TYPES];
        for (d, s) in big.iter_mut().zip(sums) {
            *d = s / (n_micro * n_micro);
        }
        let b_big = n_micro * mb as f64;

        // per-example (B_small = 1)
        let (small, _) = gns_acc.finish();
        perex.observe(b_big, &big, &small);
        // sequential (B_small = mb)
        for d in micro_sq.iter_mut() {
            *d /= n_micro;
        }
        let seq_comp: Vec<_> = (0..N_TYPES)
            .map(|t| gns_components(b_big, big[t], mb as f64, micro_sq[t]))
            .collect();
        let seq_total = gns_components(b_big, big.iter().sum(), mb as f64, micro_sq.iter().sum());
        seq.observe_components(&seq_comp, &seq_total);
        // DDP (B_small = mb * accum)
        for d in rank_sq.iter_mut() {
            *d /= ranks as f64;
        }
        let b_small_ddp = (mb * accum) as f64;
        let ddp_comp: Vec<_> = (0..N_TYPES)
            .map(|t| gns_components(b_big, big[t], b_small_ddp, rank_sq[t]))
            .collect();
        let ddp_total = gns_components(b_big, big.iter().sum(), b_small_ddp, rank_sq.iter().sum());
        ddp.observe_components(&ddp_comp, &ddp_total);

        runner.adamw_update(&mean_grads, 1e-3, 1.0 / n_micro)?;
        if step % 5 == 0 || step == 1 {
            println!(
                "{:>5} {:>9.4} {:>12.3} {:>12.3} {:>12.3}",
                step,
                loss_sum / n_micro,
                perex.gns_total().unwrap_or(f64::NAN),
                seq.gns_total().unwrap_or(f64::NAN),
                ddp.gns_total().unwrap_or(f64::NAN),
            );
        }
    }
    println!("---");
    println!("per-example GNS by layer type (smoothed):");
    for t in STATS_ORDER {
        println!("  {:<10} {:>10.3}", t, perex.gns_of(t).unwrap_or(f64::NAN));
    }
    println!("all three agree in expectation; per-example (B_small=1) is the");
    println!("minimal-variance estimator and works on any training configuration.");
    Ok(())
}
