//! Pure-coordinator overhead benchmarks: everything the L3 layer adds on
//! top of the XLA executions — GNS bookkeeping, schedules, data loading,
//! jackknife — which must be negligible next to a model step.
//!
//! Run: `cargo bench --bench coordinator`. Pass `--json` (after `--`) to
//! write medians to `BENCH_coordinator.json`.

use nanogns::data::{CorpusGenerator, Loader};
use nanogns::gns::{jackknife_ratio_stderr, GnsAccumulator, GnsSimulator, GnsTracker, SimConfig};
use nanogns::schedule::{BatchSizeSchedule, GnsController};
use nanogns::util::benchkit::{Bench, BenchJson};
use nanogns::{N_TYPES, STATS_ORDER};

fn run_and_record(bench: &mut Bench, report: &mut BenchJson, name: &str, f: impl FnMut()) {
    let stats = bench.run(name, f);
    report.record(&format!("coordinator/{name}"), &stats, None);
}

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let mut report = BenchJson::new();
    let mut bench = Bench::new("coordinator");

    run_and_record(&mut bench, &mut report, "gns_accumulator_8mb", || {
        let stats = [0.1f32, 0.2, 0.3, 0.4, 0.5];
        let mut acc = GnsAccumulator::new(N_TYPES, 4);
        for _ in 0..8 {
            acc.add_microbatch(&stats);
        }
        std::hint::black_box(acc.finish());
    });

    let mut tr = GnsTracker::new(&STATS_ORDER, 0.05);
    let big = [1.0; N_TYPES];
    let small = [2.0; N_TYPES];
    run_and_record(&mut bench, &mut report, "gns_tracker_observe", || {
        tr.observe(64.0, &big, &small);
        std::hint::black_box(tr.gns_total());
    });

    let s: Vec<f64> = (0..256).map(|i| 1.0 + (i % 7) as f64 * 0.1).collect();
    let g: Vec<f64> = (0..256).map(|i| 2.0 + (i % 5) as f64 * 0.1).collect();
    run_and_record(&mut bench, &mut report, "jackknife_256", || {
        std::hint::black_box(jackknife_ratio_stderr(&s, &g));
    });

    let text = CorpusGenerator::new(0).generate(1 << 20);
    let mut loader = Loader::new(&text, 128, 0);
    run_and_record(&mut bench, &mut report, "loader_next_batch_b4_t128", || {
        std::hint::black_box(loader.next_batch(4));
    });

    let mut ctl = GnsController::new(BatchSizeSchedule::Adaptive {
        min_accum: 1,
        max_accum: 64,
        gain: 0.5,
    });
    run_and_record(&mut bench, &mut report, "controller_decide", || {
        std::hint::black_box(ctl.decide(1_000_000, Some(37.5), 4));
    });

    run_and_record(&mut bench, &mut report, "simulator_estimate_32", || {
        let mut sim = GnsSimulator::new(SimConfig::default());
        std::hint::black_box(sim.estimate(64, 1, 32));
    });

    run_and_record(&mut bench, &mut report, "corpus_generate_64k", || {
        std::hint::black_box(CorpusGenerator::new(1).generate(1 << 16));
    });

    if json_mode {
        report.write_or_exit("BENCH_coordinator.json");
    }
}
