//! Fig. 8 benchmark: fused LayerNorm backward *with* per-example gradient
//! norms vs the plain backward, across hidden sizes — the paper's
//! zero-overhead claim. Four variants per size: {xla, pallas-lowered} x
//! {plain, gnorm}, all compiled from the AOT artifacts and timed through
//! the same PJRT runtime the trainer uses.
//!
//! Run: `cargo bench --bench ln_kernel` (uses the in-tree benchkit; this
//! offline build has no criterion). Pass `--json` (after `--`) to write
//! medians to `BENCH_ln_kernel.json`.

use nanogns::runtime::{pjrt, Manifest, Runtime, Tensor};
use nanogns::util::benchkit::{Bench, BenchJson};

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let mut report = BenchJson::new();
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping ln_kernel bench: {e}");
            return;
        }
    };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    println!("Fig. 8: LayerNorm backward variants (B, T fixed; K swept)");

    let mut rows: Vec<(usize, String, f64)> = Vec::new();
    for entry in &manifest.ln_bench {
        let (b, t, k) = (entry.b, entry.t, entry.k);
        let x = pjrt::tensor_to_literal(
            &Tensor::new(
                vec![b, t, k],
                (0..b * t * k).map(|i| ((i % 97) as f32 - 48.0) / 48.0).collect(),
            )
            .unwrap(),
        )
        .unwrap();
        let g = x.clone();
        let gamma = pjrt::tensor_to_literal(&Tensor::new(vec![k], vec![1.0; k]).unwrap()).unwrap();
        let beta = pjrt::tensor_to_literal(&Tensor::new(vec![k], vec![0.0; k]).unwrap()).unwrap();

        let mut bench = Bench::new(&format!("ln_backward_k{k}")).with_samples(10);
        let mut variants: Vec<&String> = entry.variants.keys().collect();
        variants.sort();
        for variant in variants {
            let rel = &entry.variants[variant];
            let exe = rt.load(manifest.root.join(rel)).expect("load ln artifact");
            let stats = bench.run(variant, || {
                exe.run(&[&x, &gamma, &beta, &g]).expect("ln exec");
            });
            report.record(
                &format!("ln_backward_k{k}/{variant}"),
                &stats,
                Some((b * t) as f64), // rows normalized per second
            );
            rows.push((k, variant.clone(), stats.mean_ns));
        }
    }
    if json_mode {
        report.write_or_exit("BENCH_ln_kernel.json");
    }

    // The zero-overhead headline: gnorm/plain ratio per K.
    println!("\nFig. 8 summary (overhead of per-example norms, XLA-fused path):");
    println!("{:>6} {:>14} {:>14} {:>9}", "K", "plain", "with-norms", "ratio");
    let find = |k: usize, name: &str| {
        rows.iter().find(|(rk, rn, _)| *rk == k && rn == name).map(|r| r.2)
    };
    let mut ks: Vec<usize> = rows.iter().map(|r| r.0).collect();
    ks.sort_unstable();
    ks.dedup();
    for k in ks {
        if let (Some(p), Some(gn)) = (find(k, "xla_plain"), find(k, "xla_gnorm")) {
            println!(
                "{:>6} {:>14} {:>14} {:>9.3}",
                k,
                nanogns::util::benchkit::fmt_ns(p),
                nanogns::util::benchkit::fmt_ns(gn),
                gn / p
            );
        }
    }
    println!("(paper claim: ratio ~1.0 — the backward is memory-bound, the norms are free)");
}
