//! §5.1 ablation: end-to-end cost of GNS instrumentation.
//!
//! Compares the instrumented grad_step (per-example norms for every layer,
//! the Section 3 "simultaneous" method) against grad_step_plain (identical
//! model, no instrumentation) — the measured analogue of the paper's
//! 40% vs 57% MFU comparison, and the motivation for LN-only tracking.
//!
//! Run: `cargo bench --bench instrumentation`. Pass `--json` (after
//! `--`) to write medians to `BENCH_instrumentation.json`.

use nanogns::coordinator::ModelRunner;
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::runtime::{pjrt, Manifest, PjrtFactory, Runtime};
use nanogns::util::benchkit::{Bench, BenchJson};

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let mut report = BenchJson::new();
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping instrumentation bench: {e}");
            return;
        }
    };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let factory = PjrtFactory::from_parts(rt.clone(), manifest.clone());
    println!("§5.1 ablation: instrumented vs plain grad step");
    let mut rows = Vec::new();
    for model in ["nano", "micro", "small"] {
        let Ok(entry) = manifest.config(model) else { continue };
        if !entry.artifacts.contains_key("grad_step_plain") {
            eprintln!("{model}: no grad_step_plain artifact (re-run make artifacts)");
            continue;
        }
        let mut runner = ModelRunner::new(&factory, model).unwrap();
        runner.init(0).unwrap();
        let text = CorpusGenerator::new(0).generate(1 << 16);
        let mut loader = Loader::new(&text, entry.seq_len, 0);
        let batch = loader.next_batch(entry.microbatch);
        let ids = pjrt::i32_literal(&[batch.batch, batch.seq_len], &batch.inputs).unwrap();
        let tgt = pjrt::i32_literal(&[batch.batch, batch.seq_len], &batch.targets).unwrap();

        let inst = rt
            .load(entry.artifact_path(&manifest.root, "grad_step").unwrap())
            .unwrap();
        let plain = rt
            .load(entry.artifact_path(&manifest.root, "grad_step_plain").unwrap())
            .unwrap();
        let mut args: Vec<xla::Literal> = runner
            .params
            .iter()
            .map(|b| match b {
                nanogns::runtime::Buffer::Pjrt(l) => l.clone(),
                other => pjrt::tensor_to_literal(&other.to_tensor().unwrap()).unwrap(),
            })
            .collect();
        args.push(ids);
        args.push(tgt);

        let mut bench =
            Bench::new(&format!("gradstep_{model}")).with_samples(5).with_target_ms(400);
        let p = bench.run("plain", || {
            plain.run(&args).unwrap();
        });
        let i = bench.run("instrumented", || {
            inst.run(&args).unwrap();
        });
        let tokens = (batch.batch * batch.seq_len) as f64;
        report.record(&format!("gradstep_{model}/plain"), &p, Some(tokens));
        report.record(&format!("gradstep_{model}/instrumented"), &i, Some(tokens));
        rows.push((model, p.mean_ns, i.mean_ns));
    }
    if json_mode {
        report.write_or_exit("BENCH_instrumentation.json");
    }
    println!("\n{:>8} {:>12} {:>14} {:>9}", "model", "plain", "instrumented", "ratio");
    for (m, p, i) in rows {
        println!(
            "{:>8} {:>12} {:>14} {:>9.3}",
            m,
            nanogns::util::benchkit::fmt_ns(p),
            nanogns::util::benchkit::fmt_ns(i),
            i / p
        );
    }
    println!("(paper analogue: all-layer tracking cost 57%->40% MFU at 1.3B;");
    println!(" LN-only tracking via the fused kernel is the zero-overhead path)");
}
