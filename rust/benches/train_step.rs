//! End-to-end step benchmarks: grad_step dispatch, accumulate, adam
//! update, grad_sqnorms — the coordinator's hot path per Section 5's
//! requirement that GNS tracking adds no training-time overhead.
//!
//! Runs on the hermetic reference backend, so this benchmark works on a
//! bare machine and tracks the pure-Rust kernels' trajectory over PRs.
//!
//! Run: `cargo bench --bench train_step`.

use nanogns::coordinator::ModelRunner;
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::runtime::ReferenceFactory;
use nanogns::util::benchkit::Bench;

fn main() {
    for model in ["nano", "micro", "small"] {
        let Ok(mut runner) = ModelRunner::new(&ReferenceFactory, model) else {
            eprintln!("skipping unknown model {model}");
            continue;
        };
        runner.init(0).unwrap();
        let text = CorpusGenerator::new(0).generate(1 << 17);
        let mut loader = Loader::new(&text, runner.entry.seq_len, 0);
        let batch = loader.next_batch(runner.entry.microbatch);

        let mut bench = Bench::new(&format!("step_{model}")).with_samples(5).with_target_ms(300);
        bench.run("grad_microbatch", || {
            runner.grad_microbatch(&batch).unwrap();
        });
        let out = runner.grad_microbatch(&batch).unwrap();
        bench.run("grad_sqnorms", || {
            runner.grad_sqnorms(&out.grads).unwrap();
        });
        bench.run("accumulate", || {
            let acc = runner.zero_grads().unwrap();
            runner.accumulate(acc, &out.grads).unwrap();
        });
        bench.run("adamw_update", || {
            runner.adamw_update(&out.grads, 1e-3, 1.0).unwrap();
        });
        bench.run("eval_step", || {
            runner.eval(&batch).unwrap();
        });
        bench.run("zero_grads_alloc", || {
            runner.zero_grads().unwrap();
        });
    }
}
