//! End-to-end step benchmarks: grad_step dispatch, accumulate, adam
//! update, grad_sqnorms — the coordinator's hot path per Section 5's
//! requirement that GNS tracking adds no training-time overhead.
//!
//! Runs on the hermetic reference backend, so this benchmark works on a
//! bare machine and tracks the pure-Rust kernels' trajectory over PRs.
//! The fused batched path (`grad_microbatch`) is benchmarked against the
//! retained per-example oracle (`grad_microbatch_per_example`) — the
//! before/after pair for the PR-over-PR speedup record.
//!
//! Run: `cargo bench --bench train_step`.
//! Flags (after `--`):
//! * `--json`  — write medians to `BENCH_train_step.json` (name →
//!   {median_ns, samples, throughput in tokens/sec for step entries});
//! * `--smoke` — minimal timing (CI mode): exercises every entry and the
//!   NaN/panic guard without caring about wall-clock stability;
//! * `--record-baseline` — stamp the report `_meta.recorded` (implies
//!   `--json`). Only the record-baseline workflow should pass this: a
//!   recorded report committed as `bench/baseline.json` arms benchcmp's
//!   absolute `kernel_*` median gates, which are meaningless unless the
//!   numbers came from the CI hardware pool itself.

use std::time::Instant;

use nanogns::config::{RankMode, TrainConfig};
use nanogns::coordinator::{ModelRunner, ParallelExecutor, Trainer};
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::norms::{NormKind, NormPlacement};
use nanogns::runtime::kernels::{
    ln_bwd_fused, ln_fwd, matmul_at_b_acc, matmul_xw_t, matmul_xwt, rms_bwd_fused, rms_fwd, tier,
    transpose, weight_sqnorms, WorkerPool,
};
use nanogns::runtime::reference::preset_cfg;
use nanogns::runtime::{ReferenceBackend, ReferenceFactory, ReferenceVariantFactory};
use nanogns::schedule::BatchSizeSchedule;
use nanogns::util::benchkit::{Bench, BenchJson, Stats};
use nanogns::util::crc::crc32;
use nanogns::util::rng::Rng;

/// SIMD-dispatched kernel microbenches on fixed `[B·T, …]` shapes — the
/// entries the absolute-median CI gate watches (group prefix `kernel_`).
/// Shapes are big enough to exercise the column tiling and the pool, and
/// small enough for stable medians on shared runners.
fn bench_kernels(report: &mut BenchJson, target_ms: u64, samples: usize) {
    let pool = WorkerPool::with_default_workers();
    let mut rng = Rng::seed_from_u64(42);
    let mut randv = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };

    // matmul: [128, 64] x [64, 256] forward + both backward contractions.
    let (m, k, n) = (128usize, 64usize, 256usize);
    let x = randv(m * k);
    let w = randv(k * n);
    let bias = randv(n);
    let mut wt = vec![0f32; k * n];
    transpose(&w, k, n, &mut wt);
    let mut y = vec![0f32; m * n];
    let mut xt = vec![0f32; k * m];
    transpose(&x, m, k, &mut xt);
    let mut dw = vec![0f32; k * n];
    let mut dx = vec![0f32; m * k];
    let mut bench = Bench::new("kernel_matmul").with_samples(samples).with_target_ms(target_ms);
    let s = bench.run(&format!("xwt_{m}x{k}x{n}"), || {
        matmul_xwt(&pool, &x, &wt, Some(&bias), m, k, n, &mut y);
    });
    report.record(&format!("kernel_matmul/xwt_{m}x{k}x{n}"), &s, Some((m * n) as f64));
    let s = bench.run(&format!("xw_t_{m}x{k}x{n}"), || {
        matmul_xw_t(&pool, &y, &w, m, k, n, &mut dx);
    });
    report.record(&format!("kernel_matmul/xw_t_{m}x{k}x{n}"), &s, Some((m * k) as f64));
    let s = bench.run(&format!("at_b_acc_{m}x{k}x{n}"), || {
        dw.fill(0.0);
        matmul_at_b_acc(&pool, &xt, &y, m, k, n, &mut dw);
    });
    report.record(&format!("kernel_matmul/at_b_acc_{m}x{k}x{n}"), &s, Some((k * n) as f64));

    // gram: per-example weight sqnorms, 8 examples of [16, 128]x[16, 128].
    let (bsz, t, gk, gn) = (8usize, 16usize, 128usize, 128usize);
    let gx = randv(bsz * t * gk);
    let gd = randv(bsz * t * gn);
    let mut norms = vec![0f64; bsz];
    let mut bench = Bench::new("kernel_gram").with_samples(samples).with_target_ms(target_ms);
    let s = bench.run(&format!("weight_sqnorms_{bsz}x{t}x{gk}"), || {
        weight_sqnorms(&pool, &gx, &gd, bsz, t, gk, gn, &mut norms);
    });
    report.record(&format!("kernel_gram/weight_sqnorms_{bsz}x{t}x{gk}"), &s, Some(bsz as f64));

    // layernorm: fused backward on [8·16, 256] with norm emission.
    let (lb, lt, ld) = (8usize, 16usize, 256usize);
    let rows = lb * lt;
    let lx = randv(rows * ld);
    let gamma = randv(ld);
    let beta = randv(ld);
    let mut out = vec![0f32; rows * ld];
    let mut xhat = vec![0f32; rows * ld];
    let mut rstd = vec![0f32; rows];
    ln_fwd(&lx, &gamma, &beta, rows, ld, 1e-5, &mut out, &mut xhat, &mut rstd);
    let dout = randv(rows * ld);
    let mut ldx = vec![0f32; rows * ld];
    let mut scratch = vec![0f32; lb * 2 * ld];
    let mut dg = vec![0f32; ld];
    let mut db = vec![0f32; ld];
    let mut sq = vec![0f64; lb];
    let mut bench =
        Bench::new("kernel_layernorm").with_samples(samples).with_target_ms(target_ms);
    let s = bench.run(&format!("fwd_{rows}x{ld}"), || {
        ln_fwd(&lx, &gamma, &beta, rows, ld, 1e-5, &mut out, &mut xhat, &mut rstd);
    });
    report.record(&format!("kernel_layernorm/fwd_{rows}x{ld}"), &s, Some(rows as f64));
    let s = bench.run(&format!("bwd_fused_{lb}x{lt}x{ld}"), || {
        dg.fill(0.0);
        db.fill(0.0);
        ln_bwd_fused(
            &pool, &dout, &xhat, &rstd, &gamma, lb, lt, ld, &mut ldx, &mut scratch, &mut dg,
            &mut db, Some(&mut sq),
        );
    });
    report.record(&format!("kernel_layernorm/bwd_fused_{lb}x{lt}x{ld}"), &s, Some(lb as f64));
}

/// RMSNorm zero-overhead gate (PR 10): the fused RMSNorm backward with
/// per-example `||dγ_b||²` emission vs its `Option`-gated norms-off
/// path — the §3 claim on the new kernel family. The emission is one
/// extra squared-sum over the per-example `dγ` partials the batch
/// reduction forms anyway, so the bound is tight: <1% on the kernel
/// itself. Sub-millisecond medians jitter on shared runners, so the
/// gate keeps the best of a few attempts — noise passes on an early
/// attempt, while a real regression fails every one.
fn bench_rmsnorm_kernel(report: &mut BenchJson, target_ms: u64, samples: usize) {
    let pool = WorkerPool::with_default_workers();
    let mut rng = Rng::seed_from_u64(7);
    let mut randv = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };
    // T large enough that the per-example emission amortizes the way it
    // does in a real sequence (the gate bounds the kernel, not noise).
    let (bsz, t, d) = (8usize, 64usize, 256usize);
    let rows = bsz * t;
    let x = randv(rows * d);
    let gamma: Vec<f32> = (0..d).map(|j| 1.0 + 0.01 * j as f32).collect();
    let (mut out, mut xhat, mut rstd) =
        (vec![0f32; rows * d], vec![0f32; rows * d], vec![0f32; rows]);
    let mut bench = Bench::new("kernel_rmsnorm").with_samples(samples).with_target_ms(target_ms);
    let s = bench.run(&format!("fwd_{rows}x{d}"), || {
        rms_fwd(&x, &gamma, rows, d, 1e-5, &mut out, &mut xhat, &mut rstd);
    });
    report.record(&format!("kernel_rmsnorm/fwd_{rows}x{d}"), &s, Some(rows as f64));

    let dout = randv(rows * d);
    let mut dx = vec![0f32; rows * d];
    let mut scratch = vec![0f32; bsz * d];
    let mut dg = vec![0f32; d];
    let mut sq = vec![0f64; bsz];
    let mut best_pct = f64::INFINITY;
    let (mut best_on, mut best_off) = (f64::NAN, f64::NAN);
    for attempt in 0..5 {
        let on = bench.run(&format!("bwd_fused_{bsz}x{t}x{d}"), || {
            dg.fill(0.0);
            rms_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch, &mut dg,
                Some(&mut sq),
            );
        });
        let off = bench.run(&format!("bwd_no_norms_{bsz}x{t}x{d}"), || {
            dg.fill(0.0);
            rms_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch, &mut dg,
                None,
            );
        });
        if attempt == 0 {
            report.record(
                &format!("kernel_rmsnorm/bwd_fused_{bsz}x{t}x{d}"),
                &on,
                Some(bsz as f64),
            );
            report.record(
                &format!("kernel_rmsnorm/bwd_no_norms_{bsz}x{t}x{d}"),
                &off,
                Some(bsz as f64),
            );
        }
        let pct = 100.0 * (on.median_ns - off.median_ns) / off.median_ns.max(1.0);
        if pct < best_pct {
            best_pct = pct;
            best_on = on.median_ns;
            best_off = off.median_ns;
        }
        if best_pct < 1.0 {
            break;
        }
    }
    println!(
        "kernel_rmsnorm: norm-emission overhead {best_pct:+.3}% (fused {:.4} ms vs norms-off \
         {:.4} ms)",
        best_on / 1e6,
        best_off / 1e6,
    );
    assert!(
        best_pct < 1.0,
        "RMSNorm per-example-norm emission must stay under 1% of the fused backward \
         (fused {:.4} ms vs norms-off {:.4} ms = {best_pct:+.3}%)",
        best_on / 1e6,
        best_off / 1e6,
    );
}

/// Step-level view of the same claim on the `rmsnorm × periln` matrix
/// cell: the fused microbatch backward (every per-example stat on) vs
/// the norms-off oracle step. Informational like the LayerNorm entries
/// above — the hard <1% gate lives in [`bench_rmsnorm_kernel`], where
/// the comparison isolates the norm emission itself.
fn bench_rmsnorm_step(report: &mut BenchJson, target_ms: u64, samples: usize) {
    let model = "small";
    let factory = ReferenceVariantFactory::new(NormKind::RmsNorm, NormPlacement::PeriLn);
    let mut runner = ModelRunner::new(&factory, model).unwrap();
    runner.init(0).unwrap();
    let mut cfg = preset_cfg(model).unwrap();
    cfg.norm = NormKind::RmsNorm;
    cfg.placement = NormPlacement::PeriLn;
    let oracle = ReferenceBackend::new(cfg).unwrap();
    let text = CorpusGenerator::new(0).generate(1 << 17);
    let mut loader = Loader::new(&text, runner.entry.seq_len, 0);
    let batch = loader.next_batch(runner.entry.microbatch);
    let tokens = (runner.entry.microbatch * runner.entry.seq_len) as f64;

    let group = format!("step_{model}_rmsnorm_periln");
    let mut bench = Bench::new(&group).with_samples(samples).with_target_ms(target_ms);
    let fused = bench.run("grad_microbatch", || {
        runner.grad_microbatch(&batch).unwrap();
    });
    report.record(&format!("{group}/grad_microbatch"), &fused, Some(tokens));
    let no_norms = bench.run("grad_microbatch_no_norms", || {
        oracle.grad_step_no_stats(&runner.params, &batch).unwrap();
    });
    report.record(&format!("{group}/grad_microbatch_no_norms"), &no_norms, Some(tokens));
    println!(
        "{group}: per-example-norm overhead {:+.2}% (fused {:.3} ms vs norms-off {:.3} ms)",
        100.0 * (fused.median_ns - no_norms.median_ns) / no_norms.median_ns.max(1.0),
        fused.median_ns / 1e6,
        no_norms.median_ns / 1e6,
    );
}

/// Async-checkpoint latency gate (PR 8): `Trainer::checkpoint_now` is an
/// encode plus a writer-thread handoff, so submitting a checkpoint must
/// cost less than a training step — otherwise the writer thread is
/// silently back on the hot path. This asserts rather than records: a
/// regression here is a broken double-buffer contract, not a perf trend.
fn assert_async_checkpoint_latency(samples: usize) {
    let dir = std::env::temp_dir().join(format!("nanogns_bench_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = TrainConfig::quickstart("nano", 1 << 20);
    cfg.checkpoint_dir = dir.display().to_string();
    let mut tr = Trainer::new(&ReferenceFactory, cfg).unwrap();

    let mut step_ns = Vec::with_capacity(samples);
    let mut submit_ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        tr.step().unwrap();
        step_ns.push(t0.elapsed().as_nanos() as f64);
        // Drain the writer first so the timed window is the pure encode
        // + channel handoff, never a block on a previous write.
        tr.wait_checkpoints().unwrap();
        let t0 = Instant::now();
        tr.checkpoint_now().unwrap();
        submit_ns.push(t0.elapsed().as_nanos() as f64);
    }
    tr.wait_checkpoints().unwrap();
    drop(tr);
    let med = |v: &mut Vec<f64>| -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let (step, submit) = (med(&mut step_ns), med(&mut submit_ns));
    println!(
        "ckpt_async: submit median {:.3} ms vs step median {:.3} ms",
        submit / 1e6,
        step / 1e6
    );
    assert!(
        submit < step,
        "checkpoint submit ({:.3} ms) must be cheaper than a training step ({:.3} ms)",
        submit / 1e6,
        step / 1e6
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Integrity-overhead gate (PR 9): every elastic frame now carries a
/// CRC-32 trailer and every checkpoint a per-group payload checksum, so
/// this entry proves the integrity paths stay under 1% of a real
/// process-mode elastic step. The comparator is measured, not assumed:
/// a supervised-worker step on the `small` model at the large-batch end
/// of the GNS schedule (accum 64), which is where elastic runs spend
/// their wall clock. `NANOGNS_FAULT_PLAN` is never set here, so fault
/// injection stays disarmed and `faultkit::armed()` is one cached
/// atomic load on the hot path.
fn bench_integrity(report: &mut BenchJson, target_ms: u64, samples: usize) {
    let (ranks, workers, accum) = (2usize, 2usize, 64usize);
    let mut cfg = TrainConfig::quickstart("small", 1 << 20);
    cfg.ranks = ranks;
    cfg.batch_size = BatchSizeSchedule::Fixed { accum };
    cfg.rank_mode = RankMode::Process;
    cfg.elastic.worker_exe = env!("CARGO_BIN_EXE_repro").to_string();
    let mut tr = Trainer::with_rank_workers(&ReferenceFactory, cfg, workers).unwrap();
    let step_tokens =
        (ranks * tr.runner.entry.microbatch * tr.runner.entry.seq_len) as f64 * accum as f64;

    // Warm up once (worker handshake, lazy grad buffers), then time
    // real steps: compute + serialization + sockets + CRC, everything.
    tr.step().unwrap();
    let mut step_ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        tr.step().unwrap();
        step_ns.push(t0.elapsed().as_nanos() as f64);
    }
    step_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let step_med = step_ns[step_ns.len() / 2];
    let step_stats = Stats {
        name: format!("elastic_step_r{ranks}w{workers}a{accum}"),
        mean_ns: step_ns.iter().sum::<f64>() / step_ns.len() as f64,
        std_ns: 0.0,
        median_ns: step_med,
        min_ns: step_ns[0],
        iters: 1,
        samples: step_ns.len(),
    };
    report.record(
        &format!("integrity/elastic_step_r{ranks}w{workers}a{accum}"),
        &step_stats,
        Some(step_tokens),
    );

    // Bytes the frame CRCs touch per step, counted on the wall-clock
    // path: the coordinator checksums each Step payload out (params x
    // workers) and verifies each Result in (grads x ranks); a worker
    // verifies its Step and checksums its Result (+2 x params — the
    // workers run in parallel, so one worker's share bounds their wall
    // contribution). Task metadata, rng states and sqnorms are noise
    // next to the tensor payloads.
    let params_bytes: usize = tr.runner.params.iter().map(|t| t.data.len() * 4).sum();
    let frame_bytes = (workers + ranks + 2) * params_bytes;
    let mut buf = vec![0u8; frame_bytes];
    let mut rng = Rng::seed_from_u64(0x1C7);
    for chunk in buf.chunks_mut(8) {
        let v = rng.next_u64().to_le_bytes();
        chunk.copy_from_slice(&v[..chunk.len()]);
    }
    let mut bench = Bench::new("integrity").with_samples(samples).with_target_ms(target_ms);
    let frames = bench.run("crc32_step_frames", || {
        std::hint::black_box(crc32(std::hint::black_box(&buf)));
    });
    report.record("integrity/crc32_step_frames", &frames, Some(frame_bytes as f64));

    // The checkpoint side: the integrity chain's cost is one CRC pass
    // over the encoded image (the per-group pre-pass in encode_state
    // walks the same bytes once). Measure it over a real image.
    let dir = std::env::temp_dir().join(format!("nanogns_bench_integrity_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let img_path = dir.join("image.ckpt");
    tr.save_checkpoint(&img_path).unwrap();
    let image = std::fs::read(&img_path).unwrap();
    let image_stats = bench.run("crc32_ckpt_image", || {
        std::hint::black_box(crc32(std::hint::black_box(&image)));
    });
    report.record("integrity/crc32_ckpt_image", &image_stats, Some(image.len() as f64));
    drop(tr);
    let _ = std::fs::remove_dir_all(&dir);

    let frame_pct = 100.0 * frames.median_ns / step_med;
    let image_pct = 100.0 * image_stats.median_ns / step_med;
    println!(
        "integrity: elastic step (r{ranks} w{workers} accum {accum}) median {:.3} ms; \
         frame CRC {:.3} ms ({frame_pct:.3}%), ckpt-image CRC {:.3} ms ({image_pct:.3}%)",
        step_med / 1e6,
        frames.median_ns / 1e6,
        image_stats.median_ns / 1e6,
    );
    assert!(
        frame_pct < 1.0,
        "frame CRC cost ({:.3} ms over {frame_bytes} bytes) must stay under 1% of an elastic \
         step ({:.3} ms), got {frame_pct:.3}%",
        frames.median_ns / 1e6,
        step_med / 1e6,
    );
    assert!(
        image_pct < 1.0,
        "checkpoint-image CRC cost ({:.3} ms over {} bytes) must stay under 1% of an elastic \
         step ({:.3} ms), got {image_pct:.3}%",
        image_stats.median_ns / 1e6,
        image.len(),
        step_med / 1e6,
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let record_baseline = args.iter().any(|a| a == "--record-baseline");
    let json_mode = args.iter().any(|a| a == "--json") || record_baseline;
    let smoke = args.iter().any(|a| a == "--smoke");
    // Smoke keeps wall time low but takes 3 samples at a 20 ms target:
    // the bench-gate job compares the fused/oracle median *ratio*
    // against bench/baseline.json, so the medians need to be stable
    // enough for a 15% budget on shared CI runners.
    let (target_ms, samples) = if smoke { (20, 3) } else { (300, 5) };
    let mut report = BenchJson::new();
    if record_baseline {
        report.set_recorded(&std::env::var("NANOGNS_BENCH_SOURCE").unwrap_or_else(|_| {
            "record-baseline".to_string()
        }));
    }
    println!("simd tier: {}", tier().name());

    bench_kernels(&mut report, target_ms, samples);
    bench_rmsnorm_kernel(&mut report, target_ms, samples);
    bench_rmsnorm_step(&mut report, target_ms, samples);

    for model in ["nano", "micro", "small"] {
        let Ok(mut runner) = ModelRunner::new(&ReferenceFactory, model) else {
            eprintln!("skipping unknown model {model}");
            continue;
        };
        runner.init(0).unwrap();
        let oracle = ReferenceBackend::from_preset(model).unwrap();
        let text = CorpusGenerator::new(0).generate(1 << 17);
        let mut loader = Loader::new(&text, runner.entry.seq_len, 0);
        let batch = loader.next_batch(runner.entry.microbatch);
        let tokens = (runner.entry.microbatch * runner.entry.seq_len) as f64;

        // NaN/regression guard (the point of the CI smoke job): a fused
        // step must produce finite loss, strictly-positive finite stats,
        // and finite gradients.
        let out = runner.grad_microbatch(&batch).unwrap();
        assert!(out.loss.is_finite(), "{model}: non-finite loss {}", out.loss);
        for (t, s) in nanogns::STATS_ORDER.iter().zip(out.stats) {
            assert!(s.is_finite() && s > 0.0, "{model}: bad stats[{t}] = {s}");
        }
        for (spec, g) in runner.entry.params.iter().zip(&out.grads) {
            let gt = g.to_tensor().unwrap();
            assert!(
                gt.data.iter().all(|v| v.is_finite()),
                "{model}: non-finite gradient in {}",
                spec.name
            );
        }

        let group = format!("step_{model}");
        let mut bench = Bench::new(&group).with_samples(samples).with_target_ms(target_ms);

        let fused = bench.run("grad_microbatch", || {
            runner.grad_microbatch(&batch).unwrap();
        });
        report.record(&format!("{group}/grad_microbatch"), &fused, Some(tokens));

        let baseline = bench.run("grad_microbatch_per_example", || {
            oracle.grad_step_per_example(&runner.params, &batch).unwrap();
        });
        report.record(&format!("{group}/grad_microbatch_per_example"), &baseline, Some(tokens));
        println!(
            "{group}: fused {:.3} ms vs per-example {:.3} ms -> {:.2}x",
            fused.median_ns / 1e6,
            baseline.median_ns / 1e6,
            baseline.median_ns / fused.median_ns.max(1.0)
        );

        // The paper's overhead claim (§3): the same backward with every
        // per-example norm contraction skipped. The fused/no-norms gap is
        // the true cost of GNS tracking — the acceptance target is ≤2%.
        let no_norms = bench.run("grad_microbatch_no_norms", || {
            oracle.grad_step_no_stats(&runner.params, &batch).unwrap();
        });
        report.record(&format!("{group}/grad_microbatch_no_norms"), &no_norms, Some(tokens));
        println!(
            "{group}: per-example-norm overhead {:+.2}% (fused {:.3} ms vs norms-off {:.3} ms)",
            100.0 * (fused.median_ns - no_norms.median_ns) / no_norms.median_ns.max(1.0),
            fused.median_ns / 1e6,
            no_norms.median_ns / 1e6,
        );

        let s = bench.run("grad_sqnorms", || {
            runner.grad_sqnorms(&out.grads).unwrap();
        });
        report.record(&format!("{group}/grad_sqnorms"), &s, None);
        let s = bench.run("accumulate", || {
            let acc = runner.lease_zero_grads().unwrap();
            let acc = runner.accumulate(acc, &out.grads).unwrap();
            runner.recycle_grads(acc);
        });
        report.record(&format!("{group}/accumulate"), &s, None);
        let s = bench.run("adamw_update", || {
            runner.adamw_update(&out.grads, 1e-3, 1.0).unwrap();
        });
        report.record(&format!("{group}/adamw_update"), &s, None);
        let s = bench.run("eval_step", || {
            runner.eval(&batch).unwrap();
        });
        report.record(&format!("{group}/eval_step"), &s, Some(tokens));
        let s = bench.run("zero_grads_alloc", || {
            runner.zero_grads().unwrap();
        });
        report.record(&format!("{group}/zero_grads_alloc"), &s, None);
        // The arena satellite: lease + recycle must beat fresh allocation.
        let s = bench.run("zero_grads_arena", || {
            let g = runner.lease_zero_grads().unwrap();
            runner.recycle_grads(g);
        });
        report.record(&format!("{group}/zero_grads_arena"), &s, None);

        // Rank-parallel engine (PR 5): the same 4-rank workload on 1
        // worker vs 4 records the rank-scaling headroom. Results are
        // bitwise identical across worker counts (the engine's reduction
        // contract); only the wall clock may differ.
        let ranks = 4usize;
        let rank_tokens = (ranks * runner.entry.microbatch * runner.entry.seq_len) as f64;
        for workers in [1usize, ranks] {
            let engine =
                ParallelExecutor::with_workers(&ReferenceFactory, model, ranks, workers).unwrap();
            let mut rank_loaders: Vec<Loader> =
                (0..ranks as u64).map(|r| loader.for_rank(r)).collect();
            let s = bench.run(&format!("parallel_rank_step_w{workers}"), || {
                let out = engine.rank_step(&runner.params, &mut rank_loaders, 1, false).unwrap();
                engine.recycle(out.grads);
            });
            report.record(&format!("{group}/parallel_rank_step_w{workers}"), &s, Some(rank_tokens));
        }
    }

    assert_async_checkpoint_latency(samples);
    bench_integrity(&mut report, target_ms, samples);

    if json_mode {
        report.write_or_exit("BENCH_train_step.json");
    }
}
