//! End-to-end step benchmarks: grad_step dispatch, accumulate, adam
//! update, grad_sqnorms — the coordinator's hot path per Section 5's
//! requirement that GNS tracking adds no training-time overhead.
//!
//! Runs on the hermetic reference backend, so this benchmark works on a
//! bare machine and tracks the pure-Rust kernels' trajectory over PRs.
//! The fused batched path (`grad_microbatch`) is benchmarked against the
//! retained per-example oracle (`grad_microbatch_per_example`) — the
//! before/after pair for the PR-over-PR speedup record.
//!
//! Run: `cargo bench --bench train_step`.
//! Flags (after `--`):
//! * `--json`  — write medians to `BENCH_train_step.json` (name →
//!   {median_ns, samples, throughput in tokens/sec for step entries});
//! * `--smoke` — minimal timing (CI mode): exercises every entry and the
//!   NaN/panic guard without caring about wall-clock stability.

use nanogns::coordinator::{ModelRunner, ParallelExecutor};
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::runtime::{ReferenceBackend, ReferenceFactory};
use nanogns::util::benchkit::{Bench, BenchJson};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_mode = args.iter().any(|a| a == "--json");
    let smoke = args.iter().any(|a| a == "--smoke");
    // Smoke keeps wall time low but takes 3 samples at a 20 ms target:
    // the bench-gate job compares the fused/oracle median *ratio*
    // against bench/baseline.json, so the medians need to be stable
    // enough for a 15% budget on shared CI runners.
    let (target_ms, samples) = if smoke { (20, 3) } else { (300, 5) };
    let mut report = BenchJson::new();

    for model in ["nano", "micro", "small"] {
        let Ok(mut runner) = ModelRunner::new(&ReferenceFactory, model) else {
            eprintln!("skipping unknown model {model}");
            continue;
        };
        runner.init(0).unwrap();
        let oracle = ReferenceBackend::from_preset(model).unwrap();
        let text = CorpusGenerator::new(0).generate(1 << 17);
        let mut loader = Loader::new(&text, runner.entry.seq_len, 0);
        let batch = loader.next_batch(runner.entry.microbatch);
        let tokens = (runner.entry.microbatch * runner.entry.seq_len) as f64;

        // NaN/regression guard (the point of the CI smoke job): a fused
        // step must produce finite loss, strictly-positive finite stats,
        // and finite gradients.
        let out = runner.grad_microbatch(&batch).unwrap();
        assert!(out.loss.is_finite(), "{model}: non-finite loss {}", out.loss);
        for (t, s) in nanogns::STATS_ORDER.iter().zip(out.stats) {
            assert!(s.is_finite() && s > 0.0, "{model}: bad stats[{t}] = {s}");
        }
        for (spec, g) in runner.entry.params.iter().zip(&out.grads) {
            let gt = g.to_tensor().unwrap();
            assert!(
                gt.data.iter().all(|v| v.is_finite()),
                "{model}: non-finite gradient in {}",
                spec.name
            );
        }

        let group = format!("step_{model}");
        let mut bench = Bench::new(&group).with_samples(samples).with_target_ms(target_ms);

        let fused = bench.run("grad_microbatch", || {
            runner.grad_microbatch(&batch).unwrap();
        });
        report.record(&format!("{group}/grad_microbatch"), &fused, Some(tokens));

        let baseline = bench.run("grad_microbatch_per_example", || {
            oracle.grad_step_per_example(&runner.params, &batch).unwrap();
        });
        report.record(&format!("{group}/grad_microbatch_per_example"), &baseline, Some(tokens));
        println!(
            "{group}: fused {:.3} ms vs per-example {:.3} ms -> {:.2}x",
            fused.median_ns / 1e6,
            baseline.median_ns / 1e6,
            baseline.median_ns / fused.median_ns.max(1.0)
        );

        let s = bench.run("grad_sqnorms", || {
            runner.grad_sqnorms(&out.grads).unwrap();
        });
        report.record(&format!("{group}/grad_sqnorms"), &s, None);
        let s = bench.run("accumulate", || {
            let acc = runner.lease_zero_grads().unwrap();
            let acc = runner.accumulate(acc, &out.grads).unwrap();
            runner.recycle_grads(acc);
        });
        report.record(&format!("{group}/accumulate"), &s, None);
        let s = bench.run("adamw_update", || {
            runner.adamw_update(&out.grads, 1e-3, 1.0).unwrap();
        });
        report.record(&format!("{group}/adamw_update"), &s, None);
        let s = bench.run("eval_step", || {
            runner.eval(&batch).unwrap();
        });
        report.record(&format!("{group}/eval_step"), &s, Some(tokens));
        let s = bench.run("zero_grads_alloc", || {
            runner.zero_grads().unwrap();
        });
        report.record(&format!("{group}/zero_grads_alloc"), &s, None);
        // The arena satellite: lease + recycle must beat fresh allocation.
        let s = bench.run("zero_grads_arena", || {
            let g = runner.lease_zero_grads().unwrap();
            runner.recycle_grads(g);
        });
        report.record(&format!("{group}/zero_grads_arena"), &s, None);

        // Rank-parallel engine (PR 5): the same 4-rank workload on 1
        // worker vs 4 records the rank-scaling headroom. Results are
        // bitwise identical across worker counts (the engine's reduction
        // contract); only the wall clock may differ.
        let ranks = 4usize;
        let rank_tokens = (ranks * runner.entry.microbatch * runner.entry.seq_len) as f64;
        for workers in [1usize, ranks] {
            let engine =
                ParallelExecutor::with_workers(&ReferenceFactory, model, ranks, workers).unwrap();
            let mut rank_loaders: Vec<Loader> =
                (0..ranks as u64).map(|r| loader.for_rank(r)).collect();
            let s = bench.run(&format!("parallel_rank_step_w{workers}"), || {
                let out = engine.rank_step(&runner.params, &mut rank_loaders, 1, false).unwrap();
                engine.recycle(out.grads);
            });
            report.record(&format!("{group}/parallel_rank_step_w{workers}"), &s, Some(rank_tokens));
        }
    }

    if json_mode {
        report.write_or_exit("BENCH_train_step.json");
    }
}
