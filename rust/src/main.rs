//! `repro` — nanoGNS-rs launcher.
//!
//! Subcommands:
//! * `train`   — run a training job from a JSON config (or quick flags);
//! * `serve`   — run a training job with a live HTTP telemetry daemon;
//! * `figures` — regenerate any paper figure/table (see DESIGN.md §4);
//! * `info`    — inspect the available model configs;
//! * `inspect` — read fields out of checkpoints / bench reports;
//! * `help`.
//!
//! Argument parsing lives in [`nanogns::cli`]: one typed struct per
//! subcommand over a spec-driven lexer, so unknown flags fail loudly
//! (with a "did you mean" suggestion) instead of silently training the
//! defaults. The default backend is the hermetic pure-Rust reference
//! transformer, so the binary works on a bare machine; `--backend pjrt`
//! (with the `pjrt` cargo feature and `make artifacts`) switches to the
//! AOT HLO path. (CLI parsing is hand-rolled: this build is offline,
//! no clap.)

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use nanogns::cli::{self, FiguresArgs, InfoArgs, InspectArgs, RankWorkerArgs, ServeArgs, TrainArgs};
use nanogns::config::{RankMode, TrainConfig};
use nanogns::coordinator::{TrainOutcome, Trainer};
use nanogns::figures;
use nanogns::norms::{self, NormKind, NormPlacement};
use nanogns::runtime::{BackendFactory, ReferenceFactory, ReferenceVariantFactory};
use nanogns::serve::{self, Server, TelemetryHub};
use nanogns::util::json::Value;

const USAGE: &str = "\
repro — GNS-instrumented training coordinator (nanoGNS-rs)

USAGE:
  repro train    [--config F.json] [--model NAME] [--steps N] [...] [--json]
  repro serve    [train flags ...] [--port N] [--bind ADDR] [--ring-capacity N]
  repro figures  (--fig N | --table N | --report predictor | --all) [...] [--json]
  repro info     [--json]
  repro inspect  PATH [--kind checkpoint|bench|tracker|predictor] [--field NAME] [--json]
  repro help

Run `repro <subcommand> --help` for the full per-command flag list.

GLOBAL (train/serve/figures/info):
  --backend NAME    execution backend: reference (default) | pjrt (needs --features pjrt)
  --artifacts DIR   artifact directory for the pjrt backend (default: artifacts)

Data-parallel ranks run concurrently; NANOGNS_RANK_WORKERS caps the rank worker
threads (results are bitwise identical for any setting). NANOGNS_THREADS sizes
the per-backend kernel worker pool; NANOGNS_FORCE_SCALAR=1 pins every kernel to
the scalar oracle tier (config keys `threads` / `force_scalar` do the same).
With `--rank-mode process` ranks run in supervised child processes instead of
threads (same bitwise results); a dead worker is reconciled away and the run
continues on the survivors. (`repro rank-worker` is the internal child-process
entry point — the coordinator spawns it, you don't.)

The reference backend trains a normalization/architecture matrix: --norm
{layernorm|rmsnorm} x --placement {preln|postln|periln} (env NANOGNS_NORM /
NANOGNS_PLACEMENT, config keys `norm_kind` / `norm_placement`; sources that
disagree are an error). `repro figures --report predictor` sweeps the matrix
and scores the norm-only GNS predictor per cell.

FIGURES: 2..16 map to the paper's figures (8 = `cargo bench --features pjrt --bench ln_kernel`;
11..13 need the pjrt backend), tables 1..2, reports: predictor.
";

#[allow(unused_variables)]
fn make_factory(backend: &str, artifacts: &str) -> Result<Box<dyn BackendFactory>> {
    match backend {
        "reference" => Ok(Box::new(ReferenceFactory)),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(nanogns::runtime::PjrtFactory::new(artifacts)?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            bail!("this binary was built without the `pjrt` feature (cargo build --features pjrt)")
        }
        other => bail!("unknown backend {other:?} (reference|pjrt)\n{USAGE}"),
    }
}

/// Train/serve factory selection: like [`make_factory`], but the
/// reference backend is built at the resolved normalization variant.
/// Other backends only implement the default cell, so an explicit
/// variant request on them is an error rather than a silent ignore.
fn make_variant_factory(backend: &str, cfg: &TrainConfig) -> Result<Box<dyn BackendFactory>> {
    if backend == "reference" {
        return Ok(Box::new(ReferenceVariantFactory::new(cfg.norm(), cfg.placement())));
    }
    if cfg.norm_kind.is_some() || cfg.norm_placement.is_some() {
        bail!(
            "norm/placement variants are only supported on the reference backend \
             (got --backend {backend})"
        );
    }
    make_factory(backend, &cfg.artifacts)
}

/// Figs. 11–13 run on raw teacher–student artifacts, pjrt only.
#[cfg(feature = "pjrt")]
fn fig_instability(which: u32, artifacts: &str, steps: u64) -> Result<()> {
    let manifest = nanogns::runtime::Manifest::load(artifacts)?;
    let rt = nanogns::runtime::Runtime::cpu()?;
    match which {
        13 => figures::instability::fig13(&rt, &manifest, steps.max(100), 0.35),
        _ => figures::instability::fig12(&rt, &manifest, steps.max(100), 0.35),
    }
}

#[cfg(not(feature = "pjrt"))]
fn fig_instability(_which: u32, _artifacts: &str, _steps: u64) -> Result<()> {
    bail!("figures 11-13 need the teacher-student HLO artifacts: rebuild with --features pjrt")
}

/// Resolve a [`TrainConfig`] from typed train flags: config file (or
/// quickstart) plus flag overrides, then export the kernel knobs. The
/// env vars must be set before the first backend is built — the
/// worker-pool size and SIMD tier are read once, lazily, on first use;
/// explicit env vars still win over config keys.
fn build_train_config(t: &TrainArgs) -> Result<TrainConfig> {
    let mut cfg = match &t.config {
        Some(path) => TrainConfig::from_file(path)?,
        None => {
            let mut c = TrainConfig::quickstart(&t.model, t.steps);
            c.seed = t.seed;
            c.metrics_path = t.metrics.clone();
            c.ranks = t.ranks;
            c
        }
    };
    cfg.artifacts = t.artifacts.clone();
    // Checkpoint flags always win over the config file.
    if let Some(dir) = &t.checkpoint_dir {
        cfg.checkpoint_dir = dir.clone();
    }
    if let Some(every) = t.checkpoint_every {
        cfg.checkpoint_every = every;
    }
    if let Some(keep) = t.keep_last {
        cfg.checkpoint_keep_last = keep;
    }
    if let Some(r) = &t.resume {
        cfg.resume = r.clone();
    }
    if let Some(mode) = &t.rank_mode {
        cfg.rank_mode = RankMode::parse(mode)?;
    }
    // Normalization variant: flag, env var, and config key must agree
    // whenever more than one is present (`norms::resolve` rejects
    // conflicts with a typed error naming both sources).
    let env_norm = std::env::var("NANOGNS_NORM").ok();
    cfg.norm_kind = norms::resolve::<NormKind>(
        "norm kind",
        &[
            ("--norm", t.norm.as_deref()),
            ("NANOGNS_NORM", env_norm.as_deref()),
            ("config key \"norm_kind\"", cfg.norm_kind.map(|k| k.name())),
        ],
    )?;
    let env_placement = std::env::var("NANOGNS_PLACEMENT").ok();
    cfg.norm_placement = norms::resolve::<NormPlacement>(
        "norm placement",
        &[
            ("--placement", t.placement.as_deref()),
            ("NANOGNS_PLACEMENT", env_placement.as_deref()),
            ("config key \"norm_placement\"", cfg.norm_placement.map(|p| p.name())),
        ],
    )?;
    // Process-mode rank workers rebuild the factory from the
    // environment, so the resolved variant must ride along. (The value
    // written back is the one `resolve` agreed on, so overwriting the
    // env var never changes its meaning.)
    if let Some(k) = cfg.norm_kind {
        std::env::set_var("NANOGNS_NORM", k.name());
    }
    if let Some(p) = cfg.norm_placement {
        std::env::set_var("NANOGNS_PLACEMENT", p.name());
    }
    if cfg.threads > 0 && std::env::var("NANOGNS_THREADS").is_err() {
        std::env::set_var("NANOGNS_THREADS", cfg.threads.to_string());
    }
    if cfg.force_scalar && std::env::var("NANOGNS_FORCE_SCALAR").is_err() {
        std::env::set_var("NANOGNS_FORCE_SCALAR", "1");
    }
    Ok(cfg)
}

/// Build a trainer (fresh or resumed), echoing progress through `say`
/// so `--json` runs keep stdout machine-readable.
fn build_trainer(
    factory: &dyn BackendFactory,
    cfg: TrainConfig,
    say: &dyn Fn(String),
) -> Result<Trainer> {
    let resume = cfg.resume.clone();
    say(format!(
        "training {} ({:.2}M params) for {} steps on {}",
        cfg.model,
        factory.describe(&cfg.model)?.n_params as f64 / 1e6,
        cfg.steps,
        factory.platform()
    ));
    let tr = if resume.is_empty() {
        Trainer::new(factory, cfg)?
    } else {
        let tr = Trainer::resume(factory, cfg, &resume)?;
        say(format!("resumed from {resume} at step {} ({} tokens)", tr.runner.step, tr.tokens()));
        tr
    };
    if tr.cfg.ranks > 1 {
        say(format!("ranks: {} on {} rank worker(s)", tr.cfg.ranks, tr.rank_workers()));
    }
    Ok(tr)
}

fn final_line(out: &TrainOutcome) -> Option<String> {
    out.records.last().map(|r| {
        format!(
            "final: step {} loss {:.4} gns_total {:.2} gns_ln {:.2} ({} tokens)",
            r.step, r.loss, r.gns_total, r.gns_layernorm, out.tokens
        )
    })
}

fn gns_triple(s: &nanogns::gns::TypeSnapshot) -> Value {
    let mut m = BTreeMap::new();
    m.insert("g_sq".to_string(), Value::finite_or_null(s.g_sq));
    m.insert("s".to_string(), Value::finite_or_null(s.s));
    m.insert("gns".to_string(), s.gns.map(Value::finite_or_null).unwrap_or(Value::Null));
    Value::Obj(m)
}

fn str_or_null(s: &str) -> Value {
    if s.is_empty() {
        Value::Null
    } else {
        Value::Str(s.to_string())
    }
}

/// The `repro train --json` run summary printed on stdout.
fn train_summary(tr: &Trainer, out: &TrainOutcome, backend: &str) -> String {
    let snap = tr.tracker.snapshot();
    let mut per = BTreeMap::new();
    for (t, s) in &snap.per_type {
        per.insert(t.clone(), gns_triple(s));
    }
    let mut gns = BTreeMap::new();
    gns.insert("per_type".to_string(), Value::Obj(per));
    gns.insert("total".to_string(), gns_triple(&snap.total));

    let mut m = BTreeMap::new();
    m.insert("model".to_string(), Value::Str(tr.cfg.model.clone()));
    m.insert("backend".to_string(), Value::Str(backend.to_string()));
    m.insert("step".to_string(), Value::Num(tr.runner.step as f64));
    m.insert("total_steps".to_string(), Value::Num(tr.cfg.steps as f64));
    m.insert("tokens".to_string(), Value::Num(out.tokens as f64));
    m.insert("final_loss".to_string(), Value::finite_or_null(out.final_loss));
    m.insert("gns".to_string(), Value::Obj(gns));
    m.insert("checkpoint_dir".to_string(), str_or_null(&tr.cfg.checkpoint_dir));
    m.insert("metrics_path".to_string(), str_or_null(&tr.cfg.metrics_path));
    Value::Obj(m).to_string()
}

/// CSV artifacts a figure writes under `results/` (empty for the
/// stdout-only figures/tables). Used by `repro figures --json`.
fn fig_outputs(n: u32) -> &'static [&'static str] {
    match n {
        2 => &["results/fig2_stderr.csv"],
        3 => &["results/fig3_flops.csv"],
        4 => &["results/fig4_io.csv"],
        5 => &["results/fig5_phase.csv"],
        6 => &["results/fig6_temperature.csv"],
        7 => &["results/fig7_run.csv", "results/fig7_regression.csv"],
        9 => &["results/fig9_schedule.csv"],
        10 => &["results/fig10_sweep.csv"],
        11 | 12 => &["results/fig12_teacher_student.csv"],
        13 => &["results/fig13_cosine.csv"],
        14 => &["results/fig14_phase_linear.csv"],
        15 => &["results/fig15_schedule.csv"],
        16 => &["results/fig16_ddp_vs_perex.csv"],
        _ => &[],
    }
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = TrainArgs::parse(argv)?;
    if a.help {
        print!("{}", cli::TRAIN_USAGE);
        return Ok(());
    }
    let json = a.json;
    // With --json, stdout carries exactly one JSON document; the human
    // progress lines move to stderr.
    let say: Box<dyn Fn(String)> = if json {
        Box::new(|s| eprintln!("{s}"))
    } else {
        Box::new(|s| println!("{s}"))
    };
    let cfg = build_train_config(&a)?;
    let factory = make_variant_factory(&a.backend, &cfg)?;
    let mut tr = build_trainer(factory.as_ref(), cfg, say.as_ref())?;
    let out = tr.run()?;
    if let Some(line) = final_line(&out) {
        say(line);
    }
    if json {
        println!("{}", train_summary(&tr, &out, &a.backend));
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = ServeArgs::parse(argv)?;
    if a.train.help {
        print!("{}", cli::SERVE_USAGE);
        return Ok(());
    }
    let mut cfg = build_train_config(&a.train)?;
    if let Some(p) = a.port {
        cfg.serve.port = p;
    }
    if let Some(b) = &a.bind {
        cfg.serve.bind = b.clone();
    }
    if let Some(rc) = a.ring_capacity {
        cfg.serve.ring_capacity = rc;
    }
    let serve_cfg = cfg.serve.clone();
    let factory = make_variant_factory(&a.train.backend, &cfg)?;
    let say: Box<dyn Fn(String)> = Box::new(|s| println!("{s}"));
    let mut tr = build_trainer(factory.as_ref(), cfg, say.as_ref())?;

    let hub = Arc::new(TelemetryHub::new(
        serve::hub_meta(&tr, std::path::Path::new(".")),
        serve_cfg.ring_capacity,
    ));
    let server = Server::bind(&serve_cfg.bind, serve_cfg.port, Arc::clone(&hub))?;
    let addr = server.local_addr()?;
    println!("serving telemetry on http://{addr} (POST /shutdown to stop)");
    let server_thread = std::thread::Builder::new()
        .name("serve-accept".to_string())
        .spawn(move || server.serve())?;

    // The trainer keeps the main thread; the hub is marked terminal no
    // matter how the run ends.
    let result = serve::train_and_publish(&mut tr, &hub);
    match &result {
        Err(_) => {
            // A failed run must not leave a zombie daemon: flip the
            // shutdown flag (the state is already Failed) so the accept
            // loop unwinds and join() below returns.
            hub.request_shutdown();
        }
        Ok(_) if !hub.shutdown_requested() => {
            println!("run finished; telemetry stays up until POST /shutdown");
        }
        Ok(_) => {}
    }
    match server_thread.join() {
        Ok(r) => r?,
        Err(_) => bail!("telemetry server thread panicked"),
    }
    let out = result?;
    if let Some(line) = final_line(&out) {
        println!("{line}");
    }
    Ok(())
}

fn cmd_figures(argv: &[String]) -> Result<()> {
    let a = FiguresArgs::parse(argv)?;
    if a.help {
        print!("{}", cli::FIGURES_USAGE);
        return Ok(());
    }
    let factory = make_factory(&a.backend, &a.artifacts)?;
    let f = factory.as_ref();
    let run_fig = |n: u32| -> Result<()> {
        match n {
            2 => figures::simulation::fig2(4096, 8),
            3 => figures::costs::fig3(),
            4 => figures::costs::fig4(),
            5 => figures::training::fig5(f, &a.model, a.steps, false),
            6 => figures::training::fig6(f, &a.model, a.steps),
            7 => figures::training::fig7(f, &a.model, a.steps),
            8 => {
                println!("Fig. 8 is the LayerNorm kernel timing benchmark:");
                println!("  cargo bench --features pjrt --bench ln_kernel");
                Ok(())
            }
            9 => figures::training::fig9(f, &a.model, a.steps, a.seeds),
            10 => figures::training::fig10(f, a.steps),
            11 | 12 | 13 => fig_instability(n, &a.artifacts, a.steps),
            14 => figures::training::fig5(f, &a.model, a.steps, true),
            15 => figures::training::fig15(f, &a.model, a.steps),
            16 => figures::training::fig16(f, &a.model, a.steps, a.ranks),
            _ => bail!("unknown figure {n} (2..16)"),
        }
    };
    let run_table = |n: u32| -> Result<()> {
        match n {
            1 => figures::costs::table1(),
            2 => figures::costs::table2(),
            _ => bail!("unknown table {n} (1..2)"),
        }
    };

    // Figure ids that actually ran, for the --json artifact listing.
    let mut ran: Vec<u32> = Vec::new();
    let mut report_outputs: Vec<&'static str> = Vec::new();
    if let Some(r) = &a.report {
        match r.as_str() {
            "predictor" => {
                if a.backend != "reference" {
                    bail!("--report predictor sweeps the norm matrix on the reference backend only");
                }
                figures::predictor::report(&a.model, a.steps)?;
                report_outputs.push(figures::predictor::REPORT_PATH);
            }
            other => bail!("unknown report {other:?} (available: predictor)"),
        }
    } else if a.all {
        for t in 1..=2 {
            run_table(t)?;
            println!();
        }
        for fign in [2u32, 3, 4, 5, 6, 7, 9, 10, 14, 15, 16] {
            run_fig(fign)?;
            ran.push(fign);
            println!();
        }
        // Figs. 12/13 need the teacher-student HLO artifacts; keep
        // --all usable on hermetic builds by skipping, not failing.
        if cfg!(feature = "pjrt") {
            for fign in [12u32, 13] {
                match run_fig(fign) {
                    Ok(()) => ran.push(fign),
                    Err(e) => eprintln!("skipping fig {fign}: {e}"),
                }
                println!();
            }
        }
    } else if let Some(t) = a.table {
        run_table(t)?;
    } else if let Some(n) = a.fig {
        run_fig(n)?;
        ran.push(n);
    }

    if a.json {
        let outputs: Vec<Value> = ran
            .iter()
            .flat_map(|n| fig_outputs(*n).iter().copied())
            .chain(report_outputs.iter().copied())
            .filter(|p| std::path::Path::new(p).exists())
            .map(|p| Value::Str(p.to_string()))
            .collect();
        let mut m = BTreeMap::new();
        m.insert("outputs".to_string(), Value::Arr(outputs));
        // Printed last so `repro figures --json ... | tail -n1` is clean
        // JSON even though figure generators log to stdout.
        let doc = Value::Obj(m).to_string();
        println!("{doc}");
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let a = InfoArgs::parse(argv)?;
    if a.help {
        print!("{}", cli::INFO_USAGE);
        return Ok(());
    }
    let factory = make_factory(&a.backend, &a.artifacts)?;
    if a.json {
        let mut models = Vec::new();
        for name in factory.models() {
            let c = factory.describe(&name)?;
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Value::Str(name.clone()));
            m.insert("d_model".to_string(), Value::Num(c.d_model as f64));
            m.insert("n_layers".to_string(), Value::Num(c.n_layers as f64));
            m.insert("n_heads".to_string(), Value::Num(c.n_heads as f64));
            m.insert("seq_len".to_string(), Value::Num(c.seq_len as f64));
            m.insert("vocab".to_string(), Value::Num(c.vocab as f64));
            m.insert("microbatch".to_string(), Value::Num(c.microbatch as f64));
            m.insert("n_params".to_string(), Value::Num(c.n_params as f64));
            models.push(Value::Obj(m));
        }
        let mut top = BTreeMap::new();
        top.insert("backend".to_string(), Value::Str(a.backend.clone()));
        top.insert("platform".to_string(), Value::Str(factory.platform()));
        top.insert("models".to_string(), Value::Arr(models));
        let doc = Value::Obj(top).to_string();
        println!("{doc}");
    } else {
        println!("backend: {} ({})", a.backend, factory.platform());
        for name in factory.models() {
            let c = factory.describe(&name)?;
            println!(
                "  {name}: d={} L={} heads={} T={} vocab={} microbatch={} params={:.2}M",
                c.d_model,
                c.n_layers,
                c.n_heads,
                c.seq_len,
                c.vocab,
                c.microbatch,
                c.n_params as f64 / 1e6
            );
        }
    }
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let a = InspectArgs::parse(argv)?;
    if a.help {
        print!("{}", cli::INSPECT_USAGE);
        return Ok(());
    }
    let text = cli::inspect::run(&a)?;
    if text.ends_with('\n') {
        print!("{text}");
    } else {
        println!("{text}");
    }
    Ok(())
}

/// Hidden subcommand: the elastic child-process entry point. Connects
/// back to the spawning coordinator and serves rank steps until told to
/// shut down. Never meant for interactive use, but `--help` still works.
fn cmd_rank_worker(argv: &[String]) -> Result<()> {
    let a = RankWorkerArgs::parse(argv)?;
    if a.help {
        print!("{}", cli::RANK_WORKER_USAGE);
        return Ok(());
    }
    nanogns::coordinator::elastic::worker::run_worker(&a.connect, a.worker)
}

fn main() -> Result<()> {
    // Arm (and validate) any NANOGNS_FAULT_PLAN up front: an invalid
    // plan exits 2 here, before a chaos run can silently test nothing,
    // and the "armed" banner lands once at startup instead of at the
    // first fault site.
    let _ = nanogns::util::faultkit::plan();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "help" | "--help" | "-h" => print!("{USAGE}"),
        "train" => cmd_train(rest)?,
        "serve" => cmd_serve(rest)?,
        "figures" => cmd_figures(rest)?,
        "info" => cmd_info(rest)?,
        "inspect" => cmd_inspect(rest)?,
        "rank-worker" => cmd_rank_worker(rest)?,
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
    Ok(())
}
