//! `repro` — nanoGNS-rs launcher.
//!
//! Subcommands:
//! * `train`   — run a training job from a JSON config (or quick flags);
//! * `figures` — regenerate any paper figure/table (see DESIGN.md §4);
//! * `info`    — inspect the available model configs;
//! * `help`.
//!
//! The default backend is the hermetic pure-Rust reference transformer, so
//! the binary works on a bare machine. `--backend pjrt` (with the `pjrt`
//! cargo feature and `make artifacts`) switches to the AOT HLO path.
//! (CLI parsing is hand-rolled: this build is offline, no clap.)

use anyhow::{bail, Result};

use nanogns::config::TrainConfig;
use nanogns::coordinator::Trainer;
use nanogns::figures;
use nanogns::runtime::{BackendFactory, ReferenceFactory};

const USAGE: &str = "\
repro — GNS-instrumented training coordinator (nanoGNS-rs)

USAGE:
  repro train  [--config F.json] [--model NAME] [--steps N] [--seed N] [--metrics F.csv]
               [--ranks N] [--checkpoint-dir DIR] [--checkpoint-every N] [--resume CKPT]
  repro figures (--fig N | --table N | --all) [--model NAME] [--steps N] [--seeds N] [--ranks N]
  repro info
  repro help

GLOBAL:
  --backend NAME    execution backend: reference (default) | pjrt (needs --features pjrt)
  --artifacts DIR   artifact directory for the pjrt backend (default: artifacts)

CHECKPOINT/RESUME:
  --checkpoint-dir DIR   write full-state checkpoints (params, Adam moments, GNS EMAs,
                         controller state, per-rank data cursors) under DIR
  --checkpoint-every N   checkpoint every N optimizer steps (with --checkpoint-dir)
  --resume CKPT          resume from a checkpoint file (e.g. DIR/latest.ckpt); the resumed
                         run replays the uninterrupted trajectory bitwise and finishes the
                         remaining --steps budget

Data-parallel ranks run concurrently; NANOGNS_RANK_WORKERS caps the rank worker
threads (results are bitwise identical for any setting). NANOGNS_THREADS sizes
the per-backend kernel worker pool; NANOGNS_FORCE_SCALAR=1 pins every kernel to
the scalar oracle tier (config keys `threads` / `force_scalar` do the same).

FIGURES: 2..16 map to the paper's figures (8 = `cargo bench --features pjrt --bench ln_kernel`;
11..13 need the pjrt backend), tables 1..2.
";

/// Tiny flag parser: --key value pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = std::collections::HashMap::new();
        let mut switches = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    switches.insert(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?}\n{USAGE}");
            }
        }
        Ok(Self { flags, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.switches.contains(key)
    }
}

#[allow(unused_variables)]
fn make_factory(backend: &str, artifacts: &str) -> Result<Box<dyn BackendFactory>> {
    match backend {
        "reference" => Ok(Box::new(ReferenceFactory)),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(nanogns::runtime::PjrtFactory::new(artifacts)?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            bail!("this binary was built without the `pjrt` feature (cargo build --features pjrt)")
        }
        other => bail!("unknown backend {other:?} (reference|pjrt)\n{USAGE}"),
    }
}

/// Figs. 11–13 run on raw teacher–student artifacts, pjrt only.
#[cfg(feature = "pjrt")]
fn fig_instability(which: u32, artifacts: &str, steps: u64) -> Result<()> {
    let manifest = nanogns::runtime::Manifest::load(artifacts)?;
    let rt = nanogns::runtime::Runtime::cpu()?;
    match which {
        13 => figures::instability::fig13(&rt, &manifest, steps.max(100), 0.35),
        _ => figures::instability::fig12(&rt, &manifest, steps.max(100), 0.35),
    }
}

#[cfg(not(feature = "pjrt"))]
fn fig_instability(_which: u32, _artifacts: &str, _steps: u64) -> Result<()> {
    bail!("figures 11-13 need the teacher-student HLO artifacts: rebuild with --features pjrt")
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let backend = args.get_or("backend", "reference");

    match cmd.as_str() {
        "help" | "--help" | "-h" => print!("{USAGE}"),
        "train" => {
            let factory = make_factory(&backend, &artifacts)?;
            let mut cfg = match args.get("config") {
                Some(path) => TrainConfig::from_file(path)?,
                None => {
                    let mut c = TrainConfig::quickstart(
                        &args.get_or("model", "small"),
                        args.get_num("steps", 50u64)?,
                    );
                    c.seed = args.get_num("seed", 0u64)?;
                    c.metrics_path = args.get_or("metrics", "");
                    c.ranks = args.get_num("ranks", 1usize)?;
                    c
                }
            };
            cfg.artifacts = artifacts.clone();
            // Checkpoint flags always win over the config file.
            if let Some(dir) = args.get("checkpoint-dir") {
                cfg.checkpoint_dir = dir.to_string();
            }
            if let Some(every) = args.get("checkpoint-every") {
                cfg.checkpoint_every = every.parse()?;
            }
            if let Some(r) = args.get("resume") {
                cfg.resume = r.to_string();
            }
            // Kernel knobs must be exported before the first backend is
            // built: the worker-pool size and SIMD tier are read once,
            // lazily, on first use. Explicit env vars still win.
            if cfg.threads > 0 && std::env::var("NANOGNS_THREADS").is_err() {
                std::env::set_var("NANOGNS_THREADS", cfg.threads.to_string());
            }
            if cfg.force_scalar && std::env::var("NANOGNS_FORCE_SCALAR").is_err() {
                std::env::set_var("NANOGNS_FORCE_SCALAR", "1");
            }
            let resume = cfg.resume.clone();
            println!(
                "training {} ({:.2}M params) for {} steps on {}",
                cfg.model,
                factory.describe(&cfg.model)?.n_params as f64 / 1e6,
                cfg.steps,
                factory.platform()
            );
            let mut tr = if resume.is_empty() {
                Trainer::new(factory.as_ref(), cfg)?
            } else {
                let tr = Trainer::resume(factory.as_ref(), cfg, &resume)?;
                println!(
                    "resumed from {resume} at step {} ({} tokens)",
                    tr.runner.step,
                    tr.tokens()
                );
                tr
            };
            if tr.cfg.ranks > 1 {
                println!("ranks: {} on {} rank worker(s)", tr.cfg.ranks, tr.rank_workers());
            }
            let out = tr.run()?;
            if let Some(r) = out.records.last() {
                println!(
                    "final: step {} loss {:.4} gns_total {:.2} gns_ln {:.2} ({} tokens)",
                    r.step, r.loss, r.gns_total, r.gns_layernorm, out.tokens
                );
            }
        }
        "figures" => {
            let factory = make_factory(&backend, &artifacts)?;
            let f = factory.as_ref();
            let model = args.get_or("model", "micro");
            let steps = args.get_num("steps", 60u64)?;
            let seeds = args.get_num("seeds", 3u64)?;
            let ranks = args.get_num("ranks", 4usize)?;
            let run_fig = |n: u32| -> Result<()> {
                match n {
                    2 => figures::simulation::fig2(4096, 8),
                    3 => figures::costs::fig3(),
                    4 => figures::costs::fig4(),
                    5 => figures::training::fig5(f, &model, steps, false),
                    6 => figures::training::fig6(f, &model, steps),
                    7 => figures::training::fig7(f, &model, steps),
                    8 => {
                        println!("Fig. 8 is the LayerNorm kernel timing benchmark:");
                        println!("  cargo bench --features pjrt --bench ln_kernel");
                        Ok(())
                    }
                    9 => figures::training::fig9(f, &model, steps, seeds),
                    10 => figures::training::fig10(f, steps),
                    11 | 12 | 13 => fig_instability(n, &artifacts, steps),
                    14 => figures::training::fig5(f, &model, steps, true),
                    15 => figures::training::fig15(f, &model, steps),
                    16 => figures::training::fig16(f, &model, steps, ranks),
                    _ => bail!("unknown figure {n} (2..16)"),
                }
            };
            let run_table = |n: u32| -> Result<()> {
                match n {
                    1 => figures::costs::table1(),
                    2 => figures::costs::table2(),
                    _ => bail!("unknown table {n} (1..2)"),
                }
            };
            if args.has("all") {
                for t in 1..=2 {
                    run_table(t)?;
                    println!();
                }
                for fign in [2u32, 3, 4, 5, 6, 7, 9, 10, 14, 15, 16] {
                    run_fig(fign)?;
                    println!();
                }
                // Figs. 12/13 need the teacher-student HLO artifacts; keep
                // --all usable on hermetic builds by skipping, not failing.
                if cfg!(feature = "pjrt") {
                    for fign in [12u32, 13] {
                        if let Err(e) = run_fig(fign) {
                            eprintln!("skipping fig {fign}: {e}");
                        }
                        println!();
                    }
                }
            } else if let Some(t) = args.get("table") {
                run_table(t.parse()?)?;
            } else if let Some(fign) = args.get("fig") {
                run_fig(fign.parse()?)?;
            } else {
                bail!("pass --fig N, --table N, or --all\n{USAGE}");
            }
        }
        "info" => {
            let factory = make_factory(&backend, &artifacts)?;
            println!("backend: {} ({})", backend, factory.platform());
            for name in factory.models() {
                let c = factory.describe(&name)?;
                println!(
                    "  {name}: d={} L={} heads={} T={} vocab={} microbatch={} params={:.2}M",
                    c.d_model,
                    c.n_layers,
                    c.n_heads,
                    c.seq_len,
                    c.vocab,
                    c.microbatch,
                    c.n_params as f64 / 1e6
                );
            }
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
    Ok(())
}
