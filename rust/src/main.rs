//! `repro` — nanoGNS-rs launcher.
//!
//! Subcommands:
//! * `train`   — run a training job from a JSON config (or quick flags);
//! * `figures` — regenerate any paper figure/table (see DESIGN.md §4);
//! * `bench`   — run the in-tree benchmark suites (ln-kernel, train-step);
//! * `info`    — inspect the artifact manifest.
//!
//! The binary is self-contained once `make artifacts` has produced the
//! AOT-compiled HLO artifacts; Python is never invoked from here.
//! (CLI parsing is hand-rolled: this build is offline, no clap.)

use anyhow::{bail, Result};

use nanogns::config::TrainConfig;
use nanogns::coordinator::Trainer;
use nanogns::figures;
use nanogns::runtime::{Manifest, Runtime};

const USAGE: &str = "\
repro — GNS-instrumented training coordinator (nanoGNS-rs)

USAGE:
  repro train  [--config F.json] [--model NAME] [--steps N] [--seed N] [--metrics F.csv]
  repro figures (--fig N | --table N | --all) [--model NAME] [--steps N] [--seeds N] [--ranks N]
  repro info
  repro help

GLOBAL:
  --artifacts DIR   artifact directory (default: artifacts)

FIGURES: 2..16 map to the paper's figures (8 = `repro bench ln`), tables 1..2.
";

/// Tiny flag parser: --key value pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = std::collections::HashMap::new();
        let mut switches = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    switches.insert(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?}\n{USAGE}");
            }
        }
        Ok(Self { flags, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.switches.contains(key)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    let artifacts = args.get_or("artifacts", "artifacts");

    match cmd.as_str() {
        "help" | "--help" | "-h" => print!("{USAGE}"),
        "train" => {
            let manifest = Manifest::load(&artifacts)?;
            let rt = Runtime::cpu()?;
            let mut cfg = match args.get("config") {
                Some(path) => TrainConfig::from_file(path)?,
                None => {
                    let mut c = TrainConfig::quickstart(
                        &args.get_or("model", "small"),
                        args.get_num("steps", 50u64)?,
                    );
                    c.seed = args.get_num("seed", 0u64)?;
                    c.metrics_path = args.get_or("metrics", "");
                    c
                }
            };
            cfg.artifacts = artifacts.clone();
            println!(
                "training {} ({:.2}M params) for {} steps on {}",
                cfg.model,
                manifest.config(&cfg.model)?.n_params as f64 / 1e6,
                cfg.steps,
                rt.platform()
            );
            let mut tr = Trainer::new(&rt, &manifest, cfg)?;
            let out = tr.run()?;
            if let Some(r) = out.records.last() {
                println!(
                    "final: step {} loss {:.4} gns_total {:.2} gns_ln {:.2} ({} tokens)",
                    r.step, r.loss, r.gns_total, r.gns_layernorm, out.tokens
                );
            }
        }
        "figures" => {
            let manifest = Manifest::load(&artifacts)?;
            let rt = Runtime::cpu()?;
            let model = args.get_or("model", "micro");
            let steps = args.get_num("steps", 60u64)?;
            let seeds = args.get_num("seeds", 3u64)?;
            let ranks = args.get_num("ranks", 4usize)?;
            let run_fig = |n: u32| -> Result<()> {
                match n {
                    2 => figures::simulation::fig2(4096, 8),
                    3 => figures::costs::fig3(),
                    4 => figures::costs::fig4(),
                    5 => figures::training::fig5(&rt, &manifest, &model, steps, false),
                    6 => figures::training::fig6(&rt, &manifest, &model, steps),
                    7 => figures::training::fig7(&rt, &manifest, &model, steps),
                    8 => {
                        println!("Fig. 8 is the LayerNorm kernel timing benchmark:");
                        println!("  cargo bench --bench ln_kernel   (or: repro bench --suite ln)");
                        Ok(())
                    }
                    9 => figures::training::fig9(&rt, &manifest, &model, steps, seeds),
                    10 => figures::training::fig10(&rt, &manifest, steps),
                    11 | 12 => figures::instability::fig12(&rt, &manifest, steps.max(100), 0.35),
                    13 => figures::instability::fig13(&rt, &manifest, steps.max(100), 0.35),
                    14 => figures::training::fig5(&rt, &manifest, &model, steps, true),
                    15 => figures::training::fig15(&rt, &manifest, &model, steps),
                    16 => figures::training::fig16(&rt, &manifest, &model, steps, ranks),
                    _ => bail!("unknown figure {n} (2..16)"),
                }
            };
            let run_table = |n: u32| -> Result<()> {
                match n {
                    1 => figures::costs::table1(),
                    2 => figures::costs::table2(),
                    _ => bail!("unknown table {n} (1..2)"),
                }
            };
            if args.has("all") {
                for t in 1..=2 {
                    run_table(t)?;
                    println!();
                }
                for f in [2u32, 3, 4, 5, 6, 7, 9, 10, 12, 13, 14, 15, 16] {
                    run_fig(f)?;
                    println!();
                }
            } else if let Some(t) = args.get("table") {
                run_table(t.parse()?)?;
            } else if let Some(f) = args.get("fig") {
                run_fig(f.parse()?)?;
            } else {
                bail!("pass --fig N, --table N, or --all\n{USAGE}");
            }
        }
        "info" => {
            let manifest = Manifest::load(&artifacts)?;
            println!("manifest schema v{}", manifest.schema_version);
            let mut names: Vec<_> = manifest.configs.keys().collect();
            names.sort();
            for name in names {
                let c = &manifest.configs[name];
                println!(
                    "  {name}: d={} L={} heads={} T={} vocab={} microbatch={} params={:.2}M",
                    c.d_model, c.n_layers, c.n_heads, c.seq_len, c.vocab, c.microbatch,
                    c.n_params as f64 / 1e6
                );
            }
            println!(
                "  ln_bench sizes: {:?}",
                manifest.ln_bench.iter().map(|e| e.k).collect::<Vec<_>>()
            );
            println!("  instability artifacts: {}", manifest.instability.is_some());
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
    Ok(())
}
