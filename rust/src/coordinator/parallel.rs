//! Rank-parallel execution engine: genuinely concurrent data-parallel
//! ranks with a deterministic, worker-count-invariant reduction.
//!
//! [`ParallelExecutor`] owns one [`Backend`] instance per worker thread
//! (created through [`BackendFactory::create_for_rank`], so a device
//! factory can map workers onto devices). One [`ParallelExecutor::rank_step`]
//! call runs every rank's gradient-accumulation loop:
//!
//! * ranks are split into contiguous blocks, one block per worker, and the
//!   blocks execute concurrently on scoped threads (the calling thread
//!   runs block 0) — the same layout discipline as
//!   [`crate::runtime::kernels::threads`];
//! * each rank folds its `accum` microbatches left-to-right into a
//!   rank-local gradient accumulator and a rank-local
//!   [`GnsAccumulator`], exactly as the old sequential loop did within a
//!   rank;
//! * per-rank partials are then merged on the calling thread with a
//!   **fixed-order binary tree reduction** over the rank index —
//!   `(r0+r1) + (r2+r3), …` round by round, an odd tail passing through
//!   unchanged — for gradients, stats, and loss alike.
//!
//! Because every rank's work depends only on (params, its loader stream)
//! and the merge order depends only on the rank count, the result is
//! **bitwise identical for any worker count**, including the fully
//! sequential `workers = 1` execution. `NANOGNS_RANK_WORKERS` overrides
//! the worker count (see [`rank_workers`]); the CI determinism matrix
//! re-proves the invariance contract across thread/worker combinations.

use std::sync::Mutex;

use anyhow::{anyhow, bail, ensure, Result};

use crate::data::Loader;
use crate::gns::GnsAccumulator;
use crate::runtime::kernels::default_workers;
use crate::runtime::{Backend, BackendFactory, Buffer, ModelEntry};
use crate::N_TYPES;

/// Rank-worker count from the environment (`NANOGNS_RANK_WORKERS`,
/// clamped to `[1, ranks]`) or a machine-derived default that leaves the
/// intra-op kernel threads their cores: `available / intra_op_workers`,
/// clamped to `[1, ranks]`.
pub fn rank_workers(ranks: usize) -> usize {
    let ranks = ranks.max(1);
    if let Ok(v) = std::env::var("NANOGNS_RANK_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, ranks);
        }
    }
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (avail / default_workers().max(1)).clamp(1, ranks)
}

/// Merged output of one rank-parallel accumulation pass.
pub struct RankStepOut {
    /// Tree-merged gradient **sum** over all `ranks * accum` microbatches
    /// (the caller applies the `1/n_micro` mean scale, as before).
    pub grads: Vec<Buffer>,
    /// Merged per-example stats over every microbatch of every rank.
    pub stats: GnsAccumulator,
    /// Sum of per-microbatch losses (mean-per-token each).
    pub loss_sum: f64,
    /// Total microbatches executed (`ranks * accum`).
    pub n_micro: usize,
    /// Per-rank raw `sum ||grad||^2` of each rank's *unscaled* gradient
    /// sum, in rank order — only when requested (the DDP estimator's
    /// per-rank observation; `None` otherwise to skip the extra pass).
    pub rank_sqnorms: Option<Vec<[f64; N_TYPES]>>,
}

/// One rank's partial result before the tree reduction. Shared with the
/// process-isolated engine (`coordinator::elastic`), which rebuilds these
/// from wire partials and must reduce them through the *same* code path
/// to keep thread mode and process mode bitwise interchangeable.
pub(crate) struct RankPartial {
    pub(crate) grads: Vec<Buffer>,
    pub(crate) stats: GnsAccumulator,
    pub(crate) loss: f64,
    pub(crate) n_micro: usize,
    pub(crate) sqnorms: Option<[f64; N_TYPES]>,
}

/// Fixed-order binary tree reduction over the rank index: pairwise
/// rounds, odd tail passes through. Depends only on the number of
/// partials (the rank count), never on worker layout or process
/// placement — the bitwise-determinism keystone both engines share.
/// `recycle` receives each consumed right-hand gradient set.
pub(crate) fn tree_reduce(
    be: &dyn Backend,
    mut partials: Vec<RankPartial>,
    mut recycle: impl FnMut(Vec<Buffer>),
) -> Result<RankPartial> {
    ensure!(!partials.is_empty(), "tree_reduce needs at least one partial");
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(2));
        let mut it = partials.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                a.grads = be.accumulate(a.grads, &b.grads)?;
                recycle(b.grads);
                a.stats.merge(&b.stats);
                a.loss += b.loss;
                a.n_micro += b.n_micro;
            }
            next.push(a);
        }
        partials = next;
    }
    Ok(partials.pop().expect("non-empty rank set"))
}

/// Owns per-worker backend instances and runs rank loops concurrently.
pub struct ParallelExecutor {
    backends: Vec<Box<dyn Backend>>,
    entry: ModelEntry,
    workers: usize,
    /// Reusable gradient buffer sets shared by all workers (leasing is
    /// order-nondeterministic, but leased sets are re-zeroed, so reuse
    /// never changes results — same contract as the runner's arena).
    arena: Mutex<Vec<Vec<Buffer>>>,
    arena_cap: usize,
}

impl ParallelExecutor {
    /// Engine with `rank_workers(ranks)` workers (env-tunable default).
    pub fn new(factory: &dyn BackendFactory, model: &str, ranks: usize) -> Result<Self> {
        Self::with_workers(factory, model, ranks, rank_workers(ranks))
    }

    /// Engine with an explicit worker count (clamped to `[1, ranks]`).
    pub fn with_workers(
        factory: &dyn BackendFactory,
        model: &str,
        ranks: usize,
        workers: usize,
    ) -> Result<Self> {
        let ranks = ranks.max(1);
        let workers = workers.clamp(1, ranks);
        let backends: Vec<Box<dyn Backend>> = (0..workers)
            .map(|w| factory.create_for_rank(model, w))
            .collect::<Result<_>>()?;
        ensure!(!backends.is_empty(), "no worker backends created");
        let entry = backends[0].entry().clone();
        let arena_cap = 2 * ranks + 2;
        Ok(Self { backends, entry, workers, arena: Mutex::new(Vec::new()), arena_cap })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// The primary worker backend (artifact calls that need no rank
    /// parallelism: `grad_sqnorms`, `eval`, merges).
    pub fn backend(&self) -> &dyn Backend {
        self.backends[0].as_ref()
    }

    /// Zero gradient set from the shared arena (re-zeroed in place) or a
    /// fresh backend allocation.
    fn lease_zero(&self, be: &dyn Backend) -> Result<Vec<Buffer>> {
        let reused = self.arena.lock().ok().and_then(|mut pool| pool.pop());
        match reused {
            Some(mut set) => {
                for b in set.iter_mut() {
                    match b {
                        Buffer::Host(t) => t.data.fill(0.0),
                        #[cfg(feature = "pjrt")]
                        Buffer::Pjrt(_) => {}
                    }
                }
                Ok(set)
            }
            None => be.zero_grads(),
        }
    }

    /// Return a no-longer-needed gradient set for reuse. Only
    /// host-resident sets matching this model's shapes are pooled.
    pub fn recycle(&self, grads: Vec<Buffer>) {
        let matches_model = grads.len() == self.entry.params.len()
            && grads.iter().zip(&self.entry.params).all(|(b, spec)| match b {
                Buffer::Host(t) => t.shape == spec.shape,
                #[cfg(feature = "pjrt")]
                Buffer::Pjrt(_) => false,
            });
        if !matches_model {
            return;
        }
        if let Ok(mut pool) = self.arena.lock() {
            if pool.len() < self.arena_cap {
                pool.push(grads);
            }
        }
    }

    /// One rank's accumulation loop (runs on whichever worker owns it).
    fn run_rank(
        &self,
        be: &dyn Backend,
        params: &[Buffer],
        loader: &mut Loader,
        accum: usize,
        collect_rank_norms: bool,
    ) -> Result<RankPartial> {
        let mb = self.entry.microbatch;
        let mut acc = self.lease_zero(be)?;
        let mut stats = GnsAccumulator::new(N_TYPES, mb);
        let mut loss = 0f64;
        for _ in 0..accum {
            let batch = loader.next_batch(mb);
            let out = be.grad_step(params, &batch)?;
            stats.add_microbatch(&out.stats);
            acc = be.accumulate(acc, &out.grads)?;
            self.recycle(out.grads);
            loss += out.loss as f64;
        }
        let sqnorms = if collect_rank_norms { Some(be.grad_sqnorms(&acc)?) } else { None };
        Ok(RankPartial { grads: acc, stats, loss, n_micro: accum, sqnorms })
    }

    /// Run `accum` microbatches on each of `loaders.len()` ranks — rank
    /// `r` consuming `loaders[r]` — and merge the per-rank partials with
    /// the fixed-order tree reduction. Bitwise identical for any worker
    /// count; `collect_rank_norms` additionally returns each rank's
    /// pre-merge gradient squared norms (the DDP observation).
    pub fn rank_step(
        &self,
        params: &[Buffer],
        loaders: &mut [Loader],
        accum: usize,
        collect_rank_norms: bool,
    ) -> Result<RankStepOut> {
        let ranks = loaders.len();
        ensure!(ranks > 0, "rank_step needs at least one rank loader");
        ensure!(accum > 0, "rank_step needs accum >= 1");

        let workers = self.workers.min(ranks);
        let per = ranks.div_ceil(workers);
        let mut slots: Vec<Option<Result<RankPartial>>> = (0..ranks).map(|_| None).collect();

        std::thread::scope(|s| {
            let mut rest_slots = &mut slots[..];
            let mut rest_loaders = loaders;
            // Carve off block 0 for the calling thread, spawn the rest.
            let (first_slots, tail) = std::mem::take(&mut rest_slots).split_at_mut(per.min(ranks));
            rest_slots = tail;
            let (first_loaders, tail) =
                std::mem::take(&mut rest_loaders).split_at_mut(per.min(ranks));
            rest_loaders = tail;
            let mut start = per.min(ranks);
            let mut block = 1usize;
            while start < ranks {
                let end = (start + per).min(ranks);
                let n = end - start;
                let (bs, ts) = std::mem::take(&mut rest_slots).split_at_mut(n);
                let (bl, tl) = std::mem::take(&mut rest_loaders).split_at_mut(n);
                rest_slots = ts;
                rest_loaders = tl;
                let be = self.backends[block].as_ref();
                s.spawn(move || {
                    for (slot, loader) in bs.iter_mut().zip(bl.iter_mut()) {
                        let r = self.run_rank(be, params, loader, accum, collect_rank_norms);
                        let failed = r.is_err();
                        *slot = Some(r);
                        if failed {
                            break;
                        }
                    }
                });
                start = end;
                block += 1;
            }
            let be = self.backends[0].as_ref();
            for (slot, loader) in first_slots.iter_mut().zip(first_loaders.iter_mut()) {
                let r = self.run_rank(be, params, loader, accum, collect_rank_norms);
                let failed = r.is_err();
                *slot = Some(r);
                if failed {
                    break;
                }
            }
        });

        // Surface the first failure in rank order (later ranks in the same
        // block were skipped after an error).
        let mut partials: Vec<RankPartial> = Vec::with_capacity(ranks);
        let mut failure: Option<anyhow::Error> = None;
        for (rank, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(p)) => partials.push(p),
                Some(Err(e)) => {
                    if failure.is_none() {
                        failure = Some(anyhow!("rank {rank} failed: {e}"));
                    }
                }
                None => {
                    if failure.is_none() {
                        failure = Some(anyhow!("rank {rank} never executed"));
                    }
                }
            }
        }
        if let Some(e) = failure {
            for p in partials {
                self.recycle(p.grads);
            }
            bail!(e);
        }

        let rank_sqnorms: Option<Vec<[f64; N_TYPES]>> = collect_rank_norms
            .then(|| partials.iter().map(|p| p.sqnorms.unwrap_or([f64::NAN; N_TYPES])).collect());

        // Fixed-order tree reduction, shared with the elastic engine.
        let be = self.backends[0].as_ref();
        let root = tree_reduce(be, partials, |g| self.recycle(g))?;
        Ok(RankStepOut {
            grads: root.grads,
            stats: root.stats,
            loss_sum: root.loss,
            n_micro: root.n_micro,
            rank_sqnorms,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusGenerator;
    use crate::runtime::ReferenceFactory;

    fn loaders_for(entry: &ModelEntry, ranks: usize, seed: u64) -> Vec<Loader> {
        let text = CorpusGenerator::new(seed).generate(1 << 16);
        let base = Loader::new(&text, entry.seq_len, seed);
        (0..ranks as u64).map(|r| base.for_rank(r)).collect()
    }

    #[test]
    fn rank_workers_is_clamped() {
        assert_eq!(rank_workers(1), 1);
        assert!(rank_workers(4) >= 1 && rank_workers(4) <= 4);
    }

    #[test]
    fn rank_step_counts_and_shapes() {
        let ex = ParallelExecutor::with_workers(&ReferenceFactory, "nano", 3, 2).unwrap();
        let be = ReferenceFactory.create("nano").unwrap();
        let params = be.init(0).unwrap();
        let mut loaders = loaders_for(ex.entry(), 3, 0);
        let out = ex.rank_step(&params, &mut loaders, 2, true).unwrap();
        assert_eq!(out.n_micro, 6);
        assert_eq!(out.stats.n_examples(), 6 * ex.entry().microbatch);
        assert_eq!(out.grads.len(), ex.entry().params.len());
        assert_eq!(out.rank_sqnorms.as_ref().unwrap().len(), 3);
        assert!(out.loss_sum.is_finite());
    }

    /// The engine-level invariance contract: identical outputs for any
    /// worker count, including per-rank norms (integration tests extend
    /// this through the Trainer and the DDP estimator).
    #[test]
    fn rank_step_is_bitwise_worker_invariant() {
        let ranks = 5; // odd: exercises the tree's pass-through tail
        let be = ReferenceFactory.create("nano").unwrap();
        let params = be.init(1).unwrap();
        let mut want: Option<(Vec<Vec<f32>>, Vec<f64>, u64)> = None;
        for workers in [1usize, 2, 3, 5] {
            let ex =
                ParallelExecutor::with_workers(&ReferenceFactory, "nano", ranks, workers).unwrap();
            let mut loaders = loaders_for(ex.entry(), ranks, 1);
            let out = ex.rank_step(&params, &mut loaders, 2, false).unwrap();
            let grads: Vec<Vec<f32>> =
                out.grads.iter().map(|b| b.to_tensor().unwrap().data).collect();
            let (small, _) = out.stats.finish();
            let loss_bits = out.loss_sum.to_bits();
            match &want {
                None => want = Some((grads, small, loss_bits)),
                Some((wg, ws, wl)) => {
                    assert_eq!(&grads, wg, "workers={workers}: gradient drift");
                    for (a, b) in small.iter().zip(ws) {
                        assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}: stats drift");
                    }
                    assert_eq!(loss_bits, *wl, "workers={workers}: loss drift");
                }
            }
        }
    }

    #[test]
    fn rejects_empty_ranks_and_zero_accum() {
        let ex = ParallelExecutor::with_workers(&ReferenceFactory, "nano", 2, 1).unwrap();
        let be = ReferenceFactory.create("nano").unwrap();
        let params = be.init(0).unwrap();
        assert!(ex.rank_step(&params, &mut [], 1, false).is_err());
        let mut loaders = loaders_for(ex.entry(), 1, 0);
        assert!(ex.rank_step(&params, &mut loaders, 0, false).is_err());
    }
}
