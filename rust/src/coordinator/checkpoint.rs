//! Binary checkpoints: params-only snapshots (v1) and full training
//! state for interrupt/resume (v2).
//!
//! **v1** (`NANOGNS1`): magic, param count, then per param (name-len,
//! name, rank, dims..., f32 data). Kept for params-only export/import.
//!
//! **v2** (`NGNSCKP2`): magic, u32 header length, a JSON header manifest
//! (via [`crate::util::json`]), then the raw f32 payload of every listed
//! tensor (params, Adam m, Adam v — in manifest order). The header
//! carries everything else a [`super::Trainer`] mutates: step/token
//! counters, GNS tracker EMAs, batch-size controller hysteresis, LR
//! scale, and per-rank loader cursors. All f64/u64 header scalars are
//! encoded as exact strings (`0x…` bit patterns for floats, decimal for
//! integers) so a resumed run replays a **bitwise-identical** trajectory
//! — JSON numbers would round u64 RNG words through f64 and silently
//! fork the data stream. Little-endian throughout.
//!
//! Publication is crash-safe (`.tmp` → fsync → rename → parent-dir
//! fsync), and [`CkptWriter`] moves the disk work off the training
//! thread: the trainer serializes into an idle buffer ([`encode_state`])
//! and hands it to a double-buffered writer thread.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::gns::{EmaParts, TrackerState};
use crate::runtime::tensor::Tensor;
use crate::runtime::{Buffer, ModelEntry};
use crate::util::json::Value;
use crate::util::rng::RngState;

const MAGIC: &[u8; 8] = b"NANOGNS1";
const MAGIC_V2: &[u8; 8] = b"NGNSCKP2";
const VERSION_V2: u64 = 2;
/// Sanity bound on the v2 header: a few KiB in practice.
const MAX_HEADER_BYTES: usize = 1 << 24;

pub fn save(path: impl AsRef<Path>, entry: &ModelEntry, params: &[Buffer]) -> Result<()> {
    ensure!(params.len() == entry.params.len(), "param count mismatch");
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(params.len() as u32).to_le_bytes())?;
    for (spec, buf) in entry.params.iter().zip(params) {
        let t = buf.to_tensor()?;
        ensure!(t.shape == spec.shape, "{}: shape drift", spec.name);
        let name = spec.name.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        for v in &t.data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>, entry: &ModelEntry) -> Result<Vec<Buffer>> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "bad checkpoint magic");
    let mut buf4 = [0u8; 4];
    r.read_exact(&mut buf4)?;
    let n = u32::from_le_bytes(buf4) as usize;
    ensure!(n == entry.params.len(), "checkpoint has {n} params, manifest {}", entry.params.len());
    let mut out = Vec::with_capacity(n);
    for spec in &entry.params {
        r.read_exact(&mut buf4)?;
        let name_len = u32::from_le_bytes(buf4) as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        ensure!(
            name == spec.name.as_bytes(),
            "checkpoint param {:?} != manifest {:?}",
            String::from_utf8_lossy(&name),
            spec.name
        );
        r.read_exact(&mut buf4)?;
        let rank = u32::from_le_bytes(buf4) as usize;
        let mut shape = Vec::with_capacity(rank);
        let mut buf8 = [0u8; 8];
        for _ in 0..rank {
            r.read_exact(&mut buf8)?;
            shape.push(u64::from_le_bytes(buf8) as usize);
        }
        ensure!(shape == spec.shape, "{}: checkpoint shape {:?}", spec.name, shape);
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        for v in &mut data {
            r.read_exact(&mut buf4)?;
            *v = f32::from_le_bytes(buf4);
        }
        out.push(Buffer::from_tensor(Tensor::new(shape, data)?));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// v2: full training state
// ---------------------------------------------------------------------------

/// Everything a [`super::Trainer`] needs to resume a run bitwise-exactly.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub model: String,
    /// Run seed: the corpus and loader streams derive from it, so a
    /// resume under a different seed would silently fork the data.
    pub seed: u64,
    /// Corpus size the loaders were built over (same divergence hazard).
    pub corpus_bytes: u64,
    pub step: u64,
    pub tokens: u64,
    pub lr_scale: f64,
    /// Batch-size controller hysteresis anchor.
    pub controller_last: usize,
    pub tracker: TrackerState,
    /// Per-rank loader cursors, rank order.
    pub loaders: Vec<RngState>,
    pub params: Vec<Buffer>,
    pub m: Vec<Buffer>,
    pub v: Vec<Buffer>,
}

/// Borrowed view of everything [`save_state`] serializes: the saving side
/// hands in its live buffers directly, so a checkpoint never clones the
/// three model-sized tensor sets.
pub struct TrainStateView<'a> {
    pub model: &'a str,
    pub seed: u64,
    pub corpus_bytes: u64,
    pub step: u64,
    pub tokens: u64,
    pub lr_scale: f64,
    pub controller_last: usize,
    pub tracker: TrackerState,
    pub loaders: Vec<RngState>,
    pub params: &'a [Buffer],
    pub m: &'a [Buffer],
    pub v: &'a [Buffer],
}

/// Exact f64 encoding: the IEEE-754 bit pattern as a hex string. Survives
/// NaN/-0.0/subnormals, which `{}`-formatted JSON numbers cannot.
fn f64_hex(x: f64) -> Value {
    Value::Str(format!("0x{:016x}", x.to_bits()))
}

fn parse_f64_hex(v: &Value) -> Result<f64> {
    let s = v.as_str()?;
    let hex = s.strip_prefix("0x").ok_or_else(|| anyhow!("bad f64 bits {s:?}"))?;
    Ok(f64::from_bits(u64::from_str_radix(hex, 16).context("bad f64 bits")?))
}

/// Exact u64 encoding as a decimal string (JSON numbers are f64: RNG
/// words would lose bits).
fn u64_str(x: u64) -> Value {
    Value::Str(x.to_string())
}

fn parse_u64_str(v: &Value) -> Result<u64> {
    v.as_str()?.parse::<u64>().context("bad u64 string")
}

fn ema_to_json(p: &EmaParts) -> Value {
    let mut m = std::collections::BTreeMap::new();
    m.insert("alpha".into(), f64_hex(p.alpha));
    m.insert("state".into(), p.state.map(f64_hex).unwrap_or(Value::Null));
    m.insert("t".into(), u64_str(p.t));
    m.insert("bias_correct".into(), Value::Bool(p.bias_correct));
    Value::Obj(m)
}

fn ema_from_json(v: &Value) -> Result<EmaParts> {
    let state = match v.get("state")? {
        Value::Null => None,
        other => Some(parse_f64_hex(other)?),
    };
    Ok(EmaParts {
        alpha: parse_f64_hex(v.get("alpha")?)?,
        state,
        t: parse_u64_str(v.get("t")?)?,
        bias_correct: v.get("bias_correct")?.as_bool()?,
    })
}

fn ema_vec_from_json(v: &Value) -> Result<Vec<EmaParts>> {
    v.as_arr()?.iter().map(ema_from_json).collect()
}

fn rng_to_json(st: &RngState) -> Value {
    let mut m = std::collections::BTreeMap::new();
    m.insert("s".into(), Value::Arr(st.s.iter().map(|&w| u64_str(w)).collect()));
    m.insert("spare".into(), st.spare.map(f64_hex).unwrap_or(Value::Null));
    Value::Obj(m)
}

fn rng_from_json(v: &Value) -> Result<RngState> {
    let words = v.get("s")?.as_arr()?;
    ensure!(words.len() == 4, "loader cursor needs 4 RNG words");
    let mut s = [0u64; 4];
    for (d, w) in s.iter_mut().zip(words) {
        *d = parse_u64_str(w)?;
    }
    let spare = match v.get("spare")? {
        Value::Null => None,
        other => Some(parse_f64_hex(other)?),
    };
    Ok(RngState { s, spare })
}

/// The `(group, tensors)` triplets a v2 checkpoint carries, in payload
/// order.
fn groups<'a>(st: &TrainStateView<'a>) -> [(&'static str, &'a [Buffer]); 3] {
    [("params", st.params), ("m", st.m), ("v", st.v)]
}

fn header_json(st: &TrainStateView<'_>, entry: &ModelEntry) -> Result<Value> {
    let mut top = std::collections::BTreeMap::new();
    top.insert("version".into(), Value::Num(VERSION_V2 as f64));
    top.insert("model".into(), Value::Str(st.model.to_string()));
    top.insert("seed".into(), u64_str(st.seed));
    top.insert("corpus_bytes".into(), u64_str(st.corpus_bytes));
    top.insert("step".into(), u64_str(st.step));
    top.insert("tokens".into(), u64_str(st.tokens));
    top.insert("lr_scale".into(), f64_hex(st.lr_scale));
    top.insert("controller_last".into(), Value::Num(st.controller_last as f64));

    let mut tr = std::collections::BTreeMap::new();
    tr.insert(
        "types".into(),
        Value::Arr(st.tracker.types.iter().map(|t| Value::Str(t.clone())).collect()),
    );
    tr.insert("g_sq".into(), Value::Arr(st.tracker.g_sq.iter().map(ema_to_json).collect()));
    tr.insert("s".into(), Value::Arr(st.tracker.s.iter().map(ema_to_json).collect()));
    tr.insert("g_sq_total".into(), ema_to_json(&st.tracker.g_sq_total));
    tr.insert("s_total".into(), ema_to_json(&st.tracker.s_total));
    top.insert("tracker".into(), Value::Obj(tr));

    top.insert("loaders".into(), Value::Arr(st.loaders.iter().map(rng_to_json).collect()));

    let mut tensors = Vec::new();
    for (group, bufs) in groups(st) {
        ensure!(
            bufs.len() == entry.params.len(),
            "{group}: {} tensors, model has {}",
            bufs.len(),
            entry.params.len()
        );
        for (spec, buf) in entry.params.iter().zip(bufs) {
            let t = buf.as_host().with_context(|| format!("{group}/{}", spec.name))?;
            ensure!(t.shape == spec.shape, "{group}/{}: shape drift", spec.name);
            let mut e = std::collections::BTreeMap::new();
            e.insert("group".into(), Value::Str(group.into()));
            e.insert("name".into(), Value::Str(spec.name.clone()));
            e.insert(
                "shape".into(),
                Value::Arr(t.shape.iter().map(|&d| Value::Num(d as f64)).collect()),
            );
            tensors.push(Value::Obj(e));
        }
    }
    top.insert("tensors".into(), Value::Arr(tensors));
    Ok(Value::Obj(top))
}

/// Serialize a full v2 checkpoint image into `out` (cleared first). The
/// bytes are exactly what [`publish_bytes`] expects — splitting the two
/// lets the writer thread own the disk I/O while the training thread only
/// pays for serialization into a recycled buffer.
pub fn encode_state(entry: &ModelEntry, st: &TrainStateView<'_>, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    let header = header_json(st, entry)?.to_string();
    ensure!(header.len() <= MAX_HEADER_BYTES, "checkpoint header too large");
    out.extend_from_slice(MAGIC_V2);
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for (group, bufs) in groups(st) {
        for (spec, buf) in entry.params.iter().zip(bufs) {
            let t = buf.as_host().with_context(|| format!("{group}/{}", spec.name))?;
            for v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    Ok(())
}

/// Crash-safely publish an encoded checkpoint image at `path`: bytes go
/// to a `.ckpt.tmp` sibling which is fsynced and only then renamed over
/// `path`, and finally the parent directory is fsynced so the rename
/// itself survives power loss — without the directory sync, a crashed
/// machine can come back with the old name pointing at nothing.
pub fn publish_bytes(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(bytes)?;
        w.flush()?;
        w.into_inner().map_err(|e| anyhow!("flushing checkpoint: {e}"))?.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("publishing checkpoint {path:?}"))?;
    fsync_parent_dir(path)
}

/// Fsync the directory holding `path` (unix only; a no-op elsewhere).
fn fsync_parent_dir(path: &Path) -> Result<()> {
    #[cfg(unix)]
    if let Some(dir) = path.parent() {
        let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
        std::fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("fsyncing checkpoint dir {dir:?}"))?;
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// Remove leftover `*.ckpt.tmp` files from checkpoint writes interrupted
/// mid-stream (crash or kill between create and rename). Returns the
/// removed paths, sorted; a missing directory is fine (nothing to clean).
pub fn clean_stale_tmps(dir: impl AsRef<Path>) -> Result<Vec<PathBuf>> {
    let dir = dir.as_ref();
    let mut removed = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(removed),
        Err(e) => return Err(e).with_context(|| format!("scanning {dir:?}")),
    };
    for entry in entries {
        let path = entry?.path();
        let is_tmp = path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.ends_with(".ckpt.tmp"));
        if is_tmp {
            std::fs::remove_file(&path)
                .with_context(|| format!("removing stale checkpoint tmp {path:?}"))?;
            removed.push(path);
        }
    }
    removed.sort();
    Ok(removed)
}

/// Write a full-state (v2) checkpoint synchronously:
/// [`encode_state`] + [`publish_bytes`] on the calling thread.
pub fn save_state(
    path: impl AsRef<Path>,
    entry: &ModelEntry,
    st: &TrainStateView<'_>,
) -> Result<()> {
    let mut bytes = Vec::new();
    encode_state(entry, st, &mut bytes)?;
    publish_bytes(path, &bytes)
}

// ---------------------------------------------------------------------------
// Async writer
// ---------------------------------------------------------------------------

/// Double-buffered background checkpoint writer.
///
/// The training thread serializes into an idle buffer
/// ([`CkptWriter::take_buffer`]) and hands it off ([`CkptWriter::submit`]);
/// a dedicated thread runs the crash-safe [`publish_bytes`] for every
/// target path (one encode can publish both `step%08d.ckpt` and
/// `latest.ckpt`), then recycles the buffer. With the channel bound of
/// one, `submit` only blocks when two writes are already outstanding, so
/// steady-state training never waits on disk. Write errors are sticky:
/// the first failure is surfaced by every later [`CkptWriter::submit`] or
/// [`CkptWriter::wait_idle`] call.
pub struct CkptWriter {
    tx: Option<std::sync::mpsc::SyncSender<CkptJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
    shared: Arc<CkptShared>,
}

struct CkptJob {
    bytes: Vec<u8>,
    paths: Vec<PathBuf>,
}

struct CkptShared {
    state: Mutex<CkptState>,
    idle: Condvar,
}

#[derive(Default)]
struct CkptState {
    pending: usize,
    pool: Vec<Vec<u8>>,
    error: Option<String>,
}

impl CkptWriter {
    pub fn new() -> Self {
        let shared =
            Arc::new(CkptShared { state: Mutex::new(CkptState::default()), idle: Condvar::new() });
        let (tx, rx) = std::sync::mpsc::sync_channel::<CkptJob>(1);
        let worker = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || {
                for job in rx {
                    let mut failure = None;
                    for path in &job.paths {
                        if let Err(e) = publish_bytes(path, &job.bytes) {
                            failure = Some(format!("{path:?}: {e}"));
                            break;
                        }
                    }
                    let mut st = worker.state.lock().expect("ckpt writer state");
                    st.pending -= 1;
                    if st.error.is_none() {
                        st.error = failure;
                    }
                    if st.pool.len() < 2 {
                        let mut bytes = job.bytes;
                        bytes.clear();
                        st.pool.push(bytes);
                    }
                    worker.idle.notify_all();
                }
            })
            .expect("spawning checkpoint writer thread");
        Self { tx: Some(tx), handle: Some(handle), shared }
    }

    /// An idle serialization buffer — recycled from a finished write when
    /// one is available, so steady state allocates nothing per checkpoint.
    pub fn take_buffer(&self) -> Vec<u8> {
        let mut st = self.shared.state.lock().expect("ckpt writer state");
        st.pool.pop().unwrap_or_default()
    }

    /// Queue an encoded image for crash-safe publication at every path in
    /// `paths`. Returns immediately unless two writes are already
    /// outstanding; surfaces any earlier write failure.
    pub fn submit(&self, bytes: Vec<u8>, paths: Vec<PathBuf>) -> Result<()> {
        {
            let mut st = self.shared.state.lock().expect("ckpt writer state");
            Self::check_error(&st)?;
            st.pending += 1;
        }
        let tx = self.tx.as_ref().expect("ckpt writer running");
        if tx.send(CkptJob { bytes, paths }).is_err() {
            let mut st = self.shared.state.lock().expect("ckpt writer state");
            st.pending -= 1;
            bail!("checkpoint writer thread is gone");
        }
        Ok(())
    }

    /// Block until every queued write has been published; surfaces the
    /// first write error if one occurred.
    pub fn wait_idle(&self) -> Result<()> {
        let mut st = self.shared.state.lock().expect("ckpt writer state");
        while st.pending > 0 {
            st = self.shared.idle.wait(st).expect("ckpt writer state");
        }
        Self::check_error(&st)
    }

    fn check_error(st: &CkptState) -> Result<()> {
        match &st.error {
            Some(e) => bail!("async checkpoint write failed: {e}"),
            None => Ok(()),
        }
    }
}

impl Default for CkptWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for CkptWriter {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Read the magic + JSON header of a v2 checkpoint from a stream,
/// leaving the reader positioned at the start of the tensor payload.
fn read_header_from(r: &mut impl Read) -> Result<Value> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading checkpoint magic")?;
    if &magic == MAGIC {
        bail!("params-only (v1) checkpoint has no header manifest");
    }
    ensure!(&magic == MAGIC_V2, "bad checkpoint magic {magic:?}");
    let mut buf4 = [0u8; 4];
    r.read_exact(&mut buf4).context("reading header length")?;
    let hlen = u32::from_le_bytes(buf4) as usize;
    ensure!(hlen > 0 && hlen <= MAX_HEADER_BYTES, "implausible header length {hlen}");
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes).context("reading header (truncated checkpoint?)")?;
    let header = Value::parse(std::str::from_utf8(&hbytes).context("header not UTF-8")?)
        .context("parsing checkpoint header JSON")?;
    let version = header.get("version")?.as_u64()?;
    ensure!(version == VERSION_V2, "unsupported checkpoint version {version}");
    Ok(header)
}

/// Read only the JSON header manifest of a v2 checkpoint — no tensor
/// payload is touched or validated, so no model manifest is needed.
/// This is the `repro inspect checkpoint` entry point.
pub fn read_header(path: impl AsRef<Path>) -> Result<Value> {
    let mut r = BufReader::new(
        std::fs::File::open(&path).with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    read_header_from(&mut r)
}

/// Parse the GNS tracker state out of a v2 header ([`read_header`]).
pub fn tracker_from_header(header: &Value) -> Result<TrackerState> {
    let tracker_v = header.get("tracker")?;
    let tracker = TrackerState {
        types: tracker_v
            .get("types")?
            .as_arr()?
            .iter()
            .map(|t| Ok(t.as_str()?.to_string()))
            .collect::<Result<_>>()?,
        g_sq: ema_vec_from_json(tracker_v.get("g_sq")?)?,
        s: ema_vec_from_json(tracker_v.get("s")?)?,
        g_sq_total: ema_from_json(tracker_v.get("g_sq_total")?)?,
        s_total: ema_from_json(tracker_v.get("s_total")?)?,
    };
    ensure!(
        tracker.g_sq.len() == tracker.types.len() && tracker.s.len() == tracker.types.len(),
        "tracker EMA arity mismatch"
    );
    Ok(tracker)
}

/// Read a full-state (v2) checkpoint, validating the manifest against
/// `entry` (tensor names, shapes, payload length).
pub fn load_state(path: impl AsRef<Path>, entry: &ModelEntry) -> Result<TrainState> {
    let mut r = BufReader::new(
        std::fs::File::open(&path).with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    let header = read_header_from(&mut r)?;
    let tracker = tracker_from_header(&header)?;

    let loaders = header
        .get("loaders")?
        .as_arr()?
        .iter()
        .map(rng_from_json)
        .collect::<Result<Vec<_>>>()?;

    // Tensor payload: listing must match the model manifest exactly, in
    // (params, m, v) order.
    let listing = header.get("tensors")?.as_arr()?;
    ensure!(
        listing.len() == 3 * entry.params.len(),
        "checkpoint lists {} tensors, model needs {}",
        listing.len(),
        3 * entry.params.len()
    );
    let mut grouped: [Vec<Buffer>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for (i, item) in listing.iter().enumerate() {
        let gi = i / entry.params.len();
        let spec = &entry.params[i % entry.params.len()];
        let group = ["params", "m", "v"][gi];
        ensure!(
            item.get("group")?.as_str()? == group && item.get("name")?.as_str()? == spec.name,
            "tensor {i}: expected {group}/{}, found {}/{}",
            spec.name,
            item.get("group")?.as_str().unwrap_or("?"),
            item.get("name")?.as_str().unwrap_or("?")
        );
        let shape: Vec<usize> = item
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<_>>()?;
        ensure!(shape == spec.shape, "{group}/{}: checkpoint shape {shape:?}", spec.name);
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let mut raw = vec![0u8; numel * 4];
        r.read_exact(&mut raw)
            .with_context(|| format!("{group}/{}: truncated tensor payload", spec.name))?;
        for (d, c) in data.iter_mut().zip(raw.chunks_exact(4)) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        grouped[gi].push(Buffer::from_tensor(Tensor::new(shape, data)?));
    }
    let mut extra = [0u8; 1];
    ensure!(
        matches!(r.read(&mut extra), Ok(0)),
        "trailing bytes after checkpoint payload (corrupt file?)"
    );
    let [params, m, v] = grouped;

    Ok(TrainState {
        model: header.get("model")?.as_str()?.to_string(),
        seed: parse_u64_str(header.get("seed")?)?,
        corpus_bytes: parse_u64_str(header.get("corpus_bytes")?)?,
        step: parse_u64_str(header.get("step")?)?,
        tokens: parse_u64_str(header.get("tokens")?)?,
        lr_scale: parse_f64_hex(header.get("lr_scale")?)?,
        controller_last: header.get("controller_last")?.as_usize()?,
        tracker,
        loaders,
        params,
        m,
        v,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact encodings must survive the values JSON numbers cannot:
    /// NaN, -0.0, subnormals, full-width u64 RNG words.
    #[test]
    fn scalar_encodings_are_bitwise_exact() {
        for x in [1.5f64, f64::NAN, -0.0, f64::MIN_POSITIVE / 2.0, f64::INFINITY] {
            let v = f64_hex(x);
            let text = v.to_string();
            let back = parse_f64_hex(&Value::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        for n in [0u64, 1, u64::MAX, 0x9e3779b97f4a7c15] {
            let v = u64_str(n);
            let back = parse_u64_str(&Value::parse(&v.to_string()).unwrap()).unwrap();
            assert_eq!(back, n);
        }
        assert!(parse_f64_hex(&Value::Str("not-hex".into())).is_err());
        assert!(parse_u64_str(&Value::Str("-3".into())).is_err());
    }

    #[test]
    fn rng_state_json_round_trip() {
        let st = RngState { s: [u64::MAX, 0, 1, 0xdeadbeef], spare: Some(-0.0) };
        let back = rng_from_json(&rng_to_json(&st)).unwrap();
        assert_eq!(back.s, st.s);
        assert_eq!(back.spare.unwrap().to_bits(), (-0.0f64).to_bits());
        let none = RngState { s: [1, 2, 3, 4], spare: None };
        assert_eq!(rng_from_json(&rng_to_json(&none)).unwrap(), none);
    }

    #[test]
    fn ema_parts_json_round_trip() {
        let p = EmaParts { alpha: 0.05, state: Some(f64::NAN), t: 7, bias_correct: true };
        let back = ema_from_json(&ema_to_json(&p)).unwrap();
        assert_eq!(back.alpha.to_bits(), p.alpha.to_bits());
        assert_eq!(back.state.unwrap().to_bits(), p.state.unwrap().to_bits());
        assert_eq!(back.t, 7);
        assert!(back.bias_correct);
    }

    fn scratch_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nanogns-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn clean_stale_tmps_removes_only_tmp_files() {
        let dir = scratch_dir("stale");
        std::fs::write(dir.join("step00000010.ckpt"), b"keep").unwrap();
        std::fs::write(dir.join("step00000020.ckpt.tmp"), b"stale").unwrap();
        std::fs::write(dir.join("latest.ckpt.tmp"), b"stale").unwrap();
        std::fs::write(dir.join("notes.txt"), b"keep").unwrap();
        let removed = clean_stale_tmps(&dir).unwrap();
        assert_eq!(removed.len(), 2);
        assert!(dir.join("step00000010.ckpt").exists());
        assert!(dir.join("notes.txt").exists());
        assert!(!dir.join("step00000020.ckpt.tmp").exists());
        assert!(!dir.join("latest.ckpt.tmp").exists());
        // Missing directory: nothing to clean, not an error.
        assert!(clean_stale_tmps(dir.join("no-such-subdir")).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ckpt_writer_publishes_to_all_paths_and_recycles_buffers() {
        let dir = scratch_dir("writer");
        let w = CkptWriter::new();
        let mut buf = w.take_buffer();
        buf.extend_from_slice(b"checkpoint-image-bytes");
        let step = dir.join("step00000001.ckpt");
        let latest = dir.join("latest.ckpt");
        w.submit(buf, vec![step.clone(), latest.clone()]).unwrap();
        w.wait_idle().unwrap();
        assert_eq!(std::fs::read(&step).unwrap(), b"checkpoint-image-bytes");
        assert_eq!(std::fs::read(&latest).unwrap(), b"checkpoint-image-bytes");
        assert!(!dir.join("step00000001.ckpt.tmp").exists());
        // The finished write's buffer came back to the pool, emptied but
        // with its allocation intact.
        let recycled = w.take_buffer();
        assert!(recycled.is_empty());
        assert!(recycled.capacity() >= b"checkpoint-image-bytes".len());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ckpt_writer_errors_are_sticky() {
        let dir = scratch_dir("writer-err");
        // A file where the target's parent dir should be makes create_dir_all fail.
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, b"file, not dir").unwrap();
        let w = CkptWriter::new();
        w.submit(b"bytes".to_vec(), vec![blocker.join("sub").join("x.ckpt")]).unwrap();
        assert!(w.wait_idle().is_err());
        // The failure sticks: later submits refuse too.
        assert!(w.submit(b"more".to_vec(), vec![dir.join("ok.ckpt")]).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
