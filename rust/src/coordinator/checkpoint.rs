//! Binary parameter checkpoints.
//!
//! Format: magic, schema version, param count, then per param
//! (name-len, name, rank, dims..., f32 data). Self-describing enough to
//! verify against a manifest on load; little-endian throughout.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{ensure, Result};

use crate::runtime::tensor::Tensor;
use crate::runtime::{Buffer, ModelEntry};

const MAGIC: &[u8; 8] = b"NANOGNS1";

pub fn save(path: impl AsRef<Path>, entry: &ModelEntry, params: &[Buffer]) -> Result<()> {
    ensure!(params.len() == entry.params.len(), "param count mismatch");
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(params.len() as u32).to_le_bytes())?;
    for (spec, buf) in entry.params.iter().zip(params) {
        let t = buf.to_tensor()?;
        ensure!(t.shape == spec.shape, "{}: shape drift", spec.name);
        let name = spec.name.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        for v in &t.data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>, entry: &ModelEntry) -> Result<Vec<Buffer>> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "bad checkpoint magic");
    let mut buf4 = [0u8; 4];
    r.read_exact(&mut buf4)?;
    let n = u32::from_le_bytes(buf4) as usize;
    ensure!(n == entry.params.len(), "checkpoint has {n} params, manifest {}", entry.params.len());
    let mut out = Vec::with_capacity(n);
    for spec in &entry.params {
        r.read_exact(&mut buf4)?;
        let name_len = u32::from_le_bytes(buf4) as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        ensure!(
            name == spec.name.as_bytes(),
            "checkpoint param {:?} != manifest {:?}",
            String::from_utf8_lossy(&name),
            spec.name
        );
        r.read_exact(&mut buf4)?;
        let rank = u32::from_le_bytes(buf4) as usize;
        let mut shape = Vec::with_capacity(rank);
        let mut buf8 = [0u8; 8];
        for _ in 0..rank {
            r.read_exact(&mut buf8)?;
            shape.push(u64::from_le_bytes(buf8) as usize);
        }
        ensure!(shape == spec.shape, "{}: checkpoint shape {:?}", spec.name, shape);
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        for v in &mut data {
            r.read_exact(&mut buf4)?;
            *v = f32::from_le_bytes(buf4);
        }
        out.push(Buffer::from_tensor(Tensor::new(shape, data)?));
    }
    Ok(out)
}
