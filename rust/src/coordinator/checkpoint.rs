//! Binary checkpoints: params-only snapshots (v1) and full training
//! state for interrupt/resume (v3).
//!
//! **v1** (`NANOGNS1`): magic, param count, then per param (name-len,
//! name, rank, dims..., f32 data). Kept for params-only export/import.
//!
//! **v3** (`NGNSCKP3`): magic, u32 header length, u32 CRC-32 of the
//! header bytes, a JSON header manifest (via [`crate::util::json`]),
//! then the raw f32 payload of every listed tensor (params, Adam m,
//! Adam v — in manifest order). The header carries everything else a
//! [`super::Trainer`] mutates: step/token counters, GNS tracker EMAs,
//! batch-size controller hysteresis, LR scale, and per-rank loader
//! cursors — plus an `integrity` section with a CRC-32 per payload
//! group, verified streamingly on load. All f64/u64 header scalars are
//! encoded as exact strings (`0x…` bit patterns for floats, decimal for
//! integers) so a resumed run replays a **bitwise-identical** trajectory
//! — JSON numbers would round u64 RNG words through f64 and silently
//! fork the data stream. Little-endian throughout. The unchecksummed v2
//! format (`NGNSCKP2`) is refused with a loud error rather than trusted.
//!
//! Publication is crash-safe (`.tmp` → fsync → rename → parent-dir
//! fsync), and [`CkptWriter`] moves the disk work off the training
//! thread: the trainer serializes into an idle buffer ([`encode_state`])
//! and hands it to a double-buffered writer thread. A failed publish
//! (ENOSPC, permissions) *degrades* the writer — the image is retained
//! in memory with a loud warning, later publishes keep flowing, and the
//! end-of-run [`CkptWriter::wait_idle`] makes a final synchronous
//! attempt before surfacing the failure as a run error. Resume goes
//! through [`load_state_chain`], which falls back down the retained
//! `step-*.ckpt` chain to the newest checkpoint that validates.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::gns::{EmaParts, TrackerState};
use crate::norms::{NormKind, NormPlacement};
use crate::runtime::tensor::Tensor;
use crate::runtime::{Buffer, ModelEntry};
use crate::util::crc::{crc32, Crc32};
use crate::util::faultkit::{self, CkptFault};
use crate::util::json::Value;
use crate::util::rng::RngState;

const MAGIC: &[u8; 8] = b"NANOGNS1";
/// Retired full-state format without integrity checksums; refused.
const MAGIC_V2: &[u8; 8] = b"NGNSCKP2";
const MAGIC_V3: &[u8; 8] = b"NGNSCKP3";
const VERSION_V3: u64 = 3;
/// Sanity bound on the v3 header: a few KiB in practice.
const MAX_HEADER_BYTES: usize = 1 << 24;
/// Payload groups of a full-state checkpoint, in on-disk order.
const GROUP_NAMES: [&str; 3] = ["params", "m", "v"];

pub fn save(path: impl AsRef<Path>, entry: &ModelEntry, params: &[Buffer]) -> Result<()> {
    ensure!(params.len() == entry.params.len(), "param count mismatch");
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(params.len() as u32).to_le_bytes())?;
    for (spec, buf) in entry.params.iter().zip(params) {
        let t = buf.to_tensor()?;
        ensure!(t.shape == spec.shape, "{}: shape drift", spec.name);
        let name = spec.name.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        for v in &t.data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>, entry: &ModelEntry) -> Result<Vec<Buffer>> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "bad checkpoint magic");
    let mut buf4 = [0u8; 4];
    r.read_exact(&mut buf4)?;
    let n = u32::from_le_bytes(buf4) as usize;
    ensure!(n == entry.params.len(), "checkpoint has {n} params, manifest {}", entry.params.len());
    let mut out = Vec::with_capacity(n);
    for spec in &entry.params {
        r.read_exact(&mut buf4)?;
        let name_len = u32::from_le_bytes(buf4) as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        ensure!(
            name == spec.name.as_bytes(),
            "checkpoint param {:?} != manifest {:?}",
            String::from_utf8_lossy(&name),
            spec.name
        );
        r.read_exact(&mut buf4)?;
        let rank = u32::from_le_bytes(buf4) as usize;
        let mut shape = Vec::with_capacity(rank);
        let mut buf8 = [0u8; 8];
        for _ in 0..rank {
            r.read_exact(&mut buf8)?;
            shape.push(u64::from_le_bytes(buf8) as usize);
        }
        ensure!(shape == spec.shape, "{}: checkpoint shape {:?}", spec.name, shape);
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        for v in &mut data {
            r.read_exact(&mut buf4)?;
            *v = f32::from_le_bytes(buf4);
        }
        out.push(Buffer::from_tensor(Tensor::new(shape, data)?));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// v2: full training state
// ---------------------------------------------------------------------------

/// Everything a [`super::Trainer`] needs to resume a run bitwise-exactly.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub model: String,
    /// Normalization variant the run was trained under. Checkpoints
    /// predating the variant matrix decode as the historical default
    /// (LayerNorm / Pre-LN); resuming under any *other* variant is
    /// refused — the parameter layout and trajectory both differ.
    pub norm_kind: NormKind,
    pub norm_placement: NormPlacement,
    /// Run seed: the corpus and loader streams derive from it, so a
    /// resume under a different seed would silently fork the data.
    pub seed: u64,
    /// Corpus size the loaders were built over (same divergence hazard).
    pub corpus_bytes: u64,
    pub step: u64,
    pub tokens: u64,
    pub lr_scale: f64,
    /// Batch-size controller hysteresis anchor.
    pub controller_last: usize,
    pub tracker: TrackerState,
    /// Per-rank loader cursors, rank order.
    pub loaders: Vec<RngState>,
    pub params: Vec<Buffer>,
    pub m: Vec<Buffer>,
    pub v: Vec<Buffer>,
}

/// Borrowed view of everything [`save_state`] serializes: the saving side
/// hands in its live buffers directly, so a checkpoint never clones the
/// three model-sized tensor sets.
pub struct TrainStateView<'a> {
    pub model: &'a str,
    pub norm_kind: NormKind,
    pub norm_placement: NormPlacement,
    pub seed: u64,
    pub corpus_bytes: u64,
    pub step: u64,
    pub tokens: u64,
    pub lr_scale: f64,
    pub controller_last: usize,
    pub tracker: TrackerState,
    pub loaders: Vec<RngState>,
    pub params: &'a [Buffer],
    pub m: &'a [Buffer],
    pub v: &'a [Buffer],
}

/// Exact f64 encoding: the IEEE-754 bit pattern as a hex string. Survives
/// NaN/-0.0/subnormals, which `{}`-formatted JSON numbers cannot.
fn f64_hex(x: f64) -> Value {
    Value::Str(format!("0x{:016x}", x.to_bits()))
}

fn parse_f64_hex(v: &Value) -> Result<f64> {
    let s = v.as_str()?;
    let hex = s.strip_prefix("0x").ok_or_else(|| anyhow!("bad f64 bits {s:?}"))?;
    Ok(f64::from_bits(u64::from_str_radix(hex, 16).context("bad f64 bits")?))
}

/// Exact u64 encoding as a decimal string (JSON numbers are f64: RNG
/// words would lose bits).
fn u64_str(x: u64) -> Value {
    Value::Str(x.to_string())
}

fn parse_u64_str(v: &Value) -> Result<u64> {
    v.as_str()?.parse::<u64>().context("bad u64 string")
}

fn ema_to_json(p: &EmaParts) -> Value {
    let mut m = std::collections::BTreeMap::new();
    m.insert("alpha".into(), f64_hex(p.alpha));
    m.insert("state".into(), p.state.map(f64_hex).unwrap_or(Value::Null));
    m.insert("t".into(), u64_str(p.t));
    m.insert("bias_correct".into(), Value::Bool(p.bias_correct));
    Value::Obj(m)
}

fn ema_from_json(v: &Value) -> Result<EmaParts> {
    let state = match v.get("state")? {
        Value::Null => None,
        other => Some(parse_f64_hex(other)?),
    };
    Ok(EmaParts {
        alpha: parse_f64_hex(v.get("alpha")?)?,
        state,
        t: parse_u64_str(v.get("t")?)?,
        bias_correct: v.get("bias_correct")?.as_bool()?,
    })
}

fn ema_vec_from_json(v: &Value) -> Result<Vec<EmaParts>> {
    v.as_arr()?.iter().map(ema_from_json).collect()
}

fn rng_to_json(st: &RngState) -> Value {
    let mut m = std::collections::BTreeMap::new();
    m.insert("s".into(), Value::Arr(st.s.iter().map(|&w| u64_str(w)).collect()));
    m.insert("spare".into(), st.spare.map(f64_hex).unwrap_or(Value::Null));
    Value::Obj(m)
}

fn rng_from_json(v: &Value) -> Result<RngState> {
    let words = v.get("s")?.as_arr()?;
    ensure!(words.len() == 4, "loader cursor needs 4 RNG words");
    let mut s = [0u64; 4];
    for (d, w) in s.iter_mut().zip(words) {
        *d = parse_u64_str(w)?;
    }
    let spare = match v.get("spare")? {
        Value::Null => None,
        other => Some(parse_f64_hex(other)?),
    };
    Ok(RngState { s, spare })
}

/// The `(group, tensors)` triplets a v3 checkpoint carries, in payload
/// order.
fn groups<'a>(st: &TrainStateView<'a>) -> [(&'static str, &'a [Buffer]); 3] {
    [
        (GROUP_NAMES[0], st.params),
        (GROUP_NAMES[1], st.m),
        (GROUP_NAMES[2], st.v),
    ]
}

/// Fixed-width CRC-32 encoding for header fields (`0x` + 8 hex digits).
fn crc_hex(c: u32) -> Value {
    Value::Str(format!("0x{c:08x}"))
}

fn parse_crc_hex(v: &Value) -> Result<u32> {
    let s = v.as_str()?;
    let hex = s.strip_prefix("0x").ok_or_else(|| anyhow!("bad crc32 {s:?}"))?;
    u32::from_str_radix(hex, 16).context("bad crc32")
}

/// The per-group payload CRC-32s out of a v3 header's `integrity`
/// section, in [`GROUP_NAMES`] order.
fn group_crcs_from_header(header: &Value) -> Result<[u32; 3]> {
    let g = header.get("integrity")?.get("groups")?;
    let mut out = [0u32; 3];
    for (slot, name) in out.iter_mut().zip(GROUP_NAMES) {
        *slot = parse_crc_hex(g.get(name)?)
            .with_context(|| format!("integrity crc for group {name:?}"))?;
    }
    Ok(out)
}

fn header_json(st: &TrainStateView<'_>, entry: &ModelEntry, crcs: &[u32; 3]) -> Result<Value> {
    let mut top = std::collections::BTreeMap::new();
    top.insert("version".into(), Value::Num(VERSION_V3 as f64));
    top.insert("model".into(), Value::Str(st.model.to_string()));
    top.insert("norm_kind".into(), Value::Str(st.norm_kind.name().into()));
    top.insert("norm_placement".into(), Value::Str(st.norm_placement.name().into()));
    top.insert("seed".into(), u64_str(st.seed));
    top.insert("corpus_bytes".into(), u64_str(st.corpus_bytes));
    top.insert("step".into(), u64_str(st.step));
    top.insert("tokens".into(), u64_str(st.tokens));
    top.insert("lr_scale".into(), f64_hex(st.lr_scale));
    top.insert("controller_last".into(), Value::Num(st.controller_last as f64));

    let mut tr = std::collections::BTreeMap::new();
    tr.insert(
        "types".into(),
        Value::Arr(st.tracker.types.iter().map(|t| Value::Str(t.clone())).collect()),
    );
    tr.insert("g_sq".into(), Value::Arr(st.tracker.g_sq.iter().map(ema_to_json).collect()));
    tr.insert("s".into(), Value::Arr(st.tracker.s.iter().map(ema_to_json).collect()));
    tr.insert("g_sq_total".into(), ema_to_json(&st.tracker.g_sq_total));
    tr.insert("s_total".into(), ema_to_json(&st.tracker.s_total));
    top.insert("tracker".into(), Value::Obj(tr));

    top.insert("loaders".into(), Value::Arr(st.loaders.iter().map(rng_to_json).collect()));

    let mut tensors = Vec::new();
    for (group, bufs) in groups(st) {
        ensure!(
            bufs.len() == entry.params.len(),
            "{group}: {} tensors, model has {}",
            bufs.len(),
            entry.params.len()
        );
        for (spec, buf) in entry.params.iter().zip(bufs) {
            let t = buf.as_host().with_context(|| format!("{group}/{}", spec.name))?;
            ensure!(t.shape == spec.shape, "{group}/{}: shape drift", spec.name);
            let mut e = std::collections::BTreeMap::new();
            e.insert("group".into(), Value::Str(group.into()));
            e.insert("name".into(), Value::Str(spec.name.clone()));
            e.insert(
                "shape".into(),
                Value::Arr(t.shape.iter().map(|&d| Value::Num(d as f64)).collect()),
            );
            tensors.push(Value::Obj(e));
        }
    }
    top.insert("tensors".into(), Value::Arr(tensors));

    let mut gm = std::collections::BTreeMap::new();
    for (name, crc) in GROUP_NAMES.iter().zip(crcs) {
        gm.insert((*name).into(), crc_hex(*crc));
    }
    let mut ig = std::collections::BTreeMap::new();
    ig.insert("algo".into(), Value::Str("crc32".into()));
    ig.insert("groups".into(), Value::Obj(gm));
    top.insert("integrity".into(), Value::Obj(ig));

    Ok(Value::Obj(top))
}

/// Serialize a full v3 checkpoint image into `out` (cleared first). The
/// bytes are exactly what [`publish_bytes`] expects — splitting the two
/// lets the writer thread own the disk I/O while the training thread only
/// pays for serialization into a recycled buffer.
pub fn encode_state(entry: &ModelEntry, st: &TrainStateView<'_>, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    // Pre-pass: per-group payload CRCs go *into* the header, which lands
    // on disk before the payload. Bytes are staged through a small stack
    // block so the checksum runs at slice-by-8 speed.
    let mut crcs = [0u32; 3];
    for (slot, (group, bufs)) in crcs.iter_mut().zip(groups(st)) {
        let mut c = Crc32::new();
        let mut block = [0u8; 256];
        for (spec, buf) in entry.params.iter().zip(bufs) {
            let t = buf.as_host().with_context(|| format!("{group}/{}", spec.name))?;
            for chunk in t.data.chunks(block.len() / 4) {
                for (dst, v) in block.chunks_exact_mut(4).zip(chunk) {
                    dst.copy_from_slice(&v.to_le_bytes());
                }
                c.update(&block[..chunk.len() * 4]);
            }
        }
        *slot = c.finish();
    }
    let header = header_json(st, entry, &crcs)?.to_string();
    ensure!(header.len() <= MAX_HEADER_BYTES, "checkpoint header too large");
    out.extend_from_slice(MAGIC_V3);
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(header.as_bytes()).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for (group, bufs) in groups(st) {
        for (spec, buf) in entry.params.iter().zip(bufs) {
            let t = buf.as_host().with_context(|| format!("{group}/{}", spec.name))?;
            for v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    Ok(())
}

/// Crash-safely publish an encoded checkpoint image at `path`: bytes go
/// to a `.ckpt.tmp` sibling which is fsynced and only then renamed over
/// `path`, and finally the parent directory is fsynced so the rename
/// itself survives power loss — without the directory sync, a crashed
/// machine can come back with the old name pointing at nothing.
pub fn publish_bytes(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = bytes;
    // Fault injection (disarmed: one cached atomic load). ENOSPC fails
    // the publish like a full disk; a torn write publishes a truncated
    // image — the load-time integrity chain must catch it.
    if faultkit::armed() {
        match faultkit::on_ckpt_write() {
            Some(CkptFault::Enospc) => {
                bail!("injected ENOSPC publishing {path:?} (faultkit: no space left on device)")
            }
            Some(CkptFault::Torn) => {
                let half = bytes.len() / 2;
                eprintln!(
                    "faultkit: torn checkpoint write at {path:?} ({half} of {} bytes)",
                    bytes.len()
                );
                bytes = &bytes[..half];
            }
            None => {}
        }
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(bytes)?;
        w.flush()?;
        w.into_inner().map_err(|e| anyhow!("flushing checkpoint: {e}"))?.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("publishing checkpoint {path:?}"))?;
    fsync_parent_dir(path)
}

/// Fsync the directory holding `path` (unix only; a no-op elsewhere).
fn fsync_parent_dir(path: &Path) -> Result<()> {
    #[cfg(unix)]
    if let Some(dir) = path.parent() {
        let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
        std::fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("fsyncing checkpoint dir {dir:?}"))?;
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// Remove leftover `*.ckpt.tmp` files from checkpoint writes interrupted
/// mid-stream (crash or kill between create and rename). Returns the
/// removed paths, sorted; a missing directory is fine (nothing to clean).
pub fn clean_stale_tmps(dir: impl AsRef<Path>) -> Result<Vec<PathBuf>> {
    let dir = dir.as_ref();
    let mut removed = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(removed),
        Err(e) => return Err(e).with_context(|| format!("scanning {dir:?}")),
    };
    for entry in entries {
        let path = entry?.path();
        let is_tmp = path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.ends_with(".ckpt.tmp"));
        if is_tmp {
            std::fs::remove_file(&path)
                .with_context(|| format!("removing stale checkpoint tmp {path:?}"))?;
            removed.push(path);
        }
    }
    removed.sort();
    Ok(removed)
}

/// Every `step-XXXXXXXX.ckpt` in `dir` as `(step, path)`, ascending by
/// step. A missing directory is an empty chain, not an error.
pub fn list_step_checkpoints(dir: impl AsRef<Path>) -> Result<Vec<(u64, PathBuf)>> {
    let dir = dir.as_ref();
    let mut steps = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(steps),
        Err(e) => return Err(e).with_context(|| format!("scanning {dir:?}")),
    };
    for entry in entries {
        let path = entry?.path();
        let step = path
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(|n| n.strip_prefix("step-"))
            .and_then(|n| n.strip_suffix(".ckpt"))
            .and_then(|n| n.parse::<u64>().ok());
        if let Some(step) = step {
            steps.push((step, path));
        }
    }
    steps.sort();
    Ok(steps)
}

/// `keep_last` retention: delete the oldest `step-*.ckpt` files in `dir`
/// beyond the newest `keep`. `latest.ckpt` is never touched. Returns the
/// removed paths, oldest first.
pub fn prune_step_checkpoints(dir: impl AsRef<Path>, keep: usize) -> Result<Vec<PathBuf>> {
    let mut steps = list_step_checkpoints(dir)?;
    let mut removed = Vec::new();
    if steps.len() > keep {
        let excess = steps.len() - keep;
        for (_, path) in steps.drain(..excess) {
            std::fs::remove_file(&path)
                .with_context(|| format!("pruning old checkpoint {path:?}"))?;
            removed.push(path);
        }
    }
    Ok(removed)
}

/// Write a full-state (v3) checkpoint synchronously:
/// [`encode_state`] + [`publish_bytes`] on the calling thread.
pub fn save_state(
    path: impl AsRef<Path>,
    entry: &ModelEntry,
    st: &TrainStateView<'_>,
) -> Result<()> {
    let mut bytes = Vec::new();
    encode_state(entry, st, &mut bytes)?;
    publish_bytes(path, &bytes)
}

// ---------------------------------------------------------------------------
// Async writer
// ---------------------------------------------------------------------------

/// Double-buffered background checkpoint writer.
///
/// The training thread serializes into an idle buffer
/// ([`CkptWriter::take_buffer`]) and hands it off ([`CkptWriter::submit`]);
/// a dedicated thread runs the crash-safe [`publish_bytes`] for every
/// target path (one encode can publish both `step%08d.ckpt` and
/// `latest.ckpt`), applies `keep_last` retention, then recycles the
/// buffer. With the channel bound of one, `submit` only blocks when two
/// writes are already outstanding, so steady-state training never waits
/// on disk.
///
/// A failed publish (ENOSPC, permissions, a dead mount) does **not**
/// fail the run on the spot: the writer goes *degraded* — the image is
/// retained in memory, a loud warning goes to stderr, and training
/// continues. A later successful publish supersedes the retained image
/// (it carries strictly newer state) and clears the degradation.
/// [`CkptWriter::wait_idle`] — called at end of run — makes one final
/// synchronous attempt to land a still-retained image and returns an
/// error if the writer is still degraded, so a run that never recovered
/// exits nonzero instead of silently lacking a durable checkpoint.
pub struct CkptWriter {
    tx: Option<std::sync::mpsc::SyncSender<CkptJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
    shared: Arc<CkptShared>,
}

struct CkptJob {
    bytes: Vec<u8>,
    paths: Vec<PathBuf>,
    /// `(dir, keep_last)`: prune old `step-*.ckpt` files after publishing.
    retain: Option<(PathBuf, usize)>,
}

struct CkptShared {
    state: Mutex<CkptState>,
    idle: Condvar,
}

#[derive(Default)]
struct CkptState {
    pending: usize,
    pool: Vec<Vec<u8>>,
    /// First unrecovered publish failure; cleared by a later success.
    degraded: Option<String>,
    /// The newest image that failed to publish, held for a final retry.
    held: Option<CkptJob>,
}

/// Publish one job's image to every target path, then apply retention.
/// A retention failure is a warning, not a degradation — the checkpoints
/// themselves landed.
fn publish_job(job: &CkptJob) -> std::result::Result<(), String> {
    for path in &job.paths {
        publish_bytes(path, &job.bytes).map_err(|e| format!("publishing {path:?} failed: {e:#}"))?;
    }
    if let Some((dir, keep)) = &job.retain {
        if let Err(e) = prune_step_checkpoints(dir, *keep) {
            eprintln!("checkpoint: WARNING: pruning old checkpoints in {dir:?} failed: {e:#}");
        }
    }
    Ok(())
}

impl CkptWriter {
    pub fn new() -> Self {
        let shared =
            Arc::new(CkptShared { state: Mutex::new(CkptState::default()), idle: Condvar::new() });
        let (tx, rx) = std::sync::mpsc::sync_channel::<CkptJob>(1);
        let worker = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || {
                for job in rx {
                    let outcome = publish_job(&job);
                    let mut st = worker.state.lock().expect("ckpt writer state");
                    st.pending -= 1;
                    match outcome {
                        Ok(()) => {
                            if st.degraded.take().is_some() {
                                eprintln!(
                                    "checkpoint: publish recovered; resuming durable checkpoints"
                                );
                            }
                            st.held = None; // superseded by this newer image
                            if st.pool.len() < 2 {
                                let mut bytes = job.bytes;
                                bytes.clear();
                                st.pool.push(bytes);
                            }
                        }
                        Err(msg) => {
                            eprintln!(
                                "checkpoint: WARNING: {msg}; keeping the image in memory and \
                                 continuing (final retry at end of run)"
                            );
                            st.degraded = Some(msg);
                            st.held = Some(job);
                        }
                    }
                    worker.idle.notify_all();
                }
            })
            .expect("spawning checkpoint writer thread");
        Self { tx: Some(tx), handle: Some(handle), shared }
    }

    /// An idle serialization buffer — recycled from a finished write when
    /// one is available, so steady state allocates nothing per checkpoint.
    pub fn take_buffer(&self) -> Vec<u8> {
        let mut st = self.shared.state.lock().expect("ckpt writer state");
        st.pool.pop().unwrap_or_default()
    }

    /// Queue an encoded image for crash-safe publication at every path in
    /// `paths`, with optional `(dir, keep_last)` retention afterwards.
    /// Returns immediately unless two writes are already outstanding. A
    /// degraded writer still accepts images — each submit is a fresh
    /// recovery attempt.
    pub fn submit(
        &self,
        bytes: Vec<u8>,
        paths: Vec<PathBuf>,
        retain: Option<(PathBuf, usize)>,
    ) -> Result<()> {
        {
            let mut st = self.shared.state.lock().expect("ckpt writer state");
            st.pending += 1;
        }
        let tx = self.tx.as_ref().expect("ckpt writer running");
        if tx.send(CkptJob { bytes, paths, retain }).is_err() {
            let mut st = self.shared.state.lock().expect("ckpt writer state");
            st.pending -= 1;
            bail!("checkpoint writer thread is gone");
        }
        Ok(())
    }

    /// The current degradation message, if the last publish failed and no
    /// later one has succeeded (the serve daemon reports this on
    /// `/health`).
    pub fn degraded(&self) -> Option<String> {
        self.shared.state.lock().expect("ckpt writer state").degraded.clone()
    }

    /// Block until every queued write has been processed. If the writer
    /// is degraded, make one final synchronous attempt to land the
    /// retained image; surface an error only if that also fails — the
    /// hook that turns an unrecovered checkpoint failure into a nonzero
    /// exit at end of run.
    pub fn wait_idle(&self) -> Result<()> {
        let (msg, held) = {
            let mut st = self.shared.state.lock().expect("ckpt writer state");
            while st.pending > 0 {
                st = self.shared.idle.wait(st).expect("ckpt writer state");
            }
            match &st.degraded {
                None => return Ok(()),
                Some(msg) => (msg.clone(), st.held.take()),
            }
        };
        let Some(job) = held else {
            bail!("checkpoint writes degraded: {msg}");
        };
        let outcome = publish_job(&job);
        let mut st = self.shared.state.lock().expect("ckpt writer state");
        match outcome {
            Ok(()) => {
                eprintln!("checkpoint: degraded write recovered on final retry");
                st.degraded = None;
                Ok(())
            }
            Err(e) => {
                st.held = Some(job);
                bail!("checkpoint writes degraded ({msg}); final retry also failed: {e}")
            }
        }
    }
}

impl Default for CkptWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for CkptWriter {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Read the magic + JSON header of a v3 checkpoint from a stream,
/// verifying the header's own CRC-32, leaving the reader positioned at
/// the start of the tensor payload.
fn read_header_from(r: &mut impl Read) -> Result<Value> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading checkpoint magic")?;
    if &magic == MAGIC {
        bail!("params-only (v1) checkpoint has no header manifest");
    }
    if &magic == MAGIC_V2 {
        bail!(
            "v2 checkpoint predates the integrity chain and is no longer trusted; \
             re-run training to produce a v3 checkpoint"
        );
    }
    ensure!(&magic == MAGIC_V3, "bad checkpoint magic {magic:?}");
    let mut buf4 = [0u8; 4];
    r.read_exact(&mut buf4).context("reading header length")?;
    let hlen = u32::from_le_bytes(buf4) as usize;
    ensure!(hlen > 0 && hlen <= MAX_HEADER_BYTES, "implausible header length {hlen}");
    r.read_exact(&mut buf4).context("reading header checksum")?;
    let hcrc = u32::from_le_bytes(buf4);
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes).context("reading header (truncated checkpoint?)")?;
    ensure!(
        crc32(&hbytes) == hcrc,
        "checkpoint header crc mismatch (corrupt file?)"
    );
    let header = Value::parse(std::str::from_utf8(&hbytes).context("header not UTF-8")?)
        .context("parsing checkpoint header JSON")?;
    let version = header.get("version")?.as_u64()?;
    ensure!(version == VERSION_V3, "unsupported checkpoint version {version}");
    Ok(header)
}

/// Read only the JSON header manifest of a v3 checkpoint — no tensor
/// payload is touched or validated, so no model manifest is needed.
/// This is the `repro inspect checkpoint` entry point.
pub fn read_header(path: impl AsRef<Path>) -> Result<Value> {
    let mut r = BufReader::new(
        std::fs::File::open(&path).with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    read_header_from(&mut r)
}

/// The normalization variant recorded in a v3 header. Headers written
/// before the variant matrix have no such keys and decode as the
/// historical default cell (LayerNorm / Pre-LN); a present-but-garbled
/// value is an error, never a silent default.
pub fn variant_from_header(header: &Value) -> Result<(NormKind, NormPlacement)> {
    let norm = match header.get("norm_kind") {
        Ok(v) => v.as_str()?.parse().context("checkpoint norm_kind")?,
        Err(_) => NormKind::default(),
    };
    let placement = match header.get("norm_placement") {
        Ok(v) => v.as_str()?.parse().context("checkpoint norm_placement")?,
        Err(_) => NormPlacement::default(),
    };
    Ok((norm, placement))
}

/// Parse the GNS tracker state out of a v3 header ([`read_header`]).
pub fn tracker_from_header(header: &Value) -> Result<TrackerState> {
    let tracker_v = header.get("tracker")?;
    let tracker = TrackerState {
        types: tracker_v
            .get("types")?
            .as_arr()?
            .iter()
            .map(|t| Ok(t.as_str()?.to_string()))
            .collect::<Result<_>>()?,
        g_sq: ema_vec_from_json(tracker_v.get("g_sq")?)?,
        s: ema_vec_from_json(tracker_v.get("s")?)?,
        g_sq_total: ema_from_json(tracker_v.get("g_sq_total")?)?,
        s_total: ema_from_json(tracker_v.get("s_total")?)?,
    };
    ensure!(
        tracker.g_sq.len() == tracker.types.len() && tracker.s.len() == tracker.types.len(),
        "tracker EMA arity mismatch"
    );
    Ok(tracker)
}

/// Read a full-state (v3) checkpoint, validating the manifest against
/// `entry` (tensor names, shapes, payload length) and the per-group
/// payload CRC-32s against the header's integrity section.
pub fn load_state(path: impl AsRef<Path>, entry: &ModelEntry) -> Result<TrainState> {
    let mut r = BufReader::new(
        std::fs::File::open(&path).with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    let header = read_header_from(&mut r)?;
    let group_crcs = group_crcs_from_header(&header)?;
    let tracker = tracker_from_header(&header)?;

    let loaders = header
        .get("loaders")?
        .as_arr()?
        .iter()
        .map(rng_from_json)
        .collect::<Result<Vec<_>>>()?;

    // Tensor payload: listing must match the model manifest exactly, in
    // (params, m, v) order.
    let listing = header.get("tensors")?.as_arr()?;
    ensure!(
        listing.len() == 3 * entry.params.len(),
        "checkpoint lists {} tensors, model needs {}",
        listing.len(),
        3 * entry.params.len()
    );
    let mut grouped: [Vec<Buffer>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    let mut crc = Crc32::new();
    for (i, item) in listing.iter().enumerate() {
        let gi = i / entry.params.len();
        let spec = &entry.params[i % entry.params.len()];
        let group = GROUP_NAMES[gi];
        ensure!(
            item.get("group")?.as_str()? == group && item.get("name")?.as_str()? == spec.name,
            "tensor {i}: expected {group}/{}, found {}/{}",
            spec.name,
            item.get("group")?.as_str().unwrap_or("?"),
            item.get("name")?.as_str().unwrap_or("?")
        );
        let shape: Vec<usize> = item
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<_>>()?;
        ensure!(shape == spec.shape, "{group}/{}: checkpoint shape {shape:?}", spec.name);
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let mut raw = vec![0u8; numel * 4];
        r.read_exact(&mut raw)
            .with_context(|| format!("{group}/{}: truncated tensor payload", spec.name))?;
        crc.update(&raw);
        for (d, c) in data.iter_mut().zip(raw.chunks_exact(4)) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        grouped[gi].push(Buffer::from_tensor(Tensor::new(shape, data)?));
        // Group boundary: the streamed payload CRC must match the header.
        if (i + 1) % entry.params.len() == 0 {
            let got = std::mem::replace(&mut crc, Crc32::new()).finish();
            ensure!(
                got == group_crcs[gi],
                "{group}: payload crc mismatch (corrupt checkpoint?)"
            );
        }
    }
    let mut extra = [0u8; 1];
    ensure!(
        matches!(r.read(&mut extra), Ok(0)),
        "trailing bytes after checkpoint payload (corrupt file?)"
    );
    let [params, m, v] = grouped;
    let (norm_kind, norm_placement) = variant_from_header(&header)?;

    Ok(TrainState {
        model: header.get("model")?.as_str()?.to_string(),
        norm_kind,
        norm_placement,
        seed: parse_u64_str(header.get("seed")?)?,
        corpus_bytes: parse_u64_str(header.get("corpus_bytes")?)?,
        step: parse_u64_str(header.get("step")?)?,
        tokens: parse_u64_str(header.get("tokens")?)?,
        lr_scale: parse_f64_hex(header.get("lr_scale")?)?,
        controller_last: header.get("controller_last")?.as_usize()?,
        tracker,
        loaders,
        params,
        m,
        v,
    })
}

/// [`load_state`] with fallback down the retained checkpoint chain: if
/// `path` fails to load or validate, try every sibling `step-*.ckpt`
/// newest-first until one passes the full integrity check. Returns the
/// loaded state, the path actually used, and `(path, reason)` for every
/// candidate rejected on the way — callers log those loudly. Errors only
/// when no candidate in the directory validates.
pub fn load_state_chain(
    path: impl AsRef<Path>,
    entry: &ModelEntry,
) -> Result<(TrainState, PathBuf, Vec<(PathBuf, String)>)> {
    let path = path.as_ref();
    let mut rejected = Vec::new();
    match load_state(path, entry) {
        Ok(st) => return Ok((st, path.to_path_buf(), rejected)),
        Err(e) => rejected.push((path.to_path_buf(), format!("{e:#}"))),
    }
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let candidates = list_step_checkpoints(&dir).unwrap_or_default();
    for (_, cand) in candidates.into_iter().rev() {
        if cand == path {
            continue; // already tried as the primary
        }
        match load_state(&cand, entry) {
            Ok(st) => return Ok((st, cand, rejected)),
            Err(e) => rejected.push((cand, format!("{e:#}"))),
        }
    }
    let mut msg = format!("no valid checkpoint: {} candidate(s) all failed", rejected.len());
    for (p, why) in &rejected {
        msg.push_str(&format!("\n  {p:?}: {why}"));
    }
    bail!(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact encodings must survive the values JSON numbers cannot:
    /// NaN, -0.0, subnormals, full-width u64 RNG words.
    #[test]
    fn scalar_encodings_are_bitwise_exact() {
        for x in [1.5f64, f64::NAN, -0.0, f64::MIN_POSITIVE / 2.0, f64::INFINITY] {
            let v = f64_hex(x);
            let text = v.to_string();
            let back = parse_f64_hex(&Value::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        for n in [0u64, 1, u64::MAX, 0x9e3779b97f4a7c15] {
            let v = u64_str(n);
            let back = parse_u64_str(&Value::parse(&v.to_string()).unwrap()).unwrap();
            assert_eq!(back, n);
        }
        assert!(parse_f64_hex(&Value::Str("not-hex".into())).is_err());
        assert!(parse_u64_str(&Value::Str("-3".into())).is_err());
    }

    #[test]
    fn rng_state_json_round_trip() {
        let st = RngState { s: [u64::MAX, 0, 1, 0xdeadbeef], spare: Some(-0.0) };
        let back = rng_from_json(&rng_to_json(&st)).unwrap();
        assert_eq!(back.s, st.s);
        assert_eq!(back.spare.unwrap().to_bits(), (-0.0f64).to_bits());
        let none = RngState { s: [1, 2, 3, 4], spare: None };
        assert_eq!(rng_from_json(&rng_to_json(&none)).unwrap(), none);
    }

    #[test]
    fn ema_parts_json_round_trip() {
        let p = EmaParts { alpha: 0.05, state: Some(f64::NAN), t: 7, bias_correct: true };
        let back = ema_from_json(&ema_to_json(&p)).unwrap();
        assert_eq!(back.alpha.to_bits(), p.alpha.to_bits());
        assert_eq!(back.state.unwrap().to_bits(), p.state.unwrap().to_bits());
        assert_eq!(back.t, 7);
        assert!(back.bias_correct);
    }

    fn scratch_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nanogns-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn clean_stale_tmps_removes_only_tmp_files() {
        let dir = scratch_dir("stale");
        std::fs::write(dir.join("step00000010.ckpt"), b"keep").unwrap();
        std::fs::write(dir.join("step00000020.ckpt.tmp"), b"stale").unwrap();
        std::fs::write(dir.join("latest.ckpt.tmp"), b"stale").unwrap();
        std::fs::write(dir.join("notes.txt"), b"keep").unwrap();
        let removed = clean_stale_tmps(&dir).unwrap();
        assert_eq!(removed.len(), 2);
        assert!(dir.join("step00000010.ckpt").exists());
        assert!(dir.join("notes.txt").exists());
        assert!(!dir.join("step00000020.ckpt.tmp").exists());
        assert!(!dir.join("latest.ckpt.tmp").exists());
        // Missing directory: nothing to clean, not an error.
        assert!(clean_stale_tmps(dir.join("no-such-subdir")).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ckpt_writer_publishes_to_all_paths_and_recycles_buffers() {
        let dir = scratch_dir("writer");
        let w = CkptWriter::new();
        let mut buf = w.take_buffer();
        buf.extend_from_slice(b"checkpoint-image-bytes");
        let step = dir.join("step00000001.ckpt");
        let latest = dir.join("latest.ckpt");
        w.submit(buf, vec![step.clone(), latest.clone()], None).unwrap();
        w.wait_idle().unwrap();
        assert_eq!(std::fs::read(&step).unwrap(), b"checkpoint-image-bytes");
        assert_eq!(std::fs::read(&latest).unwrap(), b"checkpoint-image-bytes");
        assert!(!dir.join("step00000001.ckpt.tmp").exists());
        // The finished write's buffer came back to the pool, emptied but
        // with its allocation intact.
        let recycled = w.take_buffer();
        assert!(recycled.is_empty());
        assert!(recycled.capacity() >= b"checkpoint-image-bytes".len());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ckpt_writer_degrades_loudly_and_recovers_on_later_success() {
        let dir = scratch_dir("writer-degrade");
        // A file where the target's parent dir should be makes create_dir_all fail.
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, b"file, not dir").unwrap();
        let w = CkptWriter::new();
        w.submit(b"image-1".to_vec(), vec![blocker.join("sub").join("x.ckpt")], None).unwrap();
        // The writer degrades but keeps accepting work...
        let err = w.wait_idle().unwrap_err();
        assert!(format!("{err:#}").contains("degraded"), "{err:#}");
        assert!(w.degraded().is_some());
        // ...and a later successful publish clears the degradation: the
        // run ends clean, with the *newer* image durable.
        let good = dir.join("ok.ckpt");
        w.submit(b"image-2".to_vec(), vec![good.clone()], None).unwrap();
        w.wait_idle().unwrap();
        assert!(w.degraded().is_none());
        assert_eq!(std::fs::read(&good).unwrap(), b"image-2");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn degraded_writer_lands_retained_image_on_final_retry() {
        let dir = scratch_dir("writer-retry");
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, b"file, not dir").unwrap();
        let target = blocker.join("x.ckpt"); // parent is a file → publish fails
        let w = CkptWriter::new();
        w.submit(b"retained-image".to_vec(), vec![target.clone()], None).unwrap();
        // Wait for the writer thread to process (and degrade on) the job
        // without triggering wait_idle's final retry yet.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while w.degraded().is_none() {
            assert!(std::time::Instant::now() < deadline, "writer never degraded");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        // The obstruction clears (disk freed, mount back): the end-of-run
        // final retry lands the retained image and the run exits clean.
        std::fs::remove_file(&blocker).unwrap();
        w.wait_idle().unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"retained-image");
        assert!(w.degraded().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn retention_prunes_oldest_step_checkpoints_only() {
        let dir = scratch_dir("retain");
        let w = CkptWriter::new();
        for step in 1..=5u64 {
            let p = dir.join(format!("step-{step:08}.ckpt"));
            w.submit(
                vec![step as u8],
                vec![p, dir.join("latest.ckpt")],
                Some((dir.clone(), 2)),
            )
            .unwrap();
            // Serialize each publish so pruning order is deterministic.
            w.wait_idle().unwrap();
        }
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        assert_eq!(names, ["latest.ckpt", "step-00000004.ckpt", "step-00000005.ckpt"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn list_step_checkpoints_sorts_and_ignores_other_files() {
        let dir = scratch_dir("list");
        std::fs::write(dir.join("step-00000020.ckpt"), b"b").unwrap();
        std::fs::write(dir.join("step-00000003.ckpt"), b"a").unwrap();
        std::fs::write(dir.join("latest.ckpt"), b"l").unwrap();
        std::fs::write(dir.join("step-xx.ckpt"), b"junk").unwrap();
        let steps = list_step_checkpoints(&dir).unwrap();
        assert_eq!(steps.iter().map(|(s, _)| *s).collect::<Vec<_>>(), [3, 20]);
        assert!(list_step_checkpoints(dir.join("missing")).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
