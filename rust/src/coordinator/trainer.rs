//! The optimizer-step loop: gradient accumulation, GNS tracking,
//! schedule-driven batch sizing, telemetry.
//!
//! One optimizer step (paper Sections 3–5):
//! 1. Decide accumulation steps A from the batch-size schedule (possibly
//!    GNS-adaptive).
//! 2. Run A * ranks microbatches through `grad_step`, accumulating the
//!    gradients on device and folding each stats vector into a
//!    [`GnsAccumulator`] (the per-example ||G_Bsmall||^2 component).
//! 3. Compute per-layer-type ||G_Bbig||^2 on the accumulated gradient via
//!    `grad_sqnorms` (one cheap artifact call).
//! 4. Update the [`GnsTracker`] (EMA of Eqs. 4/5 per layer type).
//! 5. AdamW with grad_scale = 1/(A * ranks).

use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::{CorpusGenerator, Loader};
use crate::gns::{GnsAccumulator, GnsTracker};
use crate::runtime::BackendFactory;
use crate::schedule::GnsController;
use crate::telemetry::{CsvLogger, TRAIN_HEADER};
use crate::{N_TYPES, STATS_ORDER};

use super::runner::ModelRunner;

/// Per-step record kept in memory (mirrors the CSV schema).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub tokens: u64,
    pub loss: f64,
    pub lr: f64,
    pub accum: usize,
    pub b_big: f64,
    /// Raw (unsmoothed) per-type (g_sq, s) component pairs + total.
    pub raw_g_sq: [f64; N_TYPES],
    pub raw_s: [f64; N_TYPES],
    pub raw_g_sq_total: f64,
    pub raw_s_total: f64,
    pub gns_layernorm: f64,
    pub gns_total: f64,
    pub step_ms: f64,
}

pub struct TrainOutcome {
    pub records: Vec<StepRecord>,
    pub final_loss: f64,
    pub tokens: u64,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub runner: ModelRunner,
    loaders: Vec<Loader>,
    controller: GnsController,
    pub tracker: GnsTracker,
    tokens: u64,
    /// Multiplier on the scheduled LR (Fig. 6 temperature interventions).
    pub lr_scale: f64,
}

/// Deep copy of everything a [`Trainer`] mutates, for run forking (Fig. 6
/// restarts mid-training runs with varied LR / batch size).
#[derive(Clone)]
pub struct TrainerSnapshot {
    runner: crate::coordinator::runner::RunnerSnapshot,
    loaders: Vec<Loader>,
    controller: GnsController,
    tracker: GnsTracker,
    tokens: u64,
}

impl Trainer {
    pub fn new(factory: &dyn BackendFactory, cfg: TrainConfig) -> Result<Self> {
        let mut runner = ModelRunner::new(factory, &cfg.model)?;
        runner.init(cfg.seed as i32)?;
        let text = CorpusGenerator::new(cfg.seed).generate(cfg.corpus_bytes);
        let base = Loader::new(&text, runner.entry.seq_len, cfg.seed);
        let loaders: Vec<Loader> = (0..cfg.ranks.max(1) as u64).map(|r| base.for_rank(r)).collect();
        let controller = GnsController::new(cfg.batch_size.clone());
        let tracker = GnsTracker::new(&STATS_ORDER, cfg.gns_alpha);
        Ok(Self { cfg, runner, loaders, controller, tracker, tokens: 0, lr_scale: 1.0 })
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn snapshot(&self) -> TrainerSnapshot {
        TrainerSnapshot {
            runner: self.runner.snapshot(),
            loaders: self.loaders.clone(),
            controller: self.controller.clone(),
            tracker: self.tracker.clone(),
            tokens: self.tokens,
        }
    }

    pub fn restore(&mut self, s: TrainerSnapshot) {
        self.runner.restore(s.runner);
        self.loaders = s.loaders;
        self.controller = s.controller;
        self.tracker = s.tracker;
        self.tokens = s.tokens;
    }

    /// Replace the batch-size schedule mid-run (Fig. 6 interventions),
    /// seeding the controller's hysteresis at `start_accum`.
    pub fn set_batch_schedule(
        &mut self,
        s: crate::schedule::BatchSizeSchedule,
        start_accum: usize,
    ) {
        self.controller = GnsController::with_start(s, start_accum);
    }

    /// Run one optimizer step; returns its record.
    pub fn step(&mut self) -> Result<StepRecord> {
        let t0 = Instant::now();
        let mb = self.runner.entry.microbatch;
        let seq = self.runner.entry.seq_len;
        let accum = self.controller.decide(self.tokens, self.tracker.gns_total(), mb);
        let ranks = self.cfg.ranks.max(1);

        // Leased from the runner's gradient arena: after the first step
        // the accumulator is re-zeroed in place instead of reallocated
        // (grad_step's own output buffers are still per-call — GradOut
        // hands them to the caller by value).
        let mut acc = self.runner.lease_zero_grads()?;
        let mut gns_acc = GnsAccumulator::new(N_TYPES, mb);
        let mut loss_sum = 0f64;
        let mut n_micro = 0usize;
        for rank in 0..ranks {
            for _ in 0..accum {
                let batch = self.loaders[rank].next_batch(mb);
                let out = self.runner.grad_microbatch(&batch)?;
                gns_acc.add_microbatch(&out.stats);
                acc = self.runner.accumulate(acc, &out.grads)?;
                self.runner.recycle_grads(out.grads);
                loss_sum += out.loss as f64;
                n_micro += 1;
            }
        }
        let scale = 1.0 / n_micro as f64;

        // Big-batch component: norms of the *mean* gradient = norms of the
        // sum scaled by 1/n_micro (norms scale quadratically).
        let sums = self.runner.grad_sqnorms(&acc)?;
        let mut big_sq = [0f64; N_TYPES];
        for (d, s) in big_sq.iter_mut().zip(sums) {
            *d = s * scale * scale;
        }
        let (small_sq, _) = gns_acc.finish();
        let b_big = (mb * accum * ranks) as f64;
        self.tracker.observe(b_big, &big_sq, &small_sq);

        let lr = self.cfg.lr.at(self.runner.step) * self.lr_scale;
        self.runner.adamw_update(&acc, lr, scale)?;
        self.runner.recycle_grads(acc);
        self.tokens += (n_micro * mb * seq) as u64;

        let mut raw_g_sq = [0f64; N_TYPES];
        let mut raw_s = [0f64; N_TYPES];
        for (i, c) in self.tracker.last_raw.iter().enumerate() {
            raw_g_sq[i] = c.g_sq;
            raw_s[i] = c.s;
        }
        let ct = self.tracker.last_raw_total.unwrap();
        Ok(StepRecord {
            step: self.runner.step,
            tokens: self.tokens,
            loss: loss_sum / n_micro as f64,
            lr,
            accum,
            b_big,
            raw_g_sq,
            raw_s,
            raw_g_sq_total: ct.g_sq,
            raw_s_total: ct.s,
            gns_layernorm: self.tracker.gns_of("layernorm").unwrap_or(f64::NAN),
            gns_total: self.tracker.gns_total().unwrap_or(f64::NAN),
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Evaluation loss averaged over `n` held-out batches.
    pub fn eval(&mut self, n: usize) -> Result<f64> {
        let mb = self.runner.entry.microbatch;
        let mut loader = self.loaders[0].for_rank(u64::MAX); // held-out stream
        let mut sum = 0f64;
        for _ in 0..n {
            sum += self.runner.eval(&loader.next_batch(mb))? as f64;
        }
        Ok(sum / n as f64)
    }

    /// Full run per the config; logs CSV if configured.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let mut logger = if self.cfg.metrics_path.is_empty() {
            None
        } else {
            Some(CsvLogger::to_file(&self.cfg.metrics_path, TRAIN_HEADER)?)
        };
        let mut records = Vec::with_capacity(self.cfg.steps as usize);
        for _ in 0..self.cfg.steps {
            let rec = self.step()?;
            if let Some(log) = logger.as_mut() {
                log.row(&record_row(&rec))?;
            }
            records.push(rec);
        }
        if let Some(log) = logger.as_mut() {
            log.flush()?;
        }
        let final_loss = records.last().map(|r| r.loss).unwrap_or(f64::NAN);
        Ok(TrainOutcome { final_loss, tokens: self.tokens, records })
    }
}

/// CSV row in `TRAIN_HEADER` order.
pub fn record_row(r: &StepRecord) -> Vec<f64> {
    let mut row = vec![
        r.step as f64,
        r.tokens as f64,
        r.loss,
        r.lr,
        r.accum as f64,
        r.b_big,
    ];
    for i in 0..N_TYPES {
        row.push(r.raw_g_sq[i]);
        row.push(r.raw_s[i]);
    }
    row.push(r.raw_g_sq_total);
    row.push(r.raw_s_total);
    row.push(r.gns_layernorm);
    row.push(r.gns_total);
    row.push(r.step_ms);
    row
}
