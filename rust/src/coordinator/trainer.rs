//! The optimizer-step loop: rank-parallel gradient accumulation, GNS
//! tracking, schedule-driven batch sizing, checkpointing, telemetry.
//!
//! One optimizer step (paper Sections 3–5):
//! 1. Decide accumulation steps A from the batch-size schedule (possibly
//!    GNS-adaptive).
//! 2. Run A * ranks microbatches through the rank-parallel engine
//!    ([`super::parallel::ParallelExecutor`]): each rank accumulates its
//!    A microbatches concurrently, stats fold into per-rank
//!    [`crate::gns::GnsAccumulator`]s, and the partials merge with a
//!    fixed-order tree reduction (bitwise worker-count invariant).
//! 3. Compute per-layer-type ||G_Bbig||^2 on the accumulated gradient via
//!    `grad_sqnorms` (one cheap artifact call).
//! 4. Update the [`GnsTracker`] (EMA of Eqs. 4/5 per layer type).
//! 5. AdamW with grad_scale = 1/(A * ranks).
//!
//! With `checkpoint_dir`/`checkpoint_every` set, [`Trainer::run`] writes a
//! full-state (v3) checkpoint every N steps; [`Trainer::resume`] rebuilds
//! a trainer from one and replays the uninterrupted trajectory bitwise.
//! Periodic checkpoints are serialized on the training thread but
//! *published* by [`checkpoint::CkptWriter`]'s background thread, so disk
//! never blocks [`Trainer::step`].
//!
//! Under `rank_mode = process` the engine is the elastic one
//! ([`super::elastic::ElasticExecutor`]): a rank dying mid-step surfaces
//! as [`RankOutcome::Lost`], and [`Trainer::step`] reconciles — drop the
//! dead positions, rewind the batch-size controller (the failed attempt
//! must not advance hysteresis), retry on the survivors. Loader cursors
//! only move on success, so the surviving ranks' trajectories stay
//! bitwise identical to a thread-mode run at the reduced rank count.
//!
//! Dropped ranks are *parked*, not discarded: the supervisor respawns
//! dead workers with capped exponential backoff, and when one completes
//! its handshake the trainer re-admits the parked loaders at the next
//! step boundary ([`Trainer::step`] polls [`ElasticExecutor::try_rejoin`]
//! before deciding the batch size). From the rejoin boundary on, the
//! trajectory is bitwise identical to a full-rank run that dropped and
//! re-added the same positions at the same step boundaries.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::config::{RankMode, TrainConfig};
use crate::data::{CorpusGenerator, Loader};
use crate::gns::{GnsComponents, GnsTracker};
use crate::runtime::{Backend, BackendFactory, Buffer};
use crate::schedule::GnsController;
use crate::telemetry::{CsvLogger, TRAIN_HEADER};
use crate::{N_TYPES, STATS_ORDER};

use super::checkpoint;
use super::elastic::{ElasticExecutor, RankHealth, RankOutcome};
use super::parallel::ParallelExecutor;
use super::runner::ModelRunner;

/// Per-step record kept in memory (mirrors the CSV schema).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub tokens: u64,
    pub loss: f64,
    pub lr: f64,
    pub accum: usize,
    pub b_big: f64,
    /// Raw (unsmoothed) per-type (g_sq, s) component pairs + total.
    pub raw_g_sq: [f64; N_TYPES],
    pub raw_s: [f64; N_TYPES],
    pub raw_g_sq_total: f64,
    pub raw_s_total: f64,
    pub gns_layernorm: f64,
    pub gns_total: f64,
    pub step_ms: f64,
}

pub struct TrainOutcome {
    pub records: Vec<StepRecord>,
    pub final_loss: f64,
    pub tokens: u64,
}

/// What a [`StepObserver`] sees after each optimizer step: the step's
/// record plus the smoothed tracker/controller state the record alone
/// does not carry (per-layer GNS EMAs, hysteresis anchor).
pub struct StepObservation<'a> {
    pub record: &'a StepRecord,
    pub gns: crate::gns::GnsSnapshot,
    /// Batch-size controller hysteresis anchor after this step.
    pub accum: usize,
    /// Total step budget of the run (`cfg.steps`).
    pub total_steps: u64,
    /// Per-rank liveness after this step (see [`Trainer::rank_health`]).
    pub ranks: Vec<RankHealth>,
    /// Sticky checkpoint-writer degradation, if the last publish failed
    /// and no retry has landed yet (surfaced on the serve daemon's
    /// `/health`; the run itself exits nonzero if it never recovers).
    pub checkpoint_error: Option<String>,
}

/// Step-by-step consumer of a training run ([`Trainer::run_with_observer`]).
///
/// The observer is called *after* the step's CSV row is logged and any
/// due checkpoint is written, so attaching one cannot perturb the
/// run's on-disk telemetry; a `serve` daemon publishing live state is
/// just one observer, not a special case in the loop. Returning `true`
/// from [`StepObserver::stop_requested`] ends the run gracefully at the
/// next step boundary (the outcome keeps every completed step).
pub trait StepObserver: Sync {
    fn on_step(&self, obs: &StepObservation<'_>);
    fn stop_requested(&self) -> bool {
        false
    }
}

/// Rank-execution engine behind [`Trainer::step`]: scoped threads
/// in-process, or supervised child processes (elastic). Both feed the
/// same fixed-order tree reduction, so at equal rank count they are
/// bitwise interchangeable; only the process engine can report
/// [`RankOutcome::Lost`].
enum Engine {
    Threads(ParallelExecutor),
    Process(ElasticExecutor),
}

impl Engine {
    fn rank_step(
        &mut self,
        params: &[Buffer],
        loaders: &mut [Loader],
        accum: usize,
        collect_rank_norms: bool,
    ) -> Result<RankOutcome> {
        match self {
            Engine::Threads(ex) => {
                Ok(RankOutcome::Done(ex.rank_step(params, loaders, accum, collect_rank_norms)?))
            }
            Engine::Process(ex) => ex.rank_step(params, loaders, accum, collect_rank_norms),
        }
    }

    fn backend(&self) -> &dyn Backend {
        match self {
            Engine::Threads(ex) => ex.backend(),
            Engine::Process(ex) => ex.backend(),
        }
    }

    fn recycle(&self, grads: Vec<Buffer>) {
        match self {
            Engine::Threads(ex) => ex.recycle(grads),
            // Process-mode gradient sets were rebuilt from wire bytes;
            // nothing pools them.
            Engine::Process(_) => {}
        }
    }

    fn workers(&self) -> usize {
        match self {
            Engine::Threads(ex) => ex.workers(),
            Engine::Process(ex) => ex.workers(),
        }
    }
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub runner: ModelRunner,
    engine: Engine,
    loaders: Vec<Loader>,
    /// Original rank label of each live loader (always sorted ascending;
    /// rejoin inserts loaders back at their label-ordered position).
    live_origs: Vec<usize>,
    /// Loaders of dropped ranks, keyed by original rank label, kept so a
    /// respawned worker resumes its exact data stream on rejoin.
    parked: Vec<(usize, Loader)>,
    controller: GnsController,
    pub tracker: GnsTracker,
    tokens: u64,
    /// Multiplier on the scheduled LR (Fig. 6 temperature interventions).
    pub lr_scale: f64,
    /// Background checkpoint writer, spawned lazily by the first
    /// [`Trainer::checkpoint_now`].
    ckpt_writer: Option<checkpoint::CkptWriter>,
}

/// Deep copy of everything a [`Trainer`] mutates, for run forking (Fig. 6
/// restarts mid-training runs with varied LR / batch size).
#[derive(Clone)]
pub struct TrainerSnapshot {
    runner: crate::coordinator::runner::RunnerSnapshot,
    loaders: Vec<Loader>,
    live_origs: Vec<usize>,
    parked: Vec<(usize, Loader)>,
    controller: GnsController,
    tracker: GnsTracker,
    tokens: u64,
}

impl Trainer {
    /// Trainer with the env-default rank-worker count
    /// (`NANOGNS_RANK_WORKERS`; see [`super::parallel::rank_workers`]).
    pub fn new(factory: &dyn BackendFactory, cfg: TrainConfig) -> Result<Self> {
        let workers = super::parallel::rank_workers(cfg.ranks.max(1));
        Self::with_rank_workers(factory, cfg, workers)
    }

    /// Trainer with an explicit rank-worker count (the invariance tests
    /// compare worker counts without touching the environment).
    pub fn with_rank_workers(
        factory: &dyn BackendFactory,
        cfg: TrainConfig,
        workers: usize,
    ) -> Result<Self> {
        let mut runner = ModelRunner::new(factory, &cfg.model)?;
        runner.init(cfg.seed as i32)?;
        let ranks = cfg.ranks.max(1);
        let engine = match cfg.rank_mode {
            RankMode::Threads => Engine::Threads(ParallelExecutor::with_workers(
                factory, &cfg.model, ranks, workers,
            )?),
            RankMode::Process => Engine::Process(ElasticExecutor::launch(factory, &cfg, workers)?),
        };
        let text = CorpusGenerator::new(cfg.seed).generate(cfg.corpus_bytes);
        let base = Loader::new(&text, runner.entry.seq_len, cfg.seed);
        let loaders: Vec<Loader> = (0..ranks as u64).map(|r| base.for_rank(r)).collect();
        let controller = GnsController::new(cfg.batch_size.clone());
        let tracker = GnsTracker::new(&STATS_ORDER, cfg.gns_alpha);
        Ok(Self {
            cfg,
            runner,
            engine,
            loaders,
            live_origs: (0..ranks).collect(),
            parked: Vec::new(),
            controller,
            tracker,
            tokens: 0,
            lr_scale: 1.0,
            ckpt_writer: None,
        })
    }

    /// Rebuild a trainer from a full-state checkpoint; the resumed run
    /// continues the interrupted trajectory bitwise-exactly. If the named
    /// checkpoint is corrupt or truncated, resume falls back down the
    /// retained `step-*.ckpt` chain to the newest sibling that passes the
    /// integrity check (see [`Trainer::load_checkpoint_chain`]).
    pub fn resume(
        factory: &dyn BackendFactory,
        cfg: TrainConfig,
        path: impl AsRef<Path>,
    ) -> Result<Self> {
        let mut tr = Self::new(factory, cfg)?;
        tr.load_checkpoint_chain(path)?;
        Ok(tr)
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    /// Current live rank count (drops below `cfg.ranks` after elastic
    /// reconciliation).
    pub fn ranks(&self) -> usize {
        self.loaders.len()
    }

    /// Rank-parallel workers in use (threads or live worker processes).
    pub fn rank_workers(&self) -> usize {
        self.engine.workers()
    }

    /// Per-rank liveness for the serve daemon's `/ranks` endpoint. Thread
    /// mode synthesizes always-alive entries (ranks share this process);
    /// process mode reports real worker heartbeats and pids.
    pub fn rank_health(&self) -> Vec<RankHealth> {
        match &self.engine {
            Engine::Process(ex) => ex.health(),
            Engine::Threads(_) => (0..self.loaders.len())
                .map(|rank| RankHealth {
                    rank,
                    alive: true,
                    pid: None,
                    last_step: self.runner.step,
                    heartbeat_age_ms: None,
                    respawns: 0,
                    mode: "thread",
                })
                .collect(),
        }
    }

    /// Pids of live rank-worker processes (process mode only; the
    /// fault-injection tests pick their kill victim from here).
    pub fn elastic_worker_pids(&self) -> Option<Vec<u32>> {
        match &self.engine {
            Engine::Process(ex) => Some(ex.worker_pids()),
            Engine::Threads(_) => None,
        }
    }

    /// Drop rank positions (sorted or not; deduped here) from the run:
    /// their loaders are *parked* (keyed by original rank label, so a
    /// later rejoin resumes the exact data stream), survivors keep their
    /// own streams, and the elastic engine (if any) remaps its worker
    /// assignments. Thread mode accepts this too — the invariance tests
    /// use it to build the reduced-rank control trajectory.
    pub fn drop_ranks(&mut self, lost: &[usize]) -> Result<()> {
        let mut lost = lost.to_vec();
        lost.sort_unstable();
        lost.dedup();
        ensure!(!lost.is_empty(), "drop_ranks: no ranks named");
        ensure!(
            lost.iter().all(|&p| p < self.loaders.len()),
            "drop_ranks: position out of range (have {} ranks)",
            self.loaders.len()
        );
        ensure!(lost.len() < self.loaders.len(), "drop_ranks: cannot drop every rank");
        for &p in lost.iter().rev() {
            let loader = self.loaders.remove(p);
            let orig = self.live_origs.remove(p);
            self.parked.push((orig, loader));
        }
        if let Engine::Process(ex) = &mut self.engine {
            ex.confirm_loss(&lost);
        }
        Ok(())
    }

    /// Re-admit previously dropped ranks (named by original rank label)
    /// at a step boundary: each parked loader is re-inserted at its
    /// label-ordered position, so the rank layout matches a run that
    /// never renumbered. Thread mode accepts this too — the rejoin
    /// invariance test uses it to build the full-rank control trajectory.
    pub fn readmit_ranks(&mut self, origs: &[usize]) -> Result<()> {
        for &orig in origs {
            let idx = self
                .parked
                .iter()
                .position(|(o, _)| *o == orig)
                .ok_or_else(|| anyhow!("readmit_ranks: rank {orig} is not parked"))?;
            let (orig, loader) = self.parked.remove(idx);
            let at = self.live_origs.iter().position(|&o| o > orig).unwrap_or(self.live_origs.len());
            self.live_origs.insert(at, orig);
            self.loaders.insert(at, loader);
        }
        Ok(())
    }

    /// Elastic only: give respawned workers a chance to rejoin at this
    /// step boundary. The supervisor owns the respawn/backoff state;
    /// this just mirrors a successful rejoin into the loader set.
    fn poll_rejoin(&mut self) -> Result<()> {
        let report = match &mut self.engine {
            Engine::Process(ex) if !self.parked.is_empty() => ex.try_rejoin(),
            _ => return Ok(()),
        };
        if !report.rejoined.is_empty() {
            eprintln!(
                "elastic: re-admitting rank(s) {:?} at step boundary (step {})",
                report.rejoined, self.runner.step
            );
            self.readmit_ranks(&report.rejoined)?;
        }
        Ok(())
    }

    /// Everything [`checkpoint::encode_state`] serializes, borrowed from
    /// the live trainer (the model-sized buffer sets are never cloned).
    fn state_view(&self) -> checkpoint::TrainStateView<'_> {
        let (m, v) = self.runner.moments();
        checkpoint::TrainStateView {
            model: &self.cfg.model,
            norm_kind: self.cfg.norm(),
            norm_placement: self.cfg.placement(),
            seed: self.cfg.seed,
            corpus_bytes: self.cfg.corpus_bytes as u64,
            step: self.runner.step,
            tokens: self.tokens,
            lr_scale: self.lr_scale,
            controller_last: self.controller.last(),
            tracker: self.tracker.export_state(),
            loaders: self.loaders.iter().map(Loader::cursor).collect(),
            params: &self.runner.params,
            m,
            v,
        }
    }

    /// Write a full-state (v3) checkpoint of this trainer, synchronously
    /// on the calling thread.
    pub fn save_checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        checkpoint::save_state(path, &self.runner.entry, &self.state_view())
    }

    /// Block until every queued async checkpoint write has been
    /// published, surfacing the first write error if one occurred. A
    /// trainer that never checkpointed asynchronously returns
    /// immediately.
    pub fn wait_checkpoints(&self) -> Result<()> {
        match &self.ckpt_writer {
            Some(w) => w.wait_idle(),
            None => Ok(()),
        }
    }

    /// Sticky checkpoint-writer degradation: `Some(reason)` while the
    /// last background publish failed and no retry has landed (the serve
    /// daemon's `/health` reports this; [`Trainer::wait_checkpoints`]
    /// turns it into a hard error at end of run if it never recovers).
    pub fn checkpoint_degraded(&self) -> Option<String> {
        self.ckpt_writer.as_ref().and_then(|w| w.degraded())
    }

    /// Restore this trainer's mutable state from a full-state checkpoint.
    /// The trainer must have been built from the same config (model,
    /// ranks, seed, schedules) as the checkpointed run. Strict: a corrupt
    /// file is an error (no fallback; see
    /// [`Trainer::load_checkpoint_chain`]).
    pub fn load_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        // Never read under an in-flight background write.
        self.wait_checkpoints()?;
        let st = checkpoint::load_state(path, &self.runner.entry)?;
        self.apply_state(st)
    }

    /// [`Trainer::load_checkpoint`] with fallback down the retained
    /// checkpoint chain: if `path` fails the integrity check, every
    /// sibling `step-*.ckpt` is tried newest-first, each rejection logged
    /// loudly, until one validates.
    pub fn load_checkpoint_chain(&mut self, path: impl AsRef<Path>) -> Result<()> {
        self.wait_checkpoints()?;
        let path = path.as_ref();
        let (st, used, rejected) = checkpoint::load_state_chain(path, &self.runner.entry)?;
        for (p, why) in &rejected {
            eprintln!("checkpoint: WARNING: skipping {p:?}: {why}");
        }
        if used != path {
            eprintln!("checkpoint: fell back to {used:?} (newest checkpoint that validates)");
        }
        self.apply_state(st)
    }

    /// Apply a decoded [`checkpoint::TrainState`] to this trainer after
    /// checking it belongs to this run's config.
    fn apply_state(&mut self, st: checkpoint::TrainState) -> Result<()> {
        ensure!(
            st.model == self.cfg.model,
            "checkpoint is for model {:?}, config says {:?}",
            st.model,
            self.cfg.model
        );
        ensure!(
            st.norm_kind == self.cfg.norm() && st.norm_placement == self.cfg.placement(),
            "checkpoint was trained as {}/{}; config says {}/{} — the parameter layout and \
             trajectory differ across variants, resume refused",
            st.norm_kind,
            st.norm_placement,
            self.cfg.norm(),
            self.cfg.placement()
        );
        ensure!(
            st.seed == self.cfg.seed && st.corpus_bytes == self.cfg.corpus_bytes as u64,
            "checkpoint was trained with seed {} over {} corpus bytes; config says {} / {} — \
             resuming would silently fork the data stream",
            st.seed,
            st.corpus_bytes,
            self.cfg.seed,
            self.cfg.corpus_bytes
        );
        ensure!(
            st.loaders.len() == self.loaders.len(),
            "checkpoint has {} rank cursors, config has {} ranks",
            st.loaders.len(),
            self.loaders.len()
        );
        ensure!(
            st.tracker.types.as_slice() == self.tracker.types(),
            "checkpoint tracker types {:?} do not match",
            st.tracker.types
        );
        self.runner.set_state(st.params, st.m, st.v, st.step)?;
        self.tracker = GnsTracker::from_state(st.tracker);
        self.controller =
            GnsController::with_start(self.cfg.batch_size.clone(), st.controller_last);
        for (loader, cur) in self.loaders.iter_mut().zip(st.loaders) {
            loader.restore_cursor(cur);
        }
        self.tokens = st.tokens;
        self.lr_scale = st.lr_scale;
        Ok(())
    }

    /// Queue a `step-XXXXXXXX.ckpt` full-state checkpoint under
    /// `cfg.checkpoint_dir` plus the `latest.ckpt` pointer; returns the
    /// step-file path. The state is serialized here (into a recycled
    /// buffer) but *published* by the background [`checkpoint::CkptWriter`]
    /// — both files from the same image, each crash-safely (`.tmp` →
    /// fsync → rename → dir fsync) — so the training thread never waits
    /// on disk. [`Trainer::wait_checkpoints`] joins the outstanding
    /// writes; the run loop does so before returning.
    pub fn checkpoint_now(&mut self) -> Result<PathBuf> {
        ensure!(!self.cfg.checkpoint_dir.is_empty(), "no checkpoint_dir configured");
        let dir = Path::new(&self.cfg.checkpoint_dir);
        let path = dir.join(format!("step-{:08}.ckpt", self.runner.step));
        let latest = dir.join("latest.ckpt");
        if self.ckpt_writer.is_none() {
            self.ckpt_writer = Some(checkpoint::CkptWriter::new());
        }
        let writer = self.ckpt_writer.as_ref().expect("just initialized");
        let mut bytes = writer.take_buffer();
        checkpoint::encode_state(&self.runner.entry, &self.state_view(), &mut bytes)?;
        let retain = (self.cfg.checkpoint_keep_last > 0)
            .then(|| (dir.to_path_buf(), self.cfg.checkpoint_keep_last));
        writer.submit(bytes, vec![path.clone(), latest], retain)?;
        Ok(path)
    }

    pub fn snapshot(&self) -> TrainerSnapshot {
        TrainerSnapshot {
            runner: self.runner.snapshot(),
            loaders: self.loaders.clone(),
            live_origs: self.live_origs.clone(),
            parked: self.parked.clone(),
            controller: self.controller.clone(),
            tracker: self.tracker.clone(),
            tokens: self.tokens,
        }
    }

    pub fn restore(&mut self, s: TrainerSnapshot) {
        self.runner.restore(s.runner);
        self.loaders = s.loaders;
        self.live_origs = s.live_origs;
        self.parked = s.parked;
        self.controller = s.controller;
        self.tracker = s.tracker;
        self.tokens = s.tokens;
    }

    /// Replace the batch-size schedule mid-run (Fig. 6 interventions),
    /// seeding the controller's hysteresis at `start_accum`.
    pub fn set_batch_schedule(
        &mut self,
        s: crate::schedule::BatchSizeSchedule,
        start_accum: usize,
    ) {
        self.controller = GnsController::with_start(s, start_accum);
    }

    /// Run one optimizer step; returns its record.
    ///
    /// Under the elastic engine a rank dying mid-step does not fail the
    /// step: the attempt had no side effects (cursors only advance on
    /// success), so the trainer rewinds the batch-size controller, drops
    /// the dead positions, and retries on the survivors.
    pub fn step(&mut self) -> Result<StepRecord> {
        let t0 = Instant::now();
        // Step boundary: respawned workers (if any) rejoin here, before
        // the controller decides this step's batch size, so the rejoined
        // trajectory matches a full-rank run from this step onward.
        self.poll_rejoin()?;
        let mb = self.runner.entry.microbatch;
        let seq = self.runner.entry.seq_len;
        let (out, accum) = loop {
            // Snapshot the controller before `decide`: its hysteresis
            // state must advance exactly once per *successful* step, or
            // the post-drop trajectory would fork from the thread-mode
            // control run.
            let controller = self.controller.clone();
            let accum = self.controller.decide(self.tokens, self.tracker.gns_total(), mb);

            // Rank-parallel accumulation: every rank's `accum` microbatches
            // run concurrently on the engine's workers, and the per-rank
            // gradient/stats partials merge with the fixed-order tree
            // reduction (bitwise identical for any worker count).
            match self.engine.rank_step(&self.runner.params, &mut self.loaders, accum, false)? {
                RankOutcome::Done(out) => break (out, accum),
                RankOutcome::Lost(lost) => {
                    self.controller = controller;
                    eprintln!(
                        "elastic: dropped rank(s) {lost:?}; retrying step on {} survivor(s)",
                        self.loaders.len() - lost.len()
                    );
                    self.drop_ranks(&lost)?;
                }
            }
        };
        let ranks = self.loaders.len();
        let n_micro = out.n_micro;
        let acc = out.grads;
        let scale = 1.0 / n_micro as f64;

        // Big-batch component: norms of the *mean* gradient = norms of the
        // sum scaled by 1/n_micro (norms scale quadratically).
        let sums = self.runner.grad_sqnorms(&acc)?;
        let mut big_sq = [0f64; N_TYPES];
        for (d, s) in big_sq.iter_mut().zip(sums) {
            *d = s * scale * scale;
        }
        let (small_sq, _) = out.stats.finish();
        let b_big = (mb * accum * ranks) as f64;
        self.tracker.observe(b_big, &big_sq, &small_sq);

        let lr = self.cfg.lr.at(self.runner.step) * self.lr_scale;
        self.runner.adamw_update(&acc, lr, scale)?;
        self.engine.recycle(acc);
        self.tokens += (n_micro * mb * seq) as u64;

        let mut raw_g_sq = [f64::NAN; N_TYPES];
        let mut raw_s = [f64::NAN; N_TYPES];
        for (i, c) in self.tracker.last_raw.iter().enumerate() {
            raw_g_sq[i] = c.g_sq;
            raw_s[i] = c.s;
        }
        // A tracker that never observed anything reports NaN components
        // (the estimator's degenerate-input convention) instead of
        // panicking on the unwrap.
        let ct = self
            .tracker
            .last_raw_total
            .unwrap_or(GnsComponents { g_sq: f64::NAN, s: f64::NAN });
        Ok(StepRecord {
            step: self.runner.step,
            tokens: self.tokens,
            loss: out.loss_sum / n_micro as f64,
            lr,
            accum,
            b_big,
            raw_g_sq,
            raw_s,
            raw_g_sq_total: ct.g_sq,
            raw_s_total: ct.s,
            gns_layernorm: self.tracker.gns_of("layernorm").unwrap_or(f64::NAN),
            gns_total: self.tracker.gns_total().unwrap_or(f64::NAN),
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Evaluation loss averaged over `n` held-out batches. Runs on the
    /// engine's primary worker backend so the runner's own backend never
    /// pays for an activation workspace.
    pub fn eval(&mut self, n: usize) -> Result<f64> {
        let mb = self.runner.entry.microbatch;
        let mut loader = self.loaders[0].for_rank(u64::MAX); // held-out stream
        let mut sum = 0f64;
        for _ in 0..n {
            sum += self.engine.backend().eval(&self.runner.params, &loader.next_batch(mb))? as f64;
        }
        Ok(sum / n as f64)
    }

    /// Full run per the config; logs CSV if configured, and writes
    /// full-state checkpoints every `checkpoint_every` steps when
    /// `checkpoint_dir` is set (plus `latest.ckpt`, the `--resume`
    /// convenience pointer). `cfg.steps` is the *total* step budget, so a
    /// resumed trainer runs only the remaining steps.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        self.run_with_observer(None)
    }

    /// [`Self::run`] with an optional per-step observer (see
    /// [`StepObserver`] for the call ordering and stop contract).
    pub fn run_with_observer(
        &mut self,
        observer: Option<&dyn StepObserver>,
    ) -> Result<TrainOutcome> {
        // Leftover `.ckpt.tmp` files are writes a previous process died
        // inside; the renamed-over checkpoints are still good, the tmps
        // are garbage.
        if !self.cfg.checkpoint_dir.is_empty() {
            for p in checkpoint::clean_stale_tmps(&self.cfg.checkpoint_dir)? {
                eprintln!("checkpoint: removed stale partial write {p:?}");
            }
        }
        // A resumed run keeps the rows logged before the interruption,
        // drops any logged *after* the checkpoint being resumed from
        // (they will be re-executed), and appends.
        let mut logger = if self.cfg.metrics_path.is_empty() {
            None
        } else if self.runner.step > 0 {
            let at = self.runner.step as f64;
            Some(CsvLogger::resume_file(&self.cfg.metrics_path, TRAIN_HEADER, at)?)
        } else {
            Some(CsvLogger::to_file(&self.cfg.metrics_path, TRAIN_HEADER)?)
        };
        let ckpt_every = self.cfg.checkpoint_every;
        let ckpt_dir = self.cfg.checkpoint_dir.clone();
        let remaining = self.cfg.steps.saturating_sub(self.runner.step) as usize;
        let mut records = Vec::with_capacity(remaining);
        while self.runner.step < self.cfg.steps {
            let rec = self.step()?;
            if let Some(log) = logger.as_mut() {
                log.row(&record_row(&rec))?;
            }
            let at_checkpoint = !ckpt_dir.is_empty()
                && ckpt_every > 0
                && (rec.step % ckpt_every == 0 || rec.step == self.cfg.steps);
            records.push(rec);
            if at_checkpoint {
                self.checkpoint_now()?;
            }
            if let Some(obs) = observer {
                let rec = records.last().expect("just pushed");
                obs.on_step(&StepObservation {
                    record: rec,
                    gns: self.tracker.snapshot(),
                    accum: self.controller.last(),
                    total_steps: self.cfg.steps,
                    ranks: self.rank_health(),
                    checkpoint_error: self.checkpoint_degraded(),
                });
                if obs.stop_requested() {
                    break;
                }
            }
        }
        if let Some(log) = logger.as_mut() {
            log.flush()?;
        }
        // Join outstanding background checkpoint writes before declaring
        // the run done (and surface any write failure).
        self.wait_checkpoints()?;
        let final_loss = records.last().map(|r| r.loss).unwrap_or(f64::NAN);
        Ok(TrainOutcome { final_loss, tokens: self.tokens, records })
    }
}

/// JSON object for one [`StepRecord`], keyed by the `TRAIN_HEADER`
/// column names so scripted consumers see one schema across the CSV,
/// `train --json`, and the serve daemon. Non-finite values (degenerate
/// GNS estimates) serialize as `null`, never as invalid JSON.
pub fn record_json(r: &StepRecord) -> crate::util::json::Value {
    use crate::util::json::Value;
    let mut m = std::collections::BTreeMap::new();
    m.insert("step".into(), Value::Num(r.step as f64));
    m.insert("tokens".into(), Value::Num(r.tokens as f64));
    m.insert("loss".into(), Value::finite_or_null(r.loss));
    m.insert("lr".into(), Value::finite_or_null(r.lr));
    m.insert("accum".into(), Value::Num(r.accum as f64));
    m.insert("b_big".into(), Value::Num(r.b_big));
    for (i, t) in STATS_ORDER.iter().enumerate() {
        m.insert(format!("gsq_{t}"), Value::finite_or_null(r.raw_g_sq[i]));
        m.insert(format!("s_{t}"), Value::finite_or_null(r.raw_s[i]));
    }
    m.insert("gsq_total".into(), Value::finite_or_null(r.raw_g_sq_total));
    m.insert("s_total".into(), Value::finite_or_null(r.raw_s_total));
    m.insert("gns_layernorm".into(), Value::finite_or_null(r.gns_layernorm));
    m.insert("gns_total".into(), Value::finite_or_null(r.gns_total));
    m.insert("step_ms".into(), Value::Num(r.step_ms));
    Value::Obj(m)
}

/// CSV row in `TRAIN_HEADER` order.
pub fn record_row(r: &StepRecord) -> Vec<f64> {
    let mut row = vec![
        r.step as f64,
        r.tokens as f64,
        r.loss,
        r.lr,
        r.accum as f64,
        r.b_big,
    ];
    for i in 0..N_TYPES {
        row.push(r.raw_g_sq[i]);
        row.push(r.raw_s[i]);
    }
    row.push(r.raw_g_sq_total);
    row.push(r.raw_s_total);
    row.push(r.gns_layernorm);
    row.push(r.gns_total);
    row.push(r.step_ms);
    row
}
