//! Simulated distributed-data-parallel GNS estimation (taxonomy: "DDP").
//!
//! In real DDP, each rank's gradient (over its local batch) is visible
//! just before all-reduce; its norm gives a `||G_Bsmall||` observation
//! with `B_small = local batch`. We reproduce those statistics exactly by
//! running each rank's microbatches sequentially and taking per-rank
//! gradient norms before averaging across ranks — the estimator sees the
//! same random variables a real cluster would produce (DESIGN.md
//! §Substitutions). Used by the Fig. 16 harness to cross-check the
//! per-example LayerNorm estimator against the DDP method.

use anyhow::Result;

use crate::data::Loader;
use crate::gns::{gns_components, GnsComponents};
use crate::runtime::Buffer;
use crate::N_TYPES;

use super::runner::ModelRunner;

/// One DDP-style observation across `ranks` simulated workers.
pub struct DdpObservation {
    /// per-layer-type components from the DDP estimator
    pub per_type: Vec<GnsComponents>,
    pub total: GnsComponents,
    /// mean loss across all microbatches
    pub loss: f64,
    /// the all-reduced (mean) gradient, for the optimizer to consume
    pub mean_grads: Vec<Buffer>,
    pub b_big: f64,
    pub b_small: f64,
}

/// Run one step of simulated DDP: `ranks` workers, each accumulating
/// `accum` microbatches, then "all-reduce" (average). Gradient norms are
/// measured per-rank (B_small = microbatch * accum) and on the averaged
/// gradient (B_big = B_small * ranks).
pub fn ddp_step(
    runner: &ModelRunner,
    loaders: &mut [Loader],
    accum: usize,
) -> Result<DdpObservation> {
    let mut sink = crate::gns::GnsAccumulator::new(N_TYPES, runner.entry.microbatch);
    ddp_step_with_stats(runner, loaders, accum, &mut sink)
}

/// [`ddp_step`] that also folds each microbatch's per-example stats vector
/// into `gns_acc`, so the per-example and DDP estimators can be compared
/// on identical sampled gradients (Fig. 16).
pub fn ddp_step_with_stats(
    runner: &ModelRunner,
    loaders: &mut [Loader],
    accum: usize,
    gns_acc: &mut crate::gns::GnsAccumulator,
) -> Result<DdpObservation> {
    let ranks = loaders.len();
    assert!(ranks >= 2, "DDP estimator needs >= 2 ranks");
    let mb = runner.entry.microbatch;

    let mut rank_sqnorms: Vec<[f64; N_TYPES]> = Vec::with_capacity(ranks);
    let mut all_acc: Option<Vec<Buffer>> = None;
    let mut loss_sum = 0f64;

    for loader in loaders.iter_mut() {
        let mut acc = runner.lease_zero_grads()?;
        for _ in 0..accum {
            let batch = loader.next_batch(mb);
            let out = runner.grad_microbatch(&batch)?;
            loss_sum += out.loss as f64;
            gns_acc.add_microbatch(&out.stats);
            acc = runner.accumulate(acc, &out.grads)?;
            runner.recycle_grads(out.grads);
        }
        // per-rank mean gradient norm: ||sum/accum||^2 = ||sum||^2/accum^2
        let sums = runner.grad_sqnorms(&acc)?;
        let scale = 1.0 / (accum as f64 * accum as f64);
        let mut sq = [0f64; N_TYPES];
        for (d, s) in sq.iter_mut().zip(sums) {
            *d = s * scale;
        }
        rank_sqnorms.push(sq);
        all_acc = Some(match all_acc {
            None => acc,
            Some(prev) => {
                let merged = runner.accumulate(prev, &acc)?;
                runner.recycle_grads(acc);
                merged
            }
        });
    }

    let n_micro = (ranks * accum) as f64;
    let mean_grads = all_acc.unwrap();
    let total_sums = runner.grad_sqnorms(&mean_grads)?;
    let b_small = (mb * accum) as f64;
    let b_big = b_small * ranks as f64;

    let mut per_type = Vec::with_capacity(N_TYPES);
    let mut tot_big = 0f64;
    let mut tot_small = 0f64;
    for t in 0..N_TYPES {
        let big = total_sums[t] / (n_micro * n_micro); // norm of the mean grad
        let small = rank_sqnorms.iter().map(|r| r[t]).sum::<f64>() / ranks as f64;
        per_type.push(gns_components(b_big, big, b_small, small));
        tot_big += big;
        tot_small += small;
    }
    let total = gns_components(b_big, tot_big, b_small, tot_small);

    Ok(DdpObservation {
        per_type,
        total,
        loss: loss_sum / n_micro,
        mean_grads,
        b_big,
        b_small,
    })
}
