//! Distributed-data-parallel GNS estimation (taxonomy: "DDP").
//!
//! In real DDP, each rank's gradient (over its local batch) is visible
//! just before all-reduce; its norm gives a `||G_Bsmall||` observation
//! with `B_small = local batch`. We reproduce those statistics exactly —
//! and, since PR 5, with genuinely parallel ranks: each rank's
//! accumulation loop runs on its own worker backend through
//! [`ParallelExecutor::rank_step`], which also hands back every rank's
//! pre-merge gradient squared norms. The estimator sees the same random
//! variables a real cluster would produce (DESIGN.md §Substitutions),
//! and the observation is bitwise identical for any
//! `NANOGNS_RANK_WORKERS` setting. Used by the Fig. 16 harness to
//! cross-check the per-example LayerNorm estimator against the DDP
//! method.

use anyhow::{ensure, Result};

use crate::data::Loader;
use crate::gns::{gns_components, GnsAccumulator, GnsComponents};
use crate::runtime::{Backend, Buffer};
use crate::N_TYPES;

use super::parallel::ParallelExecutor;

/// One DDP-style observation across `ranks` workers.
pub struct DdpObservation {
    /// per-layer-type components from the DDP estimator
    pub per_type: Vec<GnsComponents>,
    pub total: GnsComponents,
    /// mean loss across all microbatches
    pub loss: f64,
    /// the all-reduced gradient *sum* over every microbatch, for the
    /// optimizer to consume (scale by `1 / (ranks * accum)` for the mean)
    pub mean_grads: Vec<Buffer>,
    pub b_big: f64,
    pub b_small: f64,
}

/// Run one step of DDP: `loaders.len()` rank workers, each accumulating
/// `accum` microbatches in parallel, then "all-reduce" (the engine's
/// fixed-order tree merge). Gradient norms are measured per-rank
/// (B_small = microbatch * accum) and on the merged gradient
/// (B_big = B_small * ranks).
pub fn ddp_step(
    engine: &ParallelExecutor,
    params: &[Buffer],
    loaders: &mut [Loader],
    accum: usize,
) -> Result<DdpObservation> {
    let mut sink = GnsAccumulator::new(N_TYPES, engine.entry().microbatch);
    ddp_step_with_stats(engine, params, loaders, accum, &mut sink)
}

/// [`ddp_step`] that also folds the merged per-example stats of every
/// rank's microbatches into `gns_acc`, so the per-example and DDP
/// estimators can be compared on identical sampled gradients (Fig. 16).
pub fn ddp_step_with_stats(
    engine: &ParallelExecutor,
    params: &[Buffer],
    loaders: &mut [Loader],
    accum: usize,
    gns_acc: &mut GnsAccumulator,
) -> Result<DdpObservation> {
    let ranks = loaders.len();
    ensure!(ranks >= 2, "DDP estimator needs >= 2 ranks");
    let mb = engine.entry().microbatch;

    let out = engine.rank_step(params, loaders, accum, true)?;
    gns_acc.merge(&out.stats);
    let rank_sums = out.rank_sqnorms.expect("rank norms requested");

    // per-rank mean gradient norm: ||sum/accum||^2 = ||sum||^2/accum^2
    let rank_scale = 1.0 / (accum as f64 * accum as f64);
    let n_micro = out.n_micro as f64;
    let total_sums = engine.backend().grad_sqnorms(&out.grads)?;
    let b_small = (mb * accum) as f64;
    let b_big = b_small * ranks as f64;

    let mut per_type = Vec::with_capacity(N_TYPES);
    let mut tot_big = 0f64;
    let mut tot_small = 0f64;
    for t in 0..N_TYPES {
        let big = total_sums[t] / (n_micro * n_micro); // norm of the mean grad
        let small =
            rank_sums.iter().map(|r| r[t] * rank_scale).sum::<f64>() / ranks as f64;
        per_type.push(gns_components(b_big, big, b_small, small));
        tot_big += big;
        tot_small += small;
    }
    let total = gns_components(b_big, tot_big, b_small, tot_small);

    Ok(DdpObservation {
        per_type,
        total,
        loss: out.loss_sum / n_micro,
        mean_grads: out.grads,
        b_big,
        b_small,
    })
}
