//! Model runner: training state + backend dispatch for one model config.
//!
//! Owns parameters and AdamW moments as opaque [`Buffer`]s and forwards
//! the compute to whichever [`Backend`] it was built with (reference or
//! PJRT). The backend itself is stateless, so snapshot/restore (run
//! forking, Fig. 6) and checkpointing are pure buffer copies.

use anyhow::{ensure, Result};

use crate::data::Batch;
use crate::runtime::{Backend, BackendFactory, Buffer, ModelEntry};
use crate::N_TYPES;

pub use crate::runtime::backend::GradOut;

/// Deep copy of a runner's mutable state.
#[derive(Clone)]
pub struct RunnerSnapshot {
    params: Vec<Buffer>,
    m: Vec<Buffer>,
    v: Vec<Buffer>,
    step: u64,
}

/// Owns parameters + optimizer state and runs them through a backend.
pub struct ModelRunner {
    backend: Box<dyn Backend>,
    pub entry: ModelEntry,
    pub params: Vec<Buffer>,
    m: Vec<Buffer>,
    v: Vec<Buffer>,
    /// Optimizer step count (1-based after first update).
    pub step: u64,
}

impl ModelRunner {
    pub fn new(factory: &dyn BackendFactory, model: &str) -> Result<Self> {
        Ok(Self::from_backend(factory.create(model)?))
    }

    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        let entry = backend.entry().clone();
        Self { backend, entry, params: Vec::new(), m: Vec::new(), v: Vec::new(), step: 0 }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn n_params_tensors(&self) -> usize {
        self.entry.params.len()
    }

    /// Initialize parameters and zero optimizer state from a seed.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let out = self.backend.init(seed)?;
        ensure!(
            out.len() == self.entry.params.len(),
            "init returned {} tensors, model has {}",
            out.len(),
            self.entry.params.len()
        );
        self.m = self.backend.zero_grads()?;
        self.v = self.backend.zero_grads()?;
        self.params = out;
        self.step = 0;
        Ok(())
    }

    /// Replace parameters (e.g. from a checkpoint); resets Adam state.
    pub fn set_params(&mut self, params: Vec<Buffer>) -> Result<()> {
        ensure!(params.len() == self.entry.params.len(), "param count mismatch");
        self.m = self.backend.zero_grads()?;
        self.v = self.backend.zero_grads()?;
        self.params = params;
        self.step = 0;
        Ok(())
    }

    fn check_batch(&self, batch: &Batch) -> Result<()> {
        ensure!(
            batch.batch == self.entry.microbatch && batch.seq_len == self.entry.seq_len,
            "batch shape ({}, {}) != model shape ({}, {})",
            batch.batch,
            batch.seq_len,
            self.entry.microbatch,
            self.entry.seq_len
        );
        Ok(())
    }

    /// Forward+backward on one microbatch: loss, gradients, GNS stats.
    pub fn grad_microbatch(&self, batch: &Batch) -> Result<GradOut> {
        self.check_batch(batch)?;
        self.backend.grad_step(&self.params, batch)
    }

    /// acc += grads (element-wise over the whole parameter list).
    pub fn accumulate(&self, acc: Vec<Buffer>, grads: &[Buffer]) -> Result<Vec<Buffer>> {
        self.backend.accumulate(acc, grads)
    }

    /// Per-layer-type squared norms of a gradient set (Eq. 4's big-batch
    /// component, computed on the accumulated gradient).
    pub fn grad_sqnorms(&self, grads: &[Buffer]) -> Result<[f64; N_TYPES]> {
        self.backend.grad_sqnorms(grads)
    }

    /// AdamW update with `grads * grad_scale`; advances `self.step` on
    /// success. The state buffers are moved into the backend, so on a
    /// backend error the runner's state is consumed and must be rebuilt
    /// via [`Self::init`], [`Self::set_params`], or [`Self::restore`]
    /// before further use (the step counter is left unadvanced).
    pub fn adamw_update(&mut self, grads: &[Buffer], lr: f64, grad_scale: f64) -> Result<()> {
        let params = std::mem::take(&mut self.params);
        let m = std::mem::take(&mut self.m);
        let v = std::mem::take(&mut self.v);
        let (p, m, v) =
            self.backend.adamw_update(params, m, v, grads, self.step + 1, lr, grad_scale)?;
        self.step += 1;
        self.params = p;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// Evaluation loss on one batch (no stats, no grads).
    pub fn eval(&self, batch: &Batch) -> Result<f32> {
        self.check_batch(batch)?;
        self.backend.eval(&self.params, batch)
    }

    /// Deep-copy the full optimizer state (for run forking, Fig. 6).
    pub fn snapshot(&self) -> RunnerSnapshot {
        RunnerSnapshot {
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            step: self.step,
        }
    }

    pub fn restore(&mut self, s: RunnerSnapshot) {
        self.params = s.params;
        self.m = s.m;
        self.v = s.v;
        self.step = s.step;
    }

    /// Zero-filled gradient accumulator buffer set.
    pub fn zero_grads(&self) -> Result<Vec<Buffer>> {
        self.backend.zero_grads()
    }
}
