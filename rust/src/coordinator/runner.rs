//! Model runner: device state + artifact dispatch for one model config.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{ensure, anyhow, Result};
use xla::Literal;

use crate::data::Batch;
use crate::runtime::{tensor, Executable, Manifest, ModelEntry, Runtime};
use crate::N_TYPES;

/// Output of one microbatch gradient step.
pub struct GradOut {
    pub loss: f32,
    pub grads: Vec<Literal>,
    /// Raw per-layer-type `sum_b ||w'_b||^2` (pre-correction) stats.
    pub stats: [f32; N_TYPES],
}

/// Deep copy of a runner's mutable state.
#[derive(Clone)]
pub struct RunnerSnapshot {
    params: Vec<Literal>,
    m: Vec<Literal>,
    v: Vec<Literal>,
    step: u64,
}

/// Owns parameters + optimizer state as XLA literals and runs the
/// compiled artifacts. All shapes/orders come from the manifest.
pub struct ModelRunner {
    pub entry: ModelEntry,
    exes: HashMap<String, Rc<Executable>>,
    pub params: Vec<Literal>,
    m: Vec<Literal>,
    v: Vec<Literal>,
    /// Optimizer step count (1-based after first update).
    pub step: u64,
}

impl ModelRunner {
    pub fn new(rt: &Runtime, manifest: &Manifest, config: &str) -> Result<Self> {
        let entry = manifest.config(config)?.clone();
        let exes = rt.load_model(manifest, config)?;
        Ok(Self { entry, exes, params: Vec::new(), m: Vec::new(), v: Vec::new(), step: 0 })
    }

    fn exe(&self, name: &str) -> Result<&Rc<Executable>> {
        self.exes.get(name).ok_or_else(|| anyhow!("artifact {name} not loaded"))
    }

    pub fn n_params_tensors(&self) -> usize {
        self.entry.params.len()
    }

    /// Initialize parameters and zero optimizer state from a seed.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let out = self.exe("init")?.run(&[tensor::i32_scalar(seed)])?;
        ensure!(
            out.len() == self.entry.params.len(),
            "init returned {} tensors, manifest says {}",
            out.len(),
            self.entry.params.len()
        );
        self.m = out
            .iter()
            .zip(&self.entry.params)
            .map(|(_, spec)| {
                tensor::Tensor::zeros(&spec.shape).to_literal()
            })
            .collect::<Result<Vec<_>>>()?;
        self.v = self
            .entry
            .params
            .iter()
            .map(|spec| tensor::Tensor::zeros(&spec.shape).to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.params = out;
        self.step = 0;
        Ok(())
    }

    /// Replace parameters (e.g. from a checkpoint); resets Adam state.
    pub fn set_params(&mut self, params: Vec<Literal>) -> Result<()> {
        ensure!(params.len() == self.entry.params.len(), "param count mismatch");
        self.m = self
            .entry
            .params
            .iter()
            .map(|s| tensor::Tensor::zeros(&s.shape).to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.v = self
            .entry
            .params
            .iter()
            .map(|s| tensor::Tensor::zeros(&s.shape).to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.params = params;
        self.step = 0;
        Ok(())
    }

    fn batch_literals(&self, batch: &Batch) -> Result<(Literal, Literal)> {
        ensure!(
            batch.batch == self.entry.microbatch && batch.seq_len == self.entry.seq_len,
            "batch shape ({}, {}) != artifact shape ({}, {})",
            batch.batch,
            batch.seq_len,
            self.entry.microbatch,
            self.entry.seq_len
        );
        let shape = [batch.batch, batch.seq_len];
        Ok((
            tensor::i32_literal(&shape, &batch.inputs)?,
            tensor::i32_literal(&shape, &batch.targets)?,
        ))
    }

    /// Forward+backward on one microbatch: loss, gradients, GNS stats.
    pub fn grad_microbatch(&self, batch: &Batch) -> Result<GradOut> {
        let (ids, tgt) = self.batch_literals(batch)?;
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&ids);
        args.push(&tgt);
        let mut out = self.exe("grad_step")?.run(&args)?;
        let n = self.entry.params.len();
        ensure!(out.len() == n + 2, "grad_step returned {} outputs", out.len());
        let stats_lit = out.pop().unwrap();
        let stats_v = tensor::vec_f32(&stats_lit)?;
        ensure!(stats_v.len() == N_TYPES, "stats len {}", stats_v.len());
        let mut stats = [0f32; N_TYPES];
        stats.copy_from_slice(&stats_v);
        let grads = out.split_off(1);
        let loss = tensor::scalar_f32(&out[0])?;
        Ok(GradOut { loss, grads, stats })
    }

    /// acc += grads (element-wise over the whole parameter list).
    pub fn accumulate(&self, acc: Vec<Literal>, grads: &[Literal]) -> Result<Vec<Literal>> {
        let mut args: Vec<&Literal> = acc.iter().collect();
        args.extend(grads.iter());
        self.exe("accumulate")?.run(&args)
    }

    /// Per-layer-type squared norms of a gradient set (Eq. 4's big-batch
    /// component, computed on the accumulated gradient).
    pub fn grad_sqnorms(&self, grads: &[Literal]) -> Result<[f64; N_TYPES]> {
        let args: Vec<&Literal> = grads.iter().collect();
        let out = self.exe("grad_sqnorms")?.run1(&args)?;
        let v = tensor::vec_f32(&out)?;
        ensure!(v.len() == N_TYPES);
        let mut a = [0f64; N_TYPES];
        for (d, s) in a.iter_mut().zip(v) {
            *d = s as f64;
        }
        Ok(a)
    }

    /// AdamW update with `grads * grad_scale`; advances `self.step`.
    pub fn adamw_update(&mut self, grads: &[Literal], lr: f64, grad_scale: f64) -> Result<()> {
        self.step += 1;
        let step_l = tensor::f32_scalar(self.step as f32);
        let lr_l = tensor::f32_scalar(lr as f32);
        let scale_l = tensor::f32_scalar(grad_scale as f32);
        let mut args: Vec<&Literal> = Vec::with_capacity(4 * self.params.len() + 3);
        args.extend(self.params.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        args.extend(grads.iter());
        args.push(&step_l);
        args.push(&lr_l);
        args.push(&scale_l);
        let mut out = self.exe("adamw_update")?.run(&args)?;
        let n = self.entry.params.len();
        ensure!(out.len() == 3 * n, "adamw_update returned {} outputs", out.len());
        self.v = out.split_off(2 * n);
        self.m = out.split_off(n);
        self.params = out;
        Ok(())
    }

    /// Evaluation loss on one batch (no stats, no grads).
    pub fn eval(&self, batch: &Batch) -> Result<f32> {
        let (ids, tgt) = self.batch_literals(batch)?;
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&ids);
        args.push(&tgt);
        let out = self.exe("eval_step")?.run1(&args)?;
        tensor::scalar_f32(&out)
    }

    /// Deep-copy the full optimizer state (for run forking, Fig. 6).
    pub fn snapshot(&self) -> RunnerSnapshot {
        RunnerSnapshot {
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            step: self.step,
        }
    }

    pub fn restore(&mut self, s: RunnerSnapshot) {
        self.params = s.params;
        self.m = s.m;
        self.v = s.v;
        self.step = s.step;
    }

    /// Zero-filled gradient accumulator literal set.
    pub fn zero_grads(&self) -> Result<Vec<Literal>> {
        self.entry
            .params
            .iter()
            .map(|s| tensor::Tensor::zeros(&s.shape).to_literal())
            .collect()
    }
}
