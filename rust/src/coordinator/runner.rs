//! Model runner: training state + backend dispatch for one model config.
//!
//! Owns parameters and AdamW moments as opaque [`Buffer`]s and forwards
//! the compute to whichever [`Backend`] it was built with (reference or
//! PJRT). The backend itself is stateless, so snapshot/restore (run
//! forking, Fig. 6) and checkpointing are pure buffer copies.

use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::data::Batch;
use crate::runtime::{Backend, BackendFactory, Buffer, ModelEntry};
use crate::N_TYPES;

/// Gradient buffer sets the arena keeps around for reuse. The
/// accumulation loops lease at most one set at a time (the accumulator);
/// a second slot absorbs recycle/lease interleaving without hoarding
/// model-sized buffers.
const ARENA_MAX_SETS: usize = 2;

pub use crate::runtime::backend::GradOut;

/// Deep copy of a runner's mutable state.
#[derive(Clone)]
pub struct RunnerSnapshot {
    params: Vec<Buffer>,
    m: Vec<Buffer>,
    v: Vec<Buffer>,
    step: u64,
}

/// Owns parameters + optimizer state and runs them through a backend.
pub struct ModelRunner {
    backend: Box<dyn Backend>,
    pub entry: ModelEntry,
    pub params: Vec<Buffer>,
    m: Vec<Buffer>,
    v: Vec<Buffer>,
    /// Optimizer step count (1-based after first update).
    pub step: u64,
    /// Reusable gradient buffer sets: [`Self::lease_zero_grads`] pops and
    /// re-zeroes one instead of allocating every accumulation step;
    /// [`Self::recycle_grads`] returns sets to the pool. Purely a scratch
    /// cache — never part of snapshot/restore state, and leasing from a
    /// dirty pool is always equivalent to a fresh `zero_grads` call.
    arena: Mutex<Vec<Vec<Buffer>>>,
}

impl ModelRunner {
    pub fn new(factory: &dyn BackendFactory, model: &str) -> Result<Self> {
        Ok(Self::from_backend(factory.create(model)?))
    }

    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        let entry = backend.entry().clone();
        Self {
            backend,
            entry,
            params: Vec::new(),
            m: Vec::new(),
            v: Vec::new(),
            step: 0,
            arena: Mutex::new(Vec::new()),
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn n_params_tensors(&self) -> usize {
        self.entry.params.len()
    }

    /// Initialize parameters and zero optimizer state from a seed.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let out = self.backend.init(seed)?;
        ensure!(
            out.len() == self.entry.params.len(),
            "init returned {} tensors, model has {}",
            out.len(),
            self.entry.params.len()
        );
        self.m = self.backend.zero_grads()?;
        self.v = self.backend.zero_grads()?;
        self.params = out;
        self.step = 0;
        Ok(())
    }

    /// Replace parameters (e.g. from a params-only checkpoint); resets
    /// Adam state.
    pub fn set_params(&mut self, params: Vec<Buffer>) -> Result<()> {
        ensure!(params.len() == self.entry.params.len(), "param count mismatch");
        self.m = self.backend.zero_grads()?;
        self.v = self.backend.zero_grads()?;
        self.params = params;
        self.step = 0;
        Ok(())
    }

    /// Adam moment buffers `(m, v)`, for full-state checkpointing.
    pub fn moments(&self) -> (&[Buffer], &[Buffer]) {
        (&self.m, &self.v)
    }

    /// Replace the complete optimizer state (params, Adam moments, step
    /// counter) — the full-state checkpoint restore path.
    pub fn set_state(
        &mut self,
        params: Vec<Buffer>,
        m: Vec<Buffer>,
        v: Vec<Buffer>,
        step: u64,
    ) -> Result<()> {
        let n = self.entry.params.len();
        ensure!(params.len() == n, "param count mismatch: {} != {n}", params.len());
        ensure!(m.len() == n, "m count mismatch: {} != {n}", m.len());
        ensure!(v.len() == n, "v count mismatch: {} != {n}", v.len());
        self.params = params;
        self.m = m;
        self.v = v;
        self.step = step;
        Ok(())
    }

    fn check_batch(&self, batch: &Batch) -> Result<()> {
        ensure!(
            batch.batch == self.entry.microbatch && batch.seq_len == self.entry.seq_len,
            "batch shape ({}, {}) != model shape ({}, {})",
            batch.batch,
            batch.seq_len,
            self.entry.microbatch,
            self.entry.seq_len
        );
        Ok(())
    }

    /// Forward+backward on one microbatch: loss, gradients, GNS stats.
    pub fn grad_microbatch(&self, batch: &Batch) -> Result<GradOut> {
        self.check_batch(batch)?;
        self.backend.grad_step(&self.params, batch)
    }

    /// acc += grads (element-wise over the whole parameter list).
    pub fn accumulate(&self, acc: Vec<Buffer>, grads: &[Buffer]) -> Result<Vec<Buffer>> {
        self.backend.accumulate(acc, grads)
    }

    /// Per-layer-type squared norms of a gradient set (Eq. 4's big-batch
    /// component, computed on the accumulated gradient).
    pub fn grad_sqnorms(&self, grads: &[Buffer]) -> Result<[f64; N_TYPES]> {
        self.backend.grad_sqnorms(grads)
    }

    /// AdamW update with `grads * grad_scale`; advances `self.step` on
    /// success. The state buffers are moved into the backend, so on a
    /// backend error the runner's state is consumed and must be rebuilt
    /// via [`Self::init`], [`Self::set_params`], or [`Self::restore`]
    /// before further use (the step counter is left unadvanced).
    pub fn adamw_update(&mut self, grads: &[Buffer], lr: f64, grad_scale: f64) -> Result<()> {
        let params = std::mem::take(&mut self.params);
        let m = std::mem::take(&mut self.m);
        let v = std::mem::take(&mut self.v);
        let (p, m, v) =
            self.backend.adamw_update(params, m, v, grads, self.step + 1, lr, grad_scale)?;
        self.step += 1;
        self.params = p;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// Evaluation loss on one batch (no stats, no grads).
    pub fn eval(&self, batch: &Batch) -> Result<f32> {
        self.check_batch(batch)?;
        self.backend.eval(&self.params, batch)
    }

    /// Deep-copy the full optimizer state (for run forking, Fig. 6).
    pub fn snapshot(&self) -> RunnerSnapshot {
        RunnerSnapshot {
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            step: self.step,
        }
    }

    pub fn restore(&mut self, s: RunnerSnapshot) {
        self.params = s.params;
        self.m = s.m;
        self.v = s.v;
        self.step = s.step;
    }

    /// Zero-filled gradient accumulator buffer set.
    pub fn zero_grads(&self) -> Result<Vec<Buffer>> {
        self.backend.zero_grads()
    }

    /// Like [`Self::zero_grads`], but reuses a buffer set previously
    /// returned via [`Self::recycle_grads`] (re-zeroed in place) instead
    /// of reallocating — the accumulator's per-step allocation becomes a
    /// `fill(0.0)`. (Backends still allocate their *output* gradient set
    /// per `grad_step`; that allocation is part of the `GradOut` API.)
    pub fn lease_zero_grads(&self) -> Result<Vec<Buffer>> {
        let reused = self.arena.lock().ok().and_then(|mut pool| pool.pop());
        match reused {
            Some(mut set) => {
                // Pooled sets are all host-resident (recycle_grads
                // guarantees it), so re-zeroing is a plain fill.
                for b in set.iter_mut() {
                    match b {
                        Buffer::Host(t) => t.data.fill(0.0),
                        #[cfg(feature = "pjrt")]
                        Buffer::Pjrt(_) => {}
                    }
                }
                Ok(set)
            }
            None => self.backend.zero_grads(),
        }
    }

    /// Return a no-longer-needed gradient set to the arena for reuse.
    /// Only host-resident sets matching this model's tensor arity *and
    /// shapes* are pooled (a set from a different runner must not poison
    /// the pool); anything else is simply dropped.
    pub fn recycle_grads(&self, grads: Vec<Buffer>) {
        let matches_model = grads.len() == self.entry.params.len()
            && grads.iter().zip(&self.entry.params).all(|(b, spec)| match b {
                Buffer::Host(t) => t.shape == spec.shape,
                #[cfg(feature = "pjrt")]
                Buffer::Pjrt(_) => false,
            });
        if !matches_model {
            return;
        }
        if let Ok(mut pool) = self.arena.lock() {
            if pool.len() < ARENA_MAX_SETS {
                pool.push(grads);
            }
        }
    }
}
