//! Coordinator side of the elastic process-isolated rank engine.
//!
//! [`ElasticExecutor`] is the process-mode sibling of
//! [`crate::coordinator::ParallelExecutor`]: rank workers run as
//! supervised child processes (`repro rank-worker`), each owning a
//! contiguous block of logical rank positions — the same block layout as
//! the thread engine. Per step the coordinator ships parameters plus
//! per-rank loader cursors, the workers run the accumulation loops, and
//! the returned partials are merged locally through the *shared*
//! fixed-order tree reduction ([`crate::coordinator::parallel::tree_reduce`]),
//! which is what keeps process mode bitwise identical to thread mode.
//!
//! Failure model: loader cursors are coordinator-owned and only advanced
//! after a fully successful step, so a failed step has **zero** training
//! side effects. When a worker dies (crash, kill -9, heartbeat loss, or
//! per-step deadline), [`ElasticExecutor::rank_step`] returns
//! [`RankOutcome::Lost`] naming the rank positions that went down; the
//! trainer reconciles by dropping those loaders (the surviving ranks'
//! data streams are untouched) and simply retries the step on the
//! survivors. The post-drop trajectory is therefore bitwise identical to
//! a thread-mode run at the reduced rank count.
//!
//! Reconciliation is not the end of the story: dead workers are
//! *respawned* with capped exponential backoff
//! ([`ElasticExecutor::try_rejoin`], polled by the trainer at step
//! boundaries). A respawned worker completes the same handshake as at
//! launch and re-admits its original rank block; the trainer re-inserts
//! the parked loaders at their label-ordered positions, so from the
//! rejoin boundary onward the trajectory is bitwise identical to a
//! full-rank run. Every incarnation of a worker gets a fresh generation
//! tag, and reader-thread events carry it, so frames from a dead
//! incarnation can never be attributed to its successor. After
//! `max_respawns` consecutive failed spawn attempts the worker is
//! permanently retired and the run continues on the survivors.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::protocol::{self, Conn, Frame, Hello, Listener, PROTO_VERSION, RankResult, RankTask};
use crate::config::TrainConfig;
use crate::coordinator::parallel::{tree_reduce, RankPartial, RankStepOut};
use crate::data::Loader;
use crate::gns::GnsAccumulator;
use crate::runtime::{Backend, BackendFactory, Buffer, ModelEntry, Tensor};
use crate::N_TYPES;

/// Liveness/progress snapshot for one logical rank, surfaced through the
/// trainer to the `serve` daemon's `/ranks` endpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct RankHealth {
    /// Original rank index (stable label even after reconciliation).
    pub rank: usize,
    pub alive: bool,
    /// Worker process id (process mode only).
    pub pid: Option<u32>,
    /// Last step id this rank contributed a result to.
    pub last_step: u64,
    /// Milliseconds since the worker's last heartbeat (process mode only).
    pub heartbeat_age_ms: Option<f64>,
    /// Successful respawns of this rank's worker over the run.
    pub respawns: u64,
    /// `"thread"` or `"process"`.
    pub mode: &'static str,
}

/// What [`ElasticExecutor::try_rejoin`] accomplished at one step
/// boundary, in original rank labels.
#[derive(Debug, Default)]
pub struct RejoinReport {
    /// Ranks whose respawned worker completed its handshake; the trainer
    /// re-admits their parked loaders before this step runs.
    pub rejoined: Vec<usize>,
    /// Ranks permanently abandoned (respawn budget exhausted).
    pub gave_up: Vec<usize>,
}

/// Result of one elastic step attempt.
pub enum RankOutcome {
    /// The step completed on every rank; cursors have been advanced.
    Done(RankStepOut),
    /// These rank positions died (sorted). No cursors were advanced —
    /// drop the positions and retry the step on the survivors.
    Lost(Vec<usize>),
}

enum Event {
    Frame(Frame),
    Gone(String),
}

struct WorkerHandle {
    child: Child,
    /// Write half; a clone lives in the reader thread.
    conn: Conn,
    reader: Option<JoinHandle<()>>,
    alive: bool,
    pid: u32,
    /// Incarnation counter: every respawn bumps it, and reader-thread
    /// events carry the generation they were read under, so frames from
    /// a dead incarnation are never attributed to its successor.
    gen: u64,
    /// Original rank labels this worker represents. The set survives the
    /// worker's death (it is the block a respawned successor re-admits)
    /// and shrinks only when positions are deliberately dropped while
    /// the worker lives. Parallel to `positions` on live workers.
    origs: Vec<usize>,
    /// Current loader positions owned by this worker (remapped on
    /// reconciliation; empty once dead or retired).
    positions: Vec<usize>,
    last_step: u64,
    last_heartbeat: Instant,
    fail_reason: Option<String>,
    /// Consecutive failed respawn attempts since the last success.
    respawn_attempts: u32,
    /// Earliest moment of the next respawn attempt (capped exponential
    /// backoff; also paces re-admission after a successful-then-crashed
    /// respawn).
    next_respawn_at: Option<Instant>,
    /// Successful respawns over the run (telemetry).
    respawns: u64,
    /// Permanently out: deliberately retired (no positions remain) or
    /// respawn budget exhausted. Never respawned again.
    retired: bool,
}

/// Supervises rank-worker child processes and runs elastic steps.
pub struct ElasticExecutor {
    /// Local backend used for the tree reduction and artifact calls
    /// (`eval`, `grad_sqnorms` go through the trainer's runner as before).
    reduce: Box<dyn Backend>,
    entry: ModelEntry,
    workers: Vec<WorkerHandle>,
    events: Receiver<(usize, u64, Event)>,
    /// Cloned into every respawned worker's reader thread.
    tx: Sender<(usize, u64, Event)>,
    /// Rendezvous kept open for the lifetime of the run so respawned
    /// workers connect back exactly like freshly launched ones.
    listener: Listener,
    addr: String,
    exe: PathBuf,
    /// Launch config, retained to rebuild the `Hello` for respawns.
    cfg: TrainConfig,
    step_id: u64,
    heartbeat: Duration,
    spawn_timeout: Duration,
    step_timeout: Duration,
    max_respawns: u32,
    backoff_floor: Duration,
    backoff_cap: Duration,
}

fn timeout_from_secs(v: f64, default_s: f64) -> Duration {
    let v = if v.is_finite() && v > 0.0 { v } else { default_s };
    Duration::from_secs_f64(v)
}

impl ElasticExecutor {
    /// Spawn one worker process per contiguous rank block (`workers`
    /// clamped to `[1, ranks]`; `NANOGNS_RANK_WORKERS` decides the count
    /// upstream, exactly like thread mode) and complete the handshake
    /// with each before returning.
    pub fn launch(
        factory: &dyn BackendFactory,
        cfg: &TrainConfig,
        workers: usize,
    ) -> Result<Self> {
        let ranks = cfg.ranks.max(1);
        let workers = workers.clamp(1, ranks);
        let reduce = factory.create_for_rank(&cfg.model, 0)?;
        let entry = reduce.entry().clone();
        let exe = if cfg.elastic.worker_exe.is_empty() {
            std::env::current_exe().context("resolving rank-worker executable")?
        } else {
            PathBuf::from(&cfg.elastic.worker_exe)
        };
        let heartbeat = Duration::from_millis(cfg.elastic.heartbeat_ms.max(10));
        let spawn_timeout = timeout_from_secs(cfg.elastic.spawn_timeout_s, 30.0);
        let step_timeout = timeout_from_secs(cfg.elastic.step_timeout_s, 300.0);
        let (listener, addr) = Listener::bind_local()?;
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel();

        let mut handles: Vec<WorkerHandle> = Vec::new();
        let per = ranks.div_ceil(workers);
        let mut start = 0usize;
        let mut w = 0usize;
        while start < ranks {
            let end = (start + per).min(ranks);
            let block: Vec<usize> = (start..end).collect();
            match Self::spawn_worker(
                &exe,
                &listener,
                &addr,
                w,
                0,
                block,
                cfg,
                reduce.name(),
                heartbeat,
                spawn_timeout,
                &tx,
            ) {
                Ok(h) => handles.push(h),
                Err(e) => {
                    for mut h in handles {
                        let _ = h.child.kill();
                        let _ = h.child.wait();
                    }
                    return Err(e);
                }
            }
            start = end;
            w += 1;
        }
        let backoff_floor = Duration::from_millis(cfg.elastic.respawn_backoff_ms.max(1));
        let backoff_cap =
            Duration::from_millis(cfg.elastic.respawn_backoff_max_ms.max(1)).max(backoff_floor);
        Ok(Self {
            reduce,
            entry,
            workers: handles,
            events: rx,
            tx,
            listener,
            addr,
            exe,
            cfg: cfg.clone(),
            step_id: 0,
            heartbeat,
            spawn_timeout,
            step_timeout,
            max_respawns: cfg.elastic.max_respawns,
            backoff_floor,
            backoff_cap,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn spawn_worker(
        exe: &std::path::Path,
        listener: &Listener,
        addr: &str,
        w: usize,
        gen: u64,
        block: Vec<usize>,
        cfg: &TrainConfig,
        backend_name: &str,
        heartbeat: Duration,
        spawn_timeout: Duration,
        tx: &Sender<(usize, u64, Event)>,
    ) -> Result<WorkerHandle> {
        let mut child = Command::new(exe)
            .arg("rank-worker")
            .arg("--connect")
            .arg(addr)
            .arg("--worker")
            .arg(w.to_string())
            .stdin(Stdio::null())
            // Workers stay silent on stdout (the coordinator may be in
            // `--json` mode); stderr is inherited for crash visibility.
            .stdout(Stdio::null())
            .spawn()
            .with_context(|| format!("spawning rank worker {w} via {}", exe.display()))?;
        let pid = child.id();

        let handshake = Self::handshake(
            listener,
            &mut child,
            w,
            cfg,
            backend_name,
            heartbeat,
            spawn_timeout,
        );

        let (wconn, mut rconn) = match handshake {
            Ok(pair) => pair,
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e);
            }
        };

        let tx2 = tx.clone();
        let reader = std::thread::spawn(move || loop {
            match protocol::read_frame(&mut rconn) {
                Ok(f) => {
                    if tx2.send((w, gen, Event::Frame(f))).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    let _ = tx2.send((w, gen, Event::Gone(format!("{e}"))));
                    return;
                }
            }
        });

        Ok(WorkerHandle {
            child,
            conn: wconn,
            reader: Some(reader),
            alive: true,
            pid,
            gen,
            origs: block.clone(),
            positions: block,
            last_step: 0,
            last_heartbeat: Instant::now(),
            fail_reason: None,
            respawn_attempts: 0,
            next_respawn_at: None,
            respawns: 0,
            retired: false,
        })
    }

    /// Accept the freshly spawned worker's connection and complete the
    /// Ready/Hello exchange; returns the (write, read) socket halves.
    #[allow(clippy::too_many_arguments)]
    fn handshake(
        listener: &Listener,
        child: &mut Child,
        w: usize,
        cfg: &TrainConfig,
        backend_name: &str,
        heartbeat: Duration,
        spawn_timeout: Duration,
    ) -> Result<(Conn, Conn)> {
        let deadline = Instant::now() + spawn_timeout;
        let conn = loop {
            match listener.accept() {
                Ok(c) => break c,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if let Some(status) = child.try_wait()? {
                        bail!("rank worker {w} exited during startup: {status}");
                    }
                    ensure!(
                        Instant::now() < deadline,
                        "rank worker {w} did not connect within {spawn_timeout:?}"
                    );
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    // Transient accept failures (EINTR, fd pressure,
                    // connection reset before accept) are retried until
                    // the spawn deadline, not treated as fatal.
                    ensure!(
                        Instant::now() < deadline,
                        "accepting rank worker {w} connection kept failing \
                         within {spawn_timeout:?}: {e}"
                    );
                    eprintln!("elastic: accept for worker {w} failed ({e}); retrying");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        conn.set_nonblocking(false)?;
        conn.set_read_timeout(Some(spawn_timeout))?;
        let mut rconn = conn.try_clone()?;
        match protocol::read_frame(&mut rconn)
            .with_context(|| format!("handshake with rank worker {w}"))?
        {
            Frame::Ready(r) => {
                ensure!(
                    r.worker as usize == w,
                    "worker index mismatch: spawned {w}, got Ready from {}",
                    r.worker
                );
            }
            other => bail!("rank worker {w}: expected Ready, got {other:?}"),
        }
        let mut wconn = conn;
        protocol::write_frame(
            &mut wconn,
            &Frame::Hello(Hello {
                proto: PROTO_VERSION,
                worker: w as u32,
                model: cfg.model.clone(),
                backend: backend_name.to_string(),
                artifacts: cfg.artifacts.clone(),
                seed: cfg.seed,
                corpus_bytes: cfg.corpus_bytes as u64,
                heartbeat_ms: heartbeat.as_millis() as u64,
            }),
        )?;
        wconn.set_read_timeout(None)?;
        Ok((wconn, rconn))
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// The local reduction backend.
    pub fn backend(&self) -> &dyn Backend {
        self.reduce.as_ref()
    }

    /// Live worker processes.
    pub fn workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Pids of live workers, in worker order (fault-injection tests pick
    /// a victim from here).
    pub fn worker_pids(&self) -> Vec<u32> {
        self.workers.iter().filter(|w| w.alive).map(|w| w.pid).collect()
    }

    fn mark_dead(&mut self, wi: usize, reason: String) {
        let floor = self.backoff_floor;
        let w = &mut self.workers[wi];
        if !w.alive {
            return;
        }
        w.alive = false;
        eprintln!(
            "elastic: worker {wi} (pid {}, ranks {:?}) down: {reason}",
            w.pid, w.origs
        );
        w.fail_reason = Some(reason);
        // Pace the next re-admission: even when every spawn succeeds, a
        // crash-looping worker waits at least the backoff floor between
        // incarnations.
        w.next_respawn_at = Some(Instant::now() + floor);
        let _ = w.child.kill();
        let _ = w.child.wait();
    }

    fn handle_event(
        &mut self,
        wi: usize,
        gen: u64,
        ev: Event,
        step_id: u64,
        pending: &mut BTreeSet<usize>,
        results: &mut BTreeMap<usize, RankResult>,
    ) {
        // Events from a dead incarnation's reader thread (its socket can
        // outlive mark_dead by a beat) must never touch the respawned
        // successor's state.
        if gen != self.workers[wi].gen {
            return;
        }
        match ev {
            Event::Frame(Frame::Heartbeat { .. }) => {
                self.workers[wi].last_heartbeat = Instant::now();
            }
            Event::Frame(Frame::Result(res)) => {
                // Results from an aborted earlier attempt carry a stale
                // step id and are dropped on the floor.
                if res.step_id == step_id {
                    for r in res.results {
                        results.insert(r.rank as usize, r);
                    }
                    self.workers[wi].last_step = step_id;
                    self.workers[wi].last_heartbeat = Instant::now();
                    pending.remove(&wi);
                }
            }
            Event::Frame(Frame::Error { msg, .. }) => {
                self.mark_dead(wi, format!("worker reported: {msg}"));
                pending.remove(&wi);
            }
            Event::Frame(_) => {}
            Event::Gone(reason) => {
                if self.workers[wi].alive {
                    self.mark_dead(wi, format!("connection lost: {reason}"));
                }
                pending.remove(&wi);
            }
        }
    }

    /// Process queued reader events without blocking (heartbeats between
    /// steps, deaths detected while the trainer was busy elsewhere).
    fn drain_events(&mut self) {
        let mut pending = BTreeSet::new();
        let mut results = BTreeMap::new();
        while let Ok((wi, gen, ev)) = self.events.try_recv() {
            let step_id = self.step_id;
            self.handle_event(wi, gen, ev, step_id, &mut pending, &mut results);
        }
    }

    /// Positions owned by non-live workers, sorted ascending.
    fn lost_positions(&self) -> Vec<usize> {
        let mut lost: Vec<usize> = self
            .workers
            .iter()
            .filter(|w| !w.alive)
            .flat_map(|w| w.positions.iter().copied())
            .collect();
        lost.sort_unstable();
        lost
    }

    /// Run one step attempt across all live workers. Either every rank
    /// completes ([`RankOutcome::Done`], cursors advanced) or the lost
    /// positions are reported with no side effects at all.
    pub fn rank_step(
        &mut self,
        params: &[Buffer],
        loaders: &mut [Loader],
        accum: usize,
        collect_rank_norms: bool,
    ) -> Result<RankOutcome> {
        let ranks = loaders.len();
        ensure!(ranks > 0, "rank_step needs at least one rank loader");
        ensure!(accum > 0, "rank_step needs accum >= 1");
        self.drain_events();
        let lost = self.lost_positions();
        if !lost.is_empty() {
            return Ok(RankOutcome::Lost(lost));
        }
        ensure!(self.workers.iter().any(|w| w.alive), "no rank workers remain");
        let assigned: usize = self.workers.iter().map(|w| w.positions.len()).sum();
        ensure!(
            assigned == ranks,
            "elastic engine tracks {assigned} rank positions but got {ranks} loaders"
        );

        self.step_id += 1;
        let step_id = self.step_id;
        let pdata: Vec<Vec<f32>> = params
            .iter()
            .map(|b| b.as_host().map(|t| t.data.clone()))
            .collect::<Result<_>>()?;

        let mut pending: BTreeSet<usize> = BTreeSet::new();
        for wi in 0..self.workers.len() {
            if !self.workers[wi].alive || self.workers[wi].positions.is_empty() {
                continue;
            }
            let tasks: Vec<RankTask> = self.workers[wi]
                .positions
                .iter()
                .map(|&p| RankTask { rank: p as u32, cursor: loaders[p].cursor() })
                .collect();
            match protocol::write_step(
                &mut self.workers[wi].conn,
                step_id,
                accum as u32,
                collect_rank_norms,
                &tasks,
                &pdata,
            ) {
                Ok(()) => {
                    pending.insert(wi);
                }
                Err(e) => self.mark_dead(wi, format!("step send failed: {e}")),
            }
        }

        let deadline = Instant::now() + self.step_timeout;
        let hb_timeout = (self.heartbeat * 8).max(Duration::from_secs(2));
        let mut results: BTreeMap<usize, RankResult> = BTreeMap::new();
        while !pending.is_empty() {
            let now = Instant::now();
            if now >= deadline {
                for wi in pending.iter().copied().collect::<Vec<_>>() {
                    self.mark_dead(wi, format!("step {step_id} deadline exceeded"));
                    pending.remove(&wi);
                }
                break;
            }
            let wait = (deadline - now).min(self.heartbeat.max(Duration::from_millis(50)));
            match self.events.recv_timeout(wait) {
                Ok((wi, gen, ev)) => {
                    self.handle_event(wi, gen, ev, step_id, &mut pending, &mut results)
                }
                Err(RecvTimeoutError::Timeout) => {
                    let now = Instant::now();
                    let stale: Vec<usize> = pending
                        .iter()
                        .copied()
                        .filter(|&wi| {
                            now.duration_since(self.workers[wi].last_heartbeat) > hb_timeout
                        })
                        .collect();
                    for wi in stale {
                        self.mark_dead(wi, "heartbeat timeout".to_string());
                        pending.remove(&wi);
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    for wi in pending.iter().copied().collect::<Vec<_>>() {
                        self.mark_dead(wi, "event channel closed".to_string());
                    }
                    pending.clear();
                }
            }
        }

        let lost = self.lost_positions();
        if !lost.is_empty() {
            // Discard the whole attempt: no cursors move, nothing merges.
            return Ok(RankOutcome::Lost(lost));
        }

        // Success: advance cursors, rebuild partials in rank-position
        // order, and reduce through the shared fixed-order tree.
        ensure!(
            results.len() == ranks,
            "step {step_id}: got {} rank results, want {ranks}",
            results.len()
        );
        let mut partials: Vec<RankPartial> = Vec::with_capacity(ranks);
        for p in 0..ranks {
            let r = results
                .remove(&p)
                .ok_or_else(|| anyhow!("step {step_id}: no result for rank position {p}"))?;
            ensure!(
                r.n_micro as usize == accum,
                "rank {p}: ran {} microbatches, expected {accum}",
                r.n_micro
            );
            ensure!(
                r.grads.len() == self.entry.params.len(),
                "rank {p}: {} gradient tensors, expected {}",
                r.grads.len(),
                self.entry.params.len()
            );
            ensure!(
                r.perex_sum.len() == N_TYPES,
                "rank {p}: stats arity {} != {N_TYPES}",
                r.perex_sum.len()
            );
            loaders[p].restore_cursor(r.cursor);
            let mut grads = Vec::with_capacity(r.grads.len());
            for (data, spec) in r.grads.into_iter().zip(&self.entry.params) {
                let t = Tensor::new(spec.shape.clone(), data)
                    .with_context(|| format!("rank {p}: bad gradient for {}", spec.name))?;
                grads.push(Buffer::from_tensor(t));
            }
            let stats = GnsAccumulator::from_parts(
                r.microbatch as usize,
                r.perex_sum,
                r.n_examples as usize,
            );
            let sqnorms = match r.sqnorms {
                Some(v) => {
                    ensure!(v.len() == N_TYPES, "rank {p}: sqnorm arity {}", v.len());
                    let mut a = [0f64; N_TYPES];
                    a.copy_from_slice(&v);
                    Some(a)
                }
                None => None,
            };
            partials.push(RankPartial {
                grads,
                stats,
                loss: r.loss,
                n_micro: r.n_micro as usize,
                sqnorms,
            });
        }
        let rank_sqnorms: Option<Vec<[f64; N_TYPES]>> = collect_rank_norms
            .then(|| partials.iter().map(|p| p.sqnorms.unwrap_or([f64::NAN; N_TYPES])).collect());
        let root = tree_reduce(self.reduce.as_ref(), partials, |_| {})?;
        Ok(RankOutcome::Done(RankStepOut {
            grads: root.grads,
            stats: root.stats,
            loss_sum: root.loss,
            n_micro: root.n_micro,
            rank_sqnorms,
        }))
    }

    /// Commit a reconciliation the trainer has applied to its loaders:
    /// `lost` (sorted ascending) names the removed positions. Surviving
    /// workers keep their own blocks, remapped to the compacted index
    /// space; a live worker left without positions is retired. Dead
    /// workers release their positions but keep their original rank
    /// labels — that set is the block a respawned successor re-admits.
    pub fn confirm_loss(&mut self, lost: &[usize]) {
        for w in self.workers.iter_mut() {
            if w.alive {
                // `positions` and `origs` stay parallel on live workers:
                // a deliberately dropped position takes its label with it
                // (it was dropped, not crashed — nothing will rejoin it).
                let mut i = 0;
                while i < w.positions.len() {
                    if lost.contains(&w.positions[i]) {
                        w.positions.remove(i);
                        w.origs.remove(i);
                    } else {
                        i += 1;
                    }
                }
            } else {
                w.positions.retain(|p| !lost.contains(p));
            }
            for p in w.positions.iter_mut() {
                *p -= lost.iter().filter(|&&l| l < *p).count();
            }
        }
        for wi in 0..self.workers.len() {
            if self.workers[wi].alive && self.workers[wi].positions.is_empty() {
                let _ = protocol::write_frame(&mut self.workers[wi].conn, &Frame::Shutdown);
                self.workers[wi].retired = true;
                self.mark_dead(wi, "retired: no rank positions remain".to_string());
            }
        }
    }

    /// Reassign loader positions from original rank labels: the trainer
    /// keeps its live loaders sorted by label, so a live rank's position
    /// is simply its label's rank among all live labels. Called after a
    /// rejoin changes the live set.
    fn recompute_positions(&mut self) {
        let mut all: Vec<usize> = self
            .workers
            .iter()
            .filter(|w| w.alive)
            .flat_map(|w| w.origs.iter().copied())
            .collect();
        all.sort_unstable();
        for w in self.workers.iter_mut() {
            if w.alive {
                w.positions = w
                    .origs
                    .iter()
                    .map(|&o| all.binary_search(&o).expect("live orig label"))
                    .collect();
            }
        }
    }

    /// Respawn machinery, polled by the trainer at step boundaries: give
    /// every dead, unretired worker whose backoff has elapsed one spawn
    /// attempt, and report which original ranks completed the handshake
    /// (the trainer re-admits their loaders before the step runs). Spawn
    /// failures back off exponentially from the configured floor to the
    /// cap; after `max_respawns` consecutive failures the worker is
    /// permanently retired.
    pub fn try_rejoin(&mut self) -> RejoinReport {
        self.drain_events();
        let mut report = RejoinReport::default();
        let now = Instant::now();
        for wi in 0..self.workers.len() {
            {
                let w = &self.workers[wi];
                // Only workers whose loss the trainer has already
                // reconciled are eligible: confirm_loss empties a dead
                // worker's positions and parks its loaders — the thing a
                // rejoin re-admits. A death noticed just now (positions
                // still assigned) must first go through a Lost step.
                if w.alive || w.retired || w.origs.is_empty() || !w.positions.is_empty() {
                    continue;
                }
                if self.max_respawns == 0 || w.respawn_attempts >= self.max_respawns {
                    let w = &mut self.workers[wi];
                    w.retired = true;
                    report.gave_up.extend(w.origs.iter().copied());
                    eprintln!(
                        "elastic: giving up on worker {wi} (rank(s) {:?}) after {} failed \
                         respawn attempt(s); continuing on the survivors",
                        w.origs, w.respawn_attempts
                    );
                    continue;
                }
                if w.next_respawn_at.is_some_and(|at| now < at) {
                    continue;
                }
            }
            match self.spawn_into(wi) {
                Ok(()) => {
                    let w = &self.workers[wi];
                    eprintln!(
                        "elastic: respawned worker {wi} (pid {}, rank(s) {:?}); re-admitting \
                         at this step boundary",
                        w.pid, w.origs
                    );
                    report.rejoined.extend(w.origs.iter().copied());
                }
                Err(e) => {
                    let (floor, cap) = (self.backoff_floor, self.backoff_cap);
                    let w = &mut self.workers[wi];
                    w.respawn_attempts += 1;
                    let shift = (w.respawn_attempts - 1).min(16);
                    let backoff = floor.saturating_mul(1u32 << shift).min(cap);
                    w.next_respawn_at = Some(now + backoff);
                    eprintln!(
                        "elastic: respawn attempt {}/{} for worker {wi} failed: {e:#}; next \
                         attempt in {backoff:?}",
                        w.respawn_attempts, self.max_respawns
                    );
                }
            }
        }
        if !report.rejoined.is_empty() {
            self.recompute_positions();
        }
        report.rejoined.sort_unstable();
        report.gave_up.sort_unstable();
        report
    }

    /// Spawn a fresh incarnation of worker `wi` and graft it into the
    /// slot, bumping the generation and preserving the respawn counters.
    fn spawn_into(&mut self, wi: usize) -> Result<()> {
        let gen = self.workers[wi].gen + 1;
        let block = self.workers[wi].origs.clone();
        let h = Self::spawn_worker(
            &self.exe,
            &self.listener,
            &self.addr,
            wi,
            gen,
            block,
            &self.cfg,
            self.reduce.name(),
            self.heartbeat,
            self.spawn_timeout,
            &self.tx,
        )?;
        let w = &mut self.workers[wi];
        // The dead incarnation's reader already unblocked on EOF (its
        // child was killed and reaped in mark_dead).
        if let Some(j) = w.reader.take() {
            let _ = j.join();
        }
        let respawns = w.respawns + 1;
        *w = h;
        w.respawns = respawns;
        Ok(())
    }

    /// Per-rank liveness for `/ranks`, labeled by original rank index.
    pub fn health(&self) -> Vec<RankHealth> {
        let now = Instant::now();
        let mut out = Vec::new();
        for w in &self.workers {
            for &orig in &w.origs {
                out.push(RankHealth {
                    rank: orig,
                    alive: w.alive,
                    pid: Some(w.pid),
                    last_step: w.last_step,
                    heartbeat_age_ms: Some(
                        now.duration_since(w.last_heartbeat).as_secs_f64() * 1e3,
                    ),
                    respawns: w.respawns,
                    mode: "process",
                });
            }
        }
        out.sort_by_key(|h| h.rank);
        out
    }

    fn shutdown_workers(&mut self) {
        for wi in 0..self.workers.len() {
            if self.workers[wi].alive {
                let _ = protocol::write_frame(&mut self.workers[wi].conn, &Frame::Shutdown);
            }
        }
        for w in self.workers.iter_mut() {
            let deadline = Instant::now() + Duration::from_secs(2);
            loop {
                match w.child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    _ => {
                        let _ = w.child.kill();
                        let _ = w.child.wait();
                        break;
                    }
                }
            }
            w.alive = false;
        }
        // Children are gone, so the sockets are closed and every reader
        // thread unblocks with EOF.
        for w in self.workers.iter_mut() {
            if let Some(h) = w.reader.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for ElasticExecutor {
    fn drop(&mut self) {
        self.shutdown_workers();
    }
}
