//! Length-prefixed binary protocol between the elastic coordinator and
//! its rank-worker child processes.
//!
//! Every message is one frame: `[u32 LE payload length][payload][u32 LE
//! CRC-32 of payload]`, where the payload's first byte is a tag
//! selecting the message kind. All integers are little-endian; floats
//! travel as raw IEEE-754 bits, so a value decoded on the far side is
//! bit-identical to the one encoded — the property that lets the
//! coordinator's tree reduction over process-boundary partials match the
//! in-process thread engine bitwise.
//!
//! Every decode failure is a typed [`ProtoError`], never a panic: the
//! CRC trailer catches corruption in flight, the length prefix is
//! bounded before allocation, and structural decode errors are surfaced
//! as malformed frames. The supervisor treats any of them as a *rank
//! fault* — the worker is reconciled away and respawned — so one bad
//! byte on a socket can cost at most one worker, never the run.
//!
//! The handshake is worker-initiated so accept order never matters:
//! the worker connects and sends [`Frame::Ready`]; the coordinator
//! replies with [`Frame::Hello`] carrying everything the worker needs to
//! rebuild the training context (model, backend, corpus seed/size).
//! Steady state is coordinator [`Frame::Step`] → worker
//! [`Frame::Result`], with [`Frame::Heartbeat`] flowing worker→
//! coordinator from a side thread the whole time.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::util::crc::crc32;
use crate::util::faultkit::{self, FrameFault};
use crate::util::rng::RngState;

/// Bumped on any wire-format change; both sides refuse a mismatch.
/// Version 2 added the CRC-32 frame trailer.
pub const PROTO_VERSION: u32 = 2;

/// Upper bound on a single frame. Generous (a full parameter set for the
/// largest preset is far below this), but finite so a corrupt length
/// prefix cannot trigger an unbounded allocation.
pub const MAX_FRAME: usize = 1 << 30;

const TAG_HELLO: u8 = 1;
const TAG_READY: u8 = 2;
const TAG_STEP: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_HEARTBEAT: u8 = 5;
const TAG_ERROR: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;

/// Typed decode/transport failure for one frame. Every way a frame can
/// fail to parse maps onto exactly one of these — the contract the
/// mutation property test enforces: corrupt or truncated bytes yield a
/// `ProtoError`, never a panic and never a silently-accepted frame.
#[derive(Debug)]
pub enum ProtoError {
    /// Transport-level read failure (includes EOF mid-frame).
    Io(std::io::Error),
    /// Length prefix exceeds [`MAX_FRAME`] — rejected before allocating.
    Oversize(usize),
    /// The payload's CRC-32 does not match the wire trailer.
    CrcMismatch { wire: u32, computed: u32 },
    /// The payload's first byte names no known message kind.
    UnknownTag(u8),
    /// Structurally invalid payload (bad lengths, flags, or encoding).
    Malformed(&'static str),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "frame transport error: {e}"),
            ProtoError::Oversize(n) => write!(f, "frame length {n} exceeds bound"),
            ProtoError::CrcMismatch { wire, computed } => {
                write!(f, "frame crc mismatch (wire 0x{wire:08x}, computed 0x{computed:08x})")
            }
            ProtoError::UnknownTag(t) => write!(f, "unknown frame tag {t}"),
            ProtoError::Malformed(what) => write!(f, "malformed frame: {what}"),
        }
    }
}

impl std::error::Error for ProtoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ProtoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        ProtoError::Io(e)
    }
}

/// Coordinator → worker: handshake reply with the training context.
#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    pub proto: u32,
    pub worker: u32,
    pub model: String,
    pub backend: String,
    pub artifacts: String,
    pub seed: u64,
    pub corpus_bytes: u64,
    pub heartbeat_ms: u64,
}

/// Worker → coordinator: first message after connecting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ready {
    pub worker: u32,
    pub pid: u32,
}

/// One logical rank's assignment within a step: which rank position to
/// compute and the exact loader cursor to start from. Cursors are
/// coordinator-owned: the worker reports where the cursor ended up, and
/// the coordinator applies that only after a fully successful step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankTask {
    pub rank: u32,
    pub cursor: RngState,
}

/// Coordinator → worker: run one optimizer step's accumulation for the
/// assigned rank positions against the given parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct StepCmd {
    pub step_id: u64,
    pub accum: u32,
    pub collect_norms: bool,
    pub tasks: Vec<RankTask>,
    pub params: Vec<Vec<f32>>,
}

/// One rank position's partial: accumulated grads, decomposed
/// `GnsAccumulator` state, loss sum, and the advanced loader cursor.
#[derive(Debug, Clone, PartialEq)]
pub struct RankResult {
    pub rank: u32,
    pub loss: f64,
    pub n_micro: u32,
    pub microbatch: u64,
    pub n_examples: u64,
    pub perex_sum: Vec<f64>,
    pub sqnorms: Option<Vec<f64>>,
    pub cursor: RngState,
    pub grads: Vec<Vec<f32>>,
}

/// Worker → coordinator: all partials for one [`StepCmd`].
#[derive(Debug, Clone, PartialEq)]
pub struct StepResult {
    pub step_id: u64,
    pub worker: u32,
    pub results: Vec<RankResult>,
}

/// Any protocol message. `Step` is large (carries parameters); everything
/// else is small control traffic.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Hello(Hello),
    Ready(Ready),
    Step(StepCmd),
    Result(StepResult),
    Heartbeat { worker: u32, seq: u64 },
    Error { worker: u32, msg: String },
    Shutdown,
}

// ---------------------------------------------------------------------
// Encoding primitives
// ---------------------------------------------------------------------

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 4);
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(buf: &mut Vec<u8>, v: &[f64]) {
    put_u64(buf, v.len() as u64);
    buf.reserve(v.len() * 8);
    for x in v {
        put_f64(buf, *x);
    }
}

fn put_rng(buf: &mut Vec<u8>, st: &RngState) {
    for s in st.s {
        put_u64(buf, s);
    }
    match st.spare {
        Some(v) => {
            put_u8(buf, 1);
            put_f64(buf, v);
        }
        None => put_u8(buf, 0),
    }
}

/// Bounds-checked decoding cursor over one frame payload. Every error is
/// a typed [`ProtoError`]; nothing here can panic on adversarial bytes.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn need(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.pos.saturating_add(n) > self.buf.len() {
            return Err(ProtoError::Malformed("truncated frame payload"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.need(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.need(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.need(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn len(&mut self) -> Result<usize, ProtoError> {
        let n = self.u64()? as usize;
        if n > MAX_FRAME {
            return Err(ProtoError::Oversize(n));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String, ProtoError> {
        let n = self.len()?;
        let bytes = self.need(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ProtoError::Malformed("non-utf8 string field"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, ProtoError> {
        let n = self.len()?;
        let bytes = self.need(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>, ProtoError> {
        let n = self.len()?;
        let bytes = self.need(n * 8)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn rng(&mut self) -> Result<RngState, ProtoError> {
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = self.u64()?;
        }
        let spare = match self.u8()? {
            0 => None,
            1 => Some(self.f64()?),
            _ => return Err(ProtoError::Malformed("bad RngState spare flag")),
        };
        Ok(RngState { s, spare })
    }

    fn finish(&self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(ProtoError::Malformed("trailing bytes in frame payload"));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Frame encode/decode
// ---------------------------------------------------------------------

fn encode_step_payload(
    buf: &mut Vec<u8>,
    step_id: u64,
    accum: u32,
    collect_norms: bool,
    tasks: &[RankTask],
    params: &[Vec<f32>],
) {
    put_u8(buf, TAG_STEP);
    put_u64(buf, step_id);
    put_u32(buf, accum);
    put_u8(buf, collect_norms as u8);
    put_u64(buf, tasks.len() as u64);
    for t in tasks {
        put_u32(buf, t.rank);
        put_rng(buf, &t.cursor);
    }
    put_u64(buf, params.len() as u64);
    for p in params {
        put_f32s(buf, p);
    }
}

fn encode_payload(f: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    match f {
        Frame::Hello(h) => {
            put_u8(&mut buf, TAG_HELLO);
            put_u32(&mut buf, h.proto);
            put_u32(&mut buf, h.worker);
            put_str(&mut buf, &h.model);
            put_str(&mut buf, &h.backend);
            put_str(&mut buf, &h.artifacts);
            put_u64(&mut buf, h.seed);
            put_u64(&mut buf, h.corpus_bytes);
            put_u64(&mut buf, h.heartbeat_ms);
        }
        Frame::Ready(r) => {
            put_u8(&mut buf, TAG_READY);
            put_u32(&mut buf, r.worker);
            put_u32(&mut buf, r.pid);
        }
        Frame::Step(cmd) => {
            encode_step_payload(
                &mut buf,
                cmd.step_id,
                cmd.accum,
                cmd.collect_norms,
                &cmd.tasks,
                &cmd.params,
            );
        }
        Frame::Result(res) => {
            put_u8(&mut buf, TAG_RESULT);
            put_u64(&mut buf, res.step_id);
            put_u32(&mut buf, res.worker);
            put_u64(&mut buf, res.results.len() as u64);
            for r in &res.results {
                put_u32(&mut buf, r.rank);
                put_f64(&mut buf, r.loss);
                put_u32(&mut buf, r.n_micro);
                put_u64(&mut buf, r.microbatch);
                put_u64(&mut buf, r.n_examples);
                put_f64s(&mut buf, &r.perex_sum);
                match &r.sqnorms {
                    Some(v) => {
                        put_u8(&mut buf, 1);
                        put_f64s(&mut buf, v);
                    }
                    None => put_u8(&mut buf, 0),
                }
                put_rng(&mut buf, &r.cursor);
                put_u64(&mut buf, r.grads.len() as u64);
                for g in &r.grads {
                    put_f32s(&mut buf, g);
                }
            }
        }
        Frame::Heartbeat { worker, seq } => {
            put_u8(&mut buf, TAG_HEARTBEAT);
            put_u32(&mut buf, *worker);
            put_u64(&mut buf, *seq);
        }
        Frame::Error { worker, msg } => {
            put_u8(&mut buf, TAG_ERROR);
            put_u32(&mut buf, *worker);
            put_str(&mut buf, msg);
        }
        Frame::Shutdown => put_u8(&mut buf, TAG_SHUTDOWN),
    }
    buf
}

fn write_payload(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame payload {} exceeds bound", payload.len());
    }
    let crc = crc32(payload);
    // Fault injection (disarmed: one cached atomic load). A dropped frame
    // simply never reaches the wire; a corrupted one flips a
    // deterministically-chosen payload byte *after* the CRC was computed,
    // so the receiver sees a checksum mismatch — a rank fault, by design.
    let mut flip: Option<usize> = None;
    if faultkit::armed() {
        match faultkit::on_frame_send() {
            Some(FrameFault::Drop) => {
                eprintln!("faultkit: dropping outgoing frame ({} bytes)", payload.len());
                return Ok(());
            }
            Some(FrameFault::Corrupt) => {
                let at = faultkit::corrupt_index(payload.len(), crc as u64);
                eprintln!("faultkit: corrupting outgoing frame byte {at}");
                flip = Some(at);
            }
            None => {}
        }
    }
    w.write_all(&(payload.len() as u32).to_le_bytes()).context("writing frame length")?;
    match flip {
        None => w.write_all(payload).context("writing frame payload")?,
        Some(at) => {
            w.write_all(&payload[..at]).context("writing frame payload")?;
            w.write_all(&[payload[at] ^ 0x20]).context("writing frame payload")?;
            w.write_all(&payload[at + 1..]).context("writing frame payload")?;
        }
    }
    w.write_all(&crc.to_le_bytes()).context("writing frame crc")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Encode and write one frame.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<()> {
    write_payload(w, &encode_payload(f))
}

/// Write a `Step` frame without cloning the parameter blocks per worker:
/// the coordinator encodes each worker's tasks against one shared
/// parameter snapshot.
pub fn write_step(
    w: &mut impl Write,
    step_id: u64,
    accum: u32,
    collect_norms: bool,
    tasks: &[RankTask],
    params: &[Vec<f32>],
) -> Result<()> {
    let mut buf = Vec::new();
    encode_step_payload(&mut buf, step_id, accum, collect_norms, tasks, params);
    write_payload(w, &buf)
}

/// Read one frame; blocks until a full frame (or error/EOF) arrives.
/// The CRC-32 trailer is verified before any payload decoding, so a
/// corrupted frame is a [`ProtoError::CrcMismatch`], not a parse of
/// garbage bytes.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, ProtoError> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len < 1 {
        return Err(ProtoError::Malformed("empty frame"));
    }
    if len > MAX_FRAME {
        return Err(ProtoError::Oversize(len));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let mut crc4 = [0u8; 4];
    r.read_exact(&mut crc4)?;
    let wire = u32::from_le_bytes(crc4);
    let computed = crc32(&payload);
    if wire != computed {
        return Err(ProtoError::CrcMismatch { wire, computed });
    }
    decode_payload(&payload)
}

fn decode_payload(payload: &[u8]) -> Result<Frame, ProtoError> {
    let mut d = Dec::new(payload);
    let frame = match d.u8()? {
        TAG_HELLO => Frame::Hello(Hello {
            proto: d.u32()?,
            worker: d.u32()?,
            model: d.str()?,
            backend: d.str()?,
            artifacts: d.str()?,
            seed: d.u64()?,
            corpus_bytes: d.u64()?,
            heartbeat_ms: d.u64()?,
        }),
        TAG_READY => Frame::Ready(Ready { worker: d.u32()?, pid: d.u32()? }),
        TAG_STEP => {
            let step_id = d.u64()?;
            let accum = d.u32()?;
            let collect_norms = d.u8()? != 0;
            let n_tasks = d.len()?;
            let mut tasks = Vec::with_capacity(n_tasks);
            for _ in 0..n_tasks {
                tasks.push(RankTask { rank: d.u32()?, cursor: d.rng()? });
            }
            let n_params = d.len()?;
            let mut params = Vec::with_capacity(n_params);
            for _ in 0..n_params {
                params.push(d.f32s()?);
            }
            Frame::Step(StepCmd { step_id, accum, collect_norms, tasks, params })
        }
        TAG_RESULT => {
            let step_id = d.u64()?;
            let worker = d.u32()?;
            let n = d.len()?;
            let mut results = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = d.u32()?;
                let loss = d.f64()?;
                let n_micro = d.u32()?;
                let microbatch = d.u64()?;
                let n_examples = d.u64()?;
                let perex_sum = d.f64s()?;
                let sqnorms = match d.u8()? {
                    0 => None,
                    1 => Some(d.f64s()?),
                    _ => return Err(ProtoError::Malformed("bad sqnorms flag")),
                };
                let cursor = d.rng()?;
                let n_grads = d.len()?;
                let mut grads = Vec::with_capacity(n_grads);
                for _ in 0..n_grads {
                    grads.push(d.f32s()?);
                }
                results.push(RankResult {
                    rank,
                    loss,
                    n_micro,
                    microbatch,
                    n_examples,
                    perex_sum,
                    sqnorms,
                    cursor,
                    grads,
                });
            }
            Frame::Result(StepResult { step_id, worker, results })
        }
        TAG_HEARTBEAT => Frame::Heartbeat { worker: d.u32()?, seq: d.u64()? },
        TAG_ERROR => Frame::Error { worker: d.u32()?, msg: d.str()? },
        TAG_SHUTDOWN => Frame::Shutdown,
        other => return Err(ProtoError::UnknownTag(other)),
    };
    d.finish()?;
    Ok(frame)
}

// ---------------------------------------------------------------------
// Local socket transport
// ---------------------------------------------------------------------

/// A coordinator↔worker connection: a unix-domain socket where the
/// platform has them, a 127.0.0.1 TCP socket otherwise. Addresses are
/// self-describing strings (`unix:<path>` / `tcp:<sockaddr>`) so the
/// worker subcommand needs no transport flag.
pub enum Conn {
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
    Tcp(std::net::TcpStream),
}

impl Conn {
    /// Connect to a listener address produced by [`Listener::bind_local`].
    pub fn connect(addr: &str) -> Result<Self> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let s = std::os::unix::net::UnixStream::connect(path)
                    .with_context(|| format!("connecting unix socket {path}"))?;
                return Ok(Conn::Unix(s));
            }
            #[cfg(not(unix))]
            bail!("unix socket address {path:?} unsupported on this platform");
        }
        if let Some(sockaddr) = addr.strip_prefix("tcp:") {
            let s = std::net::TcpStream::connect(sockaddr)
                .with_context(|| format!("connecting tcp {sockaddr}"))?;
            return Ok(Conn::Tcp(s));
        }
        bail!("unrecognized worker address {addr:?}")
    }

    /// [`Conn::connect`] with bounded retry and exponential backoff —
    /// transient connect failures (listener backlog pressure, a
    /// coordinator momentarily between accepts) cost a short wait, not
    /// the worker. The backoff doubles from `base_backoff` up to 2 s.
    pub fn connect_retry(
        addr: &str,
        attempts: u32,
        base_backoff: std::time::Duration,
    ) -> Result<Self> {
        let attempts = attempts.max(1);
        let mut delay = base_backoff;
        let mut last: Option<anyhow::Error> = None;
        for attempt in 1..=attempts {
            let res = if faultkit::armed() && faultkit::on_connect_attempt() {
                Err(anyhow::anyhow!("injected connect failure (faultkit)"))
            } else {
                Self::connect(addr)
            };
            match res {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if attempt < attempts {
                        eprintln!(
                            "elastic: connect attempt {attempt}/{attempts} to {addr} \
                             failed ({e}); retrying in {delay:?}"
                        );
                        std::thread::sleep(delay);
                        delay = (delay * 2).min(std::time::Duration::from_secs(2));
                    }
                    last = Some(e);
                }
            }
        }
        Err(last.expect("at least one attempt"))
            .with_context(|| format!("connecting to {addr} after {attempts} attempts"))
    }

    /// Second handle onto the same socket (independent read/write halves).
    pub fn try_clone(&self) -> Result<Self> {
        Ok(match self {
            #[cfg(unix)]
            Conn::Unix(s) => Conn::Unix(s.try_clone().context("cloning unix socket")?),
            Conn::Tcp(s) => Conn::Tcp(s.try_clone().context("cloning tcp socket")?),
        })
    }

    pub fn set_read_timeout(&self, d: Option<std::time::Duration>) -> Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d)?,
            Conn::Tcp(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }

    pub fn set_nonblocking(&self, v: bool) -> Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(v)?,
            Conn::Tcp(s) => s.set_nonblocking(v)?,
        }
        Ok(())
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

/// Listening side of the transport, created by the coordinator before
/// spawning workers. Removes its socket file on drop (unix).
pub enum Listener {
    #[cfg(unix)]
    Unix { listener: std::os::unix::net::UnixListener, path: std::path::PathBuf },
    Tcp(std::net::TcpListener),
}

impl Listener {
    /// Bind a fresh local listener: a per-process unique unix socket in
    /// the temp dir, falling back to an ephemeral 127.0.0.1 TCP port.
    /// Returns the listener and the address string workers connect to.
    pub fn bind_local() -> Result<(Self, String)> {
        #[cfg(unix)]
        {
            use std::sync::atomic::{AtomicU64, Ordering};
            static COUNTER: AtomicU64 = AtomicU64::new(0);
            let n = COUNTER.fetch_add(1, Ordering::Relaxed);
            let path = std::env::temp_dir()
                .join(format!("nanogns-elastic-{}-{n}.sock", std::process::id()));
            let _ = std::fs::remove_file(&path);
            if let Ok(listener) = std::os::unix::net::UnixListener::bind(&path) {
                let addr = format!("unix:{}", path.display());
                return Ok((Listener::Unix { listener, path }, addr));
            }
        }
        let listener =
            std::net::TcpListener::bind(("127.0.0.1", 0)).context("binding tcp listener")?;
        let addr = format!("tcp:{}", listener.local_addr().context("tcp listener addr")?);
        Ok((Listener::Tcp(listener), addr))
    }

    pub fn set_nonblocking(&self, v: bool) -> Result<()> {
        match self {
            #[cfg(unix)]
            Listener::Unix { listener, .. } => listener.set_nonblocking(v)?,
            Listener::Tcp(l) => l.set_nonblocking(v)?,
        }
        Ok(())
    }

    /// Accept one connection; `io::Result` so callers can poll on
    /// `WouldBlock` while watching the child process.
    pub fn accept(&self) -> std::io::Result<Conn> {
        match self {
            #[cfg(unix)]
            Listener::Unix { listener, .. } => listener.accept().map(|(s, _)| Conn::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
        }
    }
}

#[cfg(unix)]
impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let mut wire = Vec::new();
        write_frame(&mut wire, f).unwrap();
        let mut cursor = &wire[..];
        let back = read_frame(&mut cursor).unwrap();
        assert!(cursor.is_empty(), "frame left trailing bytes on the wire");
        back
    }

    fn sample_cursor() -> RngState {
        RngState { s: [1, u64::MAX, 0xdead_beef, 42], spare: Some(-0.5) }
    }

    #[test]
    fn control_frames_roundtrip() {
        for f in [
            Frame::Ready(Ready { worker: 3, pid: 4242 }),
            Frame::Heartbeat { worker: 1, seq: 99 },
            Frame::Error { worker: 0, msg: "worker exploded: details".into() },
            Frame::Shutdown,
            Frame::Hello(Hello {
                proto: PROTO_VERSION,
                worker: 2,
                model: "nano".into(),
                backend: "reference".into(),
                artifacts: "artifacts".into(),
                seed: 7,
                corpus_bytes: 1 << 18,
                heartbeat_ms: 250,
            }),
        ] {
            assert_eq!(roundtrip(&f), f);
        }
    }

    #[test]
    fn step_and_result_roundtrip_bitwise() {
        let step = Frame::Step(StepCmd {
            step_id: 12,
            accum: 4,
            collect_norms: true,
            tasks: vec![
                RankTask { rank: 0, cursor: sample_cursor() },
                RankTask { rank: 2, cursor: RngState { s: [9, 8, 7, 6], spare: None } },
            ],
            params: vec![vec![1.0, -0.0, f32::MIN_POSITIVE], vec![], vec![2.5; 7]],
        });
        assert_eq!(roundtrip(&step), step);

        let result = Frame::Result(StepResult {
            step_id: 12,
            worker: 1,
            results: vec![RankResult {
                rank: 2,
                loss: 3.25e-3,
                n_micro: 4,
                microbatch: 8,
                n_examples: 32,
                perex_sum: vec![1.0e-9, 5.5, f64::MIN_POSITIVE],
                sqnorms: Some(vec![0.125, 7.0]),
                cursor: sample_cursor(),
                grads: vec![vec![0.5; 3], vec![-1.25]],
            }],
        });
        let back = roundtrip(&result);
        assert_eq!(back, result);
        // Float payloads must be bit-preserved, not just approximately equal.
        if let (Frame::Result(a), Frame::Result(b)) = (&back, &result) {
            assert_eq!(a.results[0].loss.to_bits(), b.results[0].loss.to_bits());
            assert_eq!(a.results[0].grads[0][0].to_bits(), b.results[0].grads[0][0].to_bits());
        }
    }

    #[test]
    fn write_step_matches_owned_encoding() {
        let cmd = StepCmd {
            step_id: 5,
            accum: 2,
            collect_norms: false,
            tasks: vec![RankTask { rank: 1, cursor: sample_cursor() }],
            params: vec![vec![1.0, 2.0], vec![3.0]],
        };
        let mut a = Vec::new();
        write_frame(&mut a, &Frame::Step(cmd.clone())).unwrap();
        let mut b = Vec::new();
        write_step(&mut b, cmd.step_id, cmd.accum, cmd.collect_norms, &cmd.tasks, &cmd.params)
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_truncation_oversize_and_trailing_garbage() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Frame::Heartbeat { worker: 0, seq: 1 }).unwrap();
        // Truncated payload: every strict prefix fails, none panic.
        for cut in 0..wire.len() {
            let mut cursor = &wire[..cut];
            assert!(read_frame(&mut cursor).is_err(), "prefix of {cut} bytes parsed");
        }
        // Oversize length prefix is rejected before allocating.
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes();
        assert!(read_frame(&mut &huge[..]).is_err());
        // Trailing garbage inside the declared payload is rejected.
        let mut padded = Vec::new();
        write_frame(&mut padded, &Frame::Shutdown).unwrap();
        padded[0] += 1; // lengthen the declared payload by one byte
        padded.push(0xff);
        assert!(read_frame(&mut &padded[..]).is_err());
        // Unknown tag (with a *valid* CRC, so the tag check is reached).
        let mut unknown = vec![1u8, 0, 0, 0, 200];
        unknown.extend_from_slice(&crc32(&[200]).to_le_bytes());
        assert!(matches!(read_frame(&mut &unknown[..]), Err(ProtoError::UnknownTag(200))));
    }

    #[test]
    fn corrupted_payload_is_a_crc_mismatch() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Frame::Error { worker: 3, msg: "payload".into() }).unwrap();
        let at = 4 + 3; // a byte in the middle of the payload
        wire[at] ^= 0x01;
        match read_frame(&mut &wire[..]) {
            Err(ProtoError::CrcMismatch { wire: w, computed }) => assert_ne!(w, computed),
            other => panic!("expected CrcMismatch, got {other:?}"),
        }
    }

    /// Satellite: property test — any random truncation or single-bit
    /// flip of a valid frame yields a typed [`ProtoError`]. Never a
    /// panic (a panic fails the test), never a silently-accepted frame.
    #[test]
    fn mutated_frames_yield_typed_errors_never_accepted() {
        use crate::util::prop::forall;
        let frames = [
            Frame::Ready(Ready { worker: 1, pid: 77 }),
            Frame::Heartbeat { worker: 0, seq: 12345 },
            Frame::Error { worker: 2, msg: "boom".into() },
            Frame::Shutdown,
            Frame::Hello(Hello {
                proto: PROTO_VERSION,
                worker: 0,
                model: "nano".into(),
                backend: "reference".into(),
                artifacts: "artifacts".into(),
                seed: 3,
                corpus_bytes: 1 << 16,
                heartbeat_ms: 100,
            }),
            Frame::Step(StepCmd {
                step_id: 9,
                accum: 2,
                collect_norms: true,
                tasks: vec![RankTask { rank: 1, cursor: sample_cursor() }],
                params: vec![vec![0.25; 64], vec![-1.5; 3]],
            }),
            Frame::Result(StepResult {
                step_id: 9,
                worker: 1,
                results: vec![RankResult {
                    rank: 1,
                    loss: 2.0,
                    n_micro: 2,
                    microbatch: 4,
                    n_examples: 8,
                    perex_sum: vec![0.5, 0.25],
                    sqnorms: None,
                    cursor: sample_cursor(),
                    grads: vec![vec![1.0; 16]],
                }],
            }),
        ];
        let wires: Vec<Vec<u8>> = frames
            .iter()
            .map(|f| {
                let mut w = Vec::new();
                write_frame(&mut w, f).unwrap();
                w
            })
            .collect();
        forall(
            0xFA017,
            600,
            |r| {
                let wi = r.range(0, wires.len());
                let wire = &wires[wi];
                if r.bool(0.5) {
                    let cut = r.range(0, wire.len());
                    (wi, wire[..cut].to_vec(), "truncation".to_string())
                } else {
                    let byte = r.range(0, wire.len());
                    let bit = r.range(0, 8);
                    let mut m = wire.clone();
                    m[byte] ^= 1 << bit;
                    (wi, m, format!("bit flip at {byte}:{bit}"))
                }
            },
            |(wi, mutated, what)| {
                let mut cursor = &mutated[..];
                match read_frame(&mut cursor) {
                    Err(_) => Ok(()), // typed error — exactly what we demand
                    Ok(f) => Err(format!("frame {wi} accepted after {what}: {f:?}")),
                }
            },
        );
    }

    #[test]
    fn connect_retry_eventually_fails_with_context() {
        // Nothing listens on this address; bounded retry must give up
        // with an error naming the attempt budget, not hang.
        let err = Conn::connect_retry(
            "tcp:127.0.0.1:1",
            2,
            std::time::Duration::from_millis(1),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("after 2 attempts"), "{err:#}");
    }

    #[test]
    fn frames_cross_a_real_local_socket() {
        let (listener, addr) = Listener::bind_local().unwrap();
        let want = Frame::Ready(Ready { worker: 7, pid: 1234 });
        let sent = want.clone();
        let client = std::thread::spawn(move || {
            let mut conn = Conn::connect(&addr).unwrap();
            write_frame(&mut conn, &sent).unwrap();
            let reply = read_frame(&mut conn).unwrap();
            assert_eq!(reply, Frame::Shutdown);
        });
        let mut server_side = listener.accept().unwrap();
        let got = read_frame(&mut server_side).unwrap();
        assert_eq!(got, want);
        write_frame(&mut server_side, &Frame::Shutdown).unwrap();
        client.join().unwrap();
    }
}
