//! Elastic process-isolated rank workers (ROADMAP item 4's fleet-shaped
//! step beyond scoped threads).
//!
//! Three pieces:
//! * [`protocol`] — the length-prefixed binary frame format and the
//!   local-socket transport (unix sockets, TCP-loopback fallback);
//! * [`supervisor`] — [`ElasticExecutor`], the coordinator-side engine:
//!   spawns/monitors workers (heartbeats + per-step deadlines) and
//!   reduces their partials through the shared fixed-order tree;
//! * [`worker`] — the child-process entry point behind the hidden
//!   `repro rank-worker` subcommand.
//!
//! The module's contract, proven by `tests/integration_elastic.rs` and
//! `tests/integration_faults.rs`: process mode is bitwise identical to
//! thread mode at the same rank count; losing a worker mid-run degrades
//! to the surviving ranks whose trajectories continue bitwise identical
//! to a thread-mode run at the reduced rank count; and a respawned
//! worker rejoins at a step boundary, after which the trajectory is
//! bitwise identical to a full-rank run again. Every frame carries a
//! CRC-32 trailer, so a torn or corrupted frame surfaces as a typed
//! protocol error (handled as a rank fault), never as silently accepted
//! bytes.

pub mod protocol;
pub mod supervisor;
pub mod worker;

pub use supervisor::{ElasticExecutor, RankHealth, RankOutcome, RejoinReport};
