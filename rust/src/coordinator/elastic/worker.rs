//! Rank-worker child process: the far side of the elastic protocol,
//! entered through the hidden `repro rank-worker` subcommand.
//!
//! A worker connects back to the coordinator, announces itself with
//! `Ready`, and receives a `Hello` carrying everything needed to rebuild
//! the training context — model name, backend name (through the
//! [`crate::runtime::BackendFactory::create_for_rank`] seam), and the
//! corpus seed/size. It then loops on `Step` commands: for each assigned
//! rank position it replays exactly the thread engine's accumulation
//! fold (zero grads → per microbatch: `next_batch`, `grad_step`,
//! stats fold, `accumulate`), so the partial it ships back is bitwise
//! identical to the one a scoped thread would have produced in-process.
//!
//! A side thread emits heartbeats at the coordinator-requested cadence
//! for the whole lifetime of the process; compute never blocks them.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::protocol::{self, Conn, Frame, Hello, RankResult, Ready, StepCmd, StepResult};
use crate::data::{CorpusGenerator, Loader};
use crate::gns::GnsAccumulator;
use crate::runtime::{Backend, BackendFactory, Buffer, ModelEntry, Tensor};
use crate::util::faultkit::{self, StepFault};
use crate::N_TYPES;

/// Build the backend factory named in the coordinator's `Hello`. Mirrors
/// the CLI's factory selection, minus the interactive error text. The
/// normalization variant rides over on NANOGNS_NORM / NANOGNS_PLACEMENT
/// (the launcher exports the resolved values before spawning workers),
/// so the child builds bitwise the same model as the coordinator.
fn factory_for(backend: &str, artifacts: &str) -> Result<Box<dyn BackendFactory>> {
    #[cfg(not(feature = "pjrt"))]
    let _ = artifacts;
    match backend {
        "reference" => {
            let norm = match std::env::var("NANOGNS_NORM") {
                Ok(v) => v.parse().context("rank worker: NANOGNS_NORM")?,
                Err(_) => crate::norms::NormKind::default(),
            };
            let placement = match std::env::var("NANOGNS_PLACEMENT") {
                Ok(v) => v.parse().context("rank worker: NANOGNS_PLACEMENT")?,
                Err(_) => crate::norms::NormPlacement::default(),
            };
            Ok(Box::new(crate::runtime::ReferenceVariantFactory::new(norm, placement)))
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(crate::runtime::PjrtFactory::new(artifacts)?)),
        other => bail!("rank worker: unsupported backend {other:?}"),
    }
}

/// Entry point for `repro rank-worker --connect <addr> --worker <n>`.
/// Returns when the coordinator sends `Shutdown` or the connection
/// closes; protocol or compute errors are reported over the wire first.
pub fn run_worker(connect: &str, worker: usize) -> Result<()> {
    // Scope the (test-only) fault plan to this worker index so plans like
    // `worker:1;worker.exit@step:3` only bite the intended victim.
    faultkit::set_scope(worker);
    // Transient connect failures (coordinator briefly saturated, race
    // with a respawn) get a handful of retries before we give up.
    let conn = Conn::connect_retry(connect, 5, Duration::from_millis(50))
        .with_context(|| format!("rank worker {worker}: connecting to coordinator"))?;
    let mut reader = conn.try_clone()?;
    let writer = Arc::new(Mutex::new(conn));
    {
        let mut wlock = writer.lock().expect("writer lock");
        protocol::write_frame(
            &mut *wlock,
            &Frame::Ready(Ready { worker: worker as u32, pid: std::process::id() }),
        )?;
    }
    let hello = match protocol::read_frame(&mut reader)? {
        Frame::Hello(h) => h,
        other => bail!("rank worker {worker}: expected Hello, got {other:?}"),
    };
    ensure!(
        hello.proto == protocol::PROTO_VERSION,
        "protocol version mismatch: coordinator {} vs worker {}",
        hello.proto,
        protocol::PROTO_VERSION
    );
    ensure!(
        hello.worker as usize == worker,
        "coordinator addressed worker {} but this is worker {worker}",
        hello.worker
    );

    // Heartbeats flow from a side thread for the process lifetime; the
    // stop flag only matters for the clean-shutdown path.
    let stop = Arc::new(AtomicBool::new(false));
    let hb_writer = Arc::clone(&writer);
    let hb_stop = Arc::clone(&stop);
    // `hb.delay@F` stretches the heartbeat period F× — the coordinator
    // sees a hung-but-alive worker and must fire its heartbeat deadline.
    let hb_period =
        Duration::from_millis(hello.heartbeat_ms.max(10).saturating_mul(faultkit::heartbeat_factor()));
    let hb = std::thread::spawn(move || {
        let mut seq = 0u64;
        loop {
            std::thread::sleep(hb_period);
            if hb_stop.load(Ordering::Relaxed) {
                return;
            }
            seq += 1;
            let mut w = match hb_writer.lock() {
                Ok(w) => w,
                Err(_) => return,
            };
            if protocol::write_frame(&mut *w, &Frame::Heartbeat { worker: worker as u32, seq })
                .is_err()
            {
                return;
            }
        }
    });

    let run = serve_steps(&hello, worker, &mut reader, &writer);
    stop.store(true, Ordering::Relaxed);
    if let Err(e) = &run {
        // Best-effort: tell the coordinator why before dying nonzero.
        if let Ok(mut w) = writer.lock() {
            let msg = format!("{e}");
            let _ = protocol::write_frame(&mut *w, &Frame::Error { worker: worker as u32, msg });
            let _ = w.flush();
        }
    }
    let _ = hb.join();
    run
}

/// The worker's steady-state loop: build the training context once, then
/// answer `Step` commands until `Shutdown` or EOF.
fn serve_steps(
    hello: &Hello,
    worker: usize,
    reader: &mut Conn,
    writer: &Arc<Mutex<Conn>>,
) -> Result<()> {
    let factory = factory_for(&hello.backend, &hello.artifacts)?;
    let be = factory.create_for_rank(&hello.model, worker)?;
    let entry = be.entry().clone();
    let text = CorpusGenerator::new(hello.seed).generate(hello.corpus_bytes as usize);
    let base = Loader::new(&text, entry.seq_len, hello.seed);

    loop {
        let cmd = match protocol::read_frame(reader) {
            Ok(Frame::Step(cmd)) => cmd,
            Ok(Frame::Shutdown) => return Ok(()),
            Ok(other) => bail!("rank worker {worker}: unexpected frame {other:?}"),
            // EOF here means the coordinator vanished without a Shutdown;
            // exiting nonzero is fine — nobody is left supervising us.
            Err(e) => {
                return Err(e).context(format!("rank worker {worker}: reading command"));
            }
        };
        match faultkit::on_step_command() {
            Some(StepFault::Exit) => {
                eprintln!("faultkit: rank worker {worker} exiting on step command (worker.exit)");
                std::process::exit(86);
            }
            Some(StepFault::StallMs(ms)) => {
                eprintln!("faultkit: rank worker {worker} stalling {ms}ms (step.stall)");
                std::thread::sleep(Duration::from_millis(ms));
            }
            None => {}
        }
        let result = run_step(be.as_ref(), &entry, &base, cmd, worker)?;
        let mut w = writer.lock().expect("writer lock");
        protocol::write_frame(&mut *w, &Frame::Result(result))?;
    }
}

/// Execute one `Step` command: per assigned rank position, the exact
/// accumulation fold the thread engine runs, against a loader rebuilt
/// from the coordinator-supplied cursor.
fn run_step(
    be: &dyn Backend,
    entry: &ModelEntry,
    base: &Loader,
    cmd: StepCmd,
    worker: usize,
) -> Result<StepResult> {
    ensure!(cmd.accum > 0, "step with accum = 0");
    ensure!(
        cmd.params.len() == entry.params.len(),
        "step carries {} parameter tensors, model has {}",
        cmd.params.len(),
        entry.params.len()
    );
    let params: Vec<Buffer> = cmd
        .params
        .into_iter()
        .zip(&entry.params)
        .map(|(data, spec)| {
            Tensor::new(spec.shape.clone(), data)
                .map(Buffer::from_tensor)
                .with_context(|| format!("bad parameter tensor {}", spec.name))
        })
        .collect::<Result<_>>()?;

    let mb = entry.microbatch;
    let mut results = Vec::with_capacity(cmd.tasks.len());
    for task in &cmd.tasks {
        let mut loader = base.clone();
        loader.restore_cursor(task.cursor);
        let mut acc = be.zero_grads()?;
        let mut stats = GnsAccumulator::new(N_TYPES, mb);
        let mut loss = 0f64;
        for _ in 0..cmd.accum {
            let batch = loader.next_batch(mb);
            let out = be.grad_step(&params, &batch)?;
            stats.add_microbatch(&out.stats);
            acc = be.accumulate(acc, &out.grads)?;
            loss += out.loss as f64;
        }
        let sqnorms = if cmd.collect_norms {
            Some(be.grad_sqnorms(&acc)?.to_vec())
        } else {
            None
        };
        let (microbatch, perex_sum, n_examples) = stats.export_parts();
        let grads: Vec<Vec<f32>> = acc
            .into_iter()
            .map(|b| b.into_host().map(|t| t.data))
            .collect::<Result<_>>()?;
        results.push(RankResult {
            rank: task.rank,
            loss,
            n_micro: cmd.accum,
            microbatch: microbatch as u64,
            n_examples: n_examples as u64,
            perex_sum,
            sqnorms,
            cursor: loader.cursor(),
            grads,
        });
    }
    Ok(StepResult { step_id: cmd.step_id, worker: worker as u32, results })
}
