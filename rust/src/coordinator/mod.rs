//! L3 coordinator: the training orchestration layer.
//!
//! * [`runner`] — owns a model's training state (params, Adam moments)
//!   and dispatches it through the `runtime::Backend` trait (init /
//!   grad_step / accumulate / adamw_update / grad_sqnorms / eval);
//! * [`parallel`] — the rank-parallel execution engine: one backend
//!   instance per worker thread, concurrent per-rank accumulation loops,
//!   and a fixed-order tree reduction that keeps results bitwise
//!   identical for any `NANOGNS_RANK_WORKERS` setting;
//! * [`trainer`] — the optimizer-step loop: rank-parallel gradient
//!   accumulation, online GNS tracking, LR + batch-size schedules,
//!   telemetry, checkpoint/resume;
//! * [`elastic`] — the process-isolated sibling of [`parallel`]: rank
//!   workers as supervised child processes over a length-prefixed local
//!   socket protocol, with heartbeat/deadline failure detection,
//!   drop-to-survivors reconciliation, and backoff-paced respawn/rejoin
//!   — same tree reduction, bitwise interchangeable with thread mode;
//! * [`ddp`] — distributed-data-parallel ranks, providing the taxonomy's
//!   *DDP* small-batch gradient-norm estimator to compare against the
//!   per-example method (Fig. 16);
//! * [`checkpoint`] — binary snapshots: params-only (v1) and full
//!   training state for bitwise-exact interrupt/resume (v3, with a
//!   per-section CRC-32 integrity chain and `keep_last` retention),
//!   published crash-safely (tmp → fsync → rename → dir fsync) and
//!   written off the training thread by a double-buffered writer that
//!   degrades to in-memory buffering on disk failure instead of
//!   silently sticking.
//!
//! Python never appears here: the default backend is pure Rust, and the
//! `pjrt` feature executes pre-compiled artifacts from disk.

pub mod checkpoint;
pub mod ddp;
pub mod elastic;
pub mod parallel;
pub mod runner;
pub mod trainer;

pub use elastic::{ElasticExecutor, RankHealth, RankOutcome, RejoinReport};
pub use parallel::{rank_workers, ParallelExecutor, RankStepOut};
pub use runner::ModelRunner;
pub use trainer::{StepObservation, StepObserver, TrainOutcome, Trainer};
