//! L3 coordinator: the training orchestration layer.
//!
//! * [`runner`] — owns a model's training state (params, Adam moments)
//!   and dispatches it through the `runtime::Backend` trait (init /
//!   grad_step / accumulate / adamw_update / grad_sqnorms / eval);
//! * [`trainer`] — the optimizer-step loop: microbatch gradient
//!   accumulation, online GNS tracking, LR + batch-size schedules,
//!   telemetry, checkpoints;
//! * [`ddp`] — simulated distributed-data-parallel ranks, providing the
//!   taxonomy's *DDP* small-batch gradient-norm estimator to compare
//!   against the per-example method (Fig. 16);
//! * [`checkpoint`] — binary param snapshots.
//!
//! Python never appears here: the default backend is pure Rust, and the
//! `pjrt` feature executes pre-compiled artifacts from disk.

pub mod checkpoint;
pub mod ddp;
pub mod runner;
pub mod trainer;

pub use runner::ModelRunner;
pub use trainer::{TrainOutcome, Trainer};
