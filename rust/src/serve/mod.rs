//! `repro serve`: a live telemetry daemon over a training run.
//!
//! Architecture (DESIGN.md §5): the trainer runs on one background
//! thread and publishes each completed step into a [`TelemetryHub`] via
//! the [`StepObserver`](crate::coordinator::StepObserver) hook; a small
//! HTTP/1.1 server ([`Server`]) answers pollers from the hub's cached
//! serialized responses. Three invariants the split buys:
//!
//! 1. **The run is untouched.** The observer fires after the CSV row is
//!    logged and any due checkpoint is written, so a served run's
//!    on-disk telemetry is identical (modulo wall-clock columns) to the
//!    same run without the daemon.
//! 2. **Pollers never block training.** The trainer's publish path takes
//!    one short lock; GET traffic reads version-keyed cached bodies.
//! 3. **Shutdown is graceful by construction.** `POST /shutdown` flips a
//!    flag the trainer polls at step boundaries; the trainer parks a
//!    final checkpoint (when configured) before the accept loop is
//!    allowed to exit.
//!
//! Endpoints: `/health`, `/status`, `/gns/layers`, `/gns/predictor`
//! (live norm-only vs total GNS fit), `/schedule`, `/ranks` (per-rank
//! liveness, elastic process mode), `/records?since=&limit=`,
//! `/metrics` (Prometheus text), and `POST /shutdown`. See README
//! "Live telemetry".

pub mod http;
pub mod hub;
pub mod ring;
pub mod server;

pub use hub::{HubMeta, RunState, TelemetryHub};
pub use ring::{RecordRing, RingEntry, RingSlice};
pub use server::Server;

use anyhow::Result;

use crate::coordinator::{TrainOutcome, Trainer};
use crate::util::json::Value;

/// Build the hub's immutable run metadata from a constructed trainer.
/// `bench_dir` (usually the workspace root) is scanned for `BENCH_*.json`
/// reports so `/status` can carry the machine's last known perf medians.
pub fn hub_meta(trainer: &Trainer, bench_dir: &std::path::Path) -> HubMeta {
    HubMeta {
        model: trainer.cfg.model.clone(),
        platform: trainer.runner.backend_name().to_string(),
        norm_kind: trainer.cfg.norm(),
        norm_placement: trainer.cfg.placement(),
        total_steps: trainer.cfg.steps,
        n_params: trainer.runner.entry.n_params,
        ranks: trainer.cfg.ranks.max(1),
        microbatch: trainer.runner.entry.microbatch,
        schedule: trainer.cfg.batch_size.to_json(),
        checkpoint_dir: trainer.cfg.checkpoint_dir.clone(),
        metrics_path: trainer.cfg.metrics_path.clone(),
        bench: load_bench_reports(bench_dir),
    }
}

/// Collect `BENCH_*.json` reports from `dir` into one object keyed by
/// report stem (`BENCH_train_step.json` → `"train_step"`). Unparseable
/// files are skipped — stale perf data must not stop a daemon.
pub fn load_bench_reports(dir: &std::path::Path) -> Option<Value> {
    let entries = std::fs::read_dir(dir).ok()?;
    let mut out = std::collections::BTreeMap::new();
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let Some(stem) = name.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json")) else {
            continue;
        };
        let Ok(text) = std::fs::read_to_string(entry.path()) else { continue };
        if let Ok(v) = Value::parse(&text) {
            out.insert(stem.to_string(), v);
        }
    }
    (!out.is_empty()).then_some(Value::Obj(out))
}

/// Run the trainer to completion on the *current* thread, publishing
/// into `hub`, and leave the hub in a terminal state no matter how the
/// run ends (finished, gracefully stopped, errored, or panicked). This
/// is the body of the daemon's training thread, shared with the
/// integration tests.
pub fn train_and_publish(trainer: &mut Trainer, hub: &TelemetryHub) -> Result<TrainOutcome> {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        trainer.run_with_observer(Some(hub))
    }));
    match result {
        Ok(Ok(outcome)) => {
            let stopped_early = hub.shutdown_requested() && trainer.runner.step < trainer.cfg.steps;
            // Park a final checkpoint on graceful early stop so the run
            // is resumable from its exact exit point (a full run already
            // wrote its last periodic checkpoint, if configured).
            let final_ckpt = if stopped_early && !trainer.cfg.checkpoint_dir.is_empty() {
                // checkpoint_now only queues the write on the writer
                // thread; block until it is durably published before
                // advertising the path on /status.
                match trainer.checkpoint_now().and_then(|p| {
                    trainer.wait_checkpoints()?;
                    Ok(p)
                }) {
                    Ok(p) => Some(p.display().to_string()),
                    Err(e) => {
                        hub.mark_done(
                            RunState::Failed,
                            Some(format!("final checkpoint failed: {e:#}")),
                            None,
                        );
                        return Err(e);
                    }
                }
            } else {
                None
            };
            let state = if stopped_early { RunState::Stopped } else { RunState::Finished };
            hub.mark_done(state, None, final_ckpt);
            Ok(outcome)
        }
        Ok(Err(e)) => {
            hub.mark_done(RunState::Failed, Some(format!("{e:#}")), None);
            Err(e)
        }
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "training thread panicked".to_string());
            hub.mark_done(RunState::Failed, Some(msg.clone()), None);
            Err(anyhow::anyhow!("training thread panicked: {msg}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_load_and_skip_garbage() {
        let dir = std::env::temp_dir().join(format!("nanogns-bench-scan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("BENCH_train_step.json"), r#"{"a":{"median_ns":1}}"#).unwrap();
        std::fs::write(dir.join("BENCH_broken.json"), "{nope").unwrap();
        std::fs::write(dir.join("unrelated.json"), "{}").unwrap();
        let v = load_bench_reports(&dir).unwrap();
        let obj = v.as_obj().unwrap();
        assert!(obj.contains_key("train_step"));
        assert!(!obj.contains_key("broken"));
        assert_eq!(obj.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_reports_none_when_absent() {
        let dir = std::env::temp_dir().join(format!("nanogns-bench-empty-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load_bench_reports(&dir).is_none());
        assert!(load_bench_reports(std::path::Path::new("/nonexistent-xyz")).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
