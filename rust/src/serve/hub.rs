//! The lock-light seam between one training thread and many pollers.
//!
//! The trainer publishes each step once ([`TelemetryHub`] implements
//! [`StepObserver`]); every HTTP worker reads *cached serialized
//! responses*. The concurrency contract:
//!
//! * the training thread takes the inner lock once per step, for the
//!   time it takes to push one pre-serialized record and update a few
//!   scalars — never proportional to poller traffic;
//! * pollers hit a version-stamped response cache; at most **one**
//!   rebuild per endpoint per published step reaches the inner state,
//!   no matter how many clients poll. Heavy traffic therefore costs
//!   `Arc<String>` clones, not JSON serialization and not trainer time;
//! * `/records` is parameterized by cursor so it reads the ring
//!   directly, but the ring stores records already serialized — the
//!   read assembles byte fragments only.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::trainer::{record_json, StepObservation, StepObserver, StepRecord};
use crate::coordinator::RankHealth;
use crate::gns::{linreg, GnsSnapshot};
use crate::norms::{NormKind, NormPlacement};
use crate::telemetry::summary::Decimated;
use crate::util::json::Value;

use super::ring::{RecordRing, RingSlice};

/// Maximum decimated loss-curve points carried by `/status`.
const LOSS_CURVE_MAX: usize = 1024;

/// Lifecycle of the run the hub fronts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunState {
    Running,
    /// Ran its full step budget.
    Finished,
    /// Stopped early by a graceful `POST /shutdown`.
    Stopped,
    /// Training thread returned an error (details in `/status.error`).
    Failed,
}

impl RunState {
    pub fn as_str(self) -> &'static str {
        match self {
            RunState::Running => "running",
            RunState::Finished => "finished",
            RunState::Stopped => "stopped",
            RunState::Failed => "failed",
        }
    }
}

/// Immutable run facts captured at daemon start.
#[derive(Debug, Clone)]
pub struct HubMeta {
    pub model: String,
    pub platform: String,
    /// Normalization variant of the served run (`/status`,
    /// `/gns/layers`, `/gns/predictor` all report it, so a dashboard
    /// polling several matrix cells can tell them apart).
    pub norm_kind: NormKind,
    pub norm_placement: NormPlacement,
    pub total_steps: u64,
    pub n_params: u64,
    pub ranks: usize,
    pub microbatch: usize,
    /// `BatchSizeSchedule::to_json` of the configured schedule.
    pub schedule: Value,
    pub checkpoint_dir: String,
    pub metrics_path: String,
    /// Medians harvested from `BENCH_*.json` reports, if any were found.
    pub bench: Option<Value>,
}

struct HubInner {
    ring: RecordRing,
    last: Option<StepRecord>,
    gns: Option<GnsSnapshot>,
    /// Controller hysteresis anchor after the last step.
    accum: usize,
    /// Per-rank liveness after the last step (`/ranks`).
    ranks: Vec<RankHealth>,
    loss_curve: Decimated,
    /// Per-step (norm-only GNS, total GNS) pairs for the live predictor
    /// fit, ring-bounded like the record ring. Only finite pairs enter
    /// (the first steps report NaN while the EMAs warm up).
    predictor: VecDeque<(f64, f64)>,
    predictor_cap: usize,
    state: RunState,
    error: Option<String>,
    /// Checkpoint-writer degradation notice (disk failures survived by
    /// falling back to in-memory buffering), surfaced on `/health`.
    checkpoint_error: Option<String>,
    final_checkpoint: Option<String>,
}

pub struct TelemetryHub {
    meta: HubMeta,
    inner: Mutex<HubInner>,
    /// Bumped on every state change; response caches key off it.
    version: AtomicU64,
    cache: Mutex<BTreeMap<&'static str, (u64, Arc<String>)>>,
    shutdown: AtomicBool,
    started: Instant,
    /// HTTP requests served (exposed on `/metrics`).
    pub requests: AtomicU64,
}

impl TelemetryHub {
    pub fn new(meta: HubMeta, ring_capacity: usize) -> Self {
        Self {
            meta,
            inner: Mutex::new(HubInner {
                ring: RecordRing::new(ring_capacity),
                last: None,
                gns: None,
                accum: 0,
                ranks: Vec::new(),
                loss_curve: Decimated::new(LOSS_CURVE_MAX),
                predictor: VecDeque::new(),
                predictor_cap: ring_capacity.max(2),
                state: RunState::Running,
                error: None,
                checkpoint_error: None,
                final_checkpoint: None,
            }),
            version: AtomicU64::new(0),
            cache: Mutex::new(BTreeMap::new()),
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
            requests: AtomicU64::new(0),
        }
    }

    pub fn meta(&self) -> &HubMeta {
        &self.meta
    }

    fn lock_inner(&self) -> std::sync::MutexGuard<'_, HubInner> {
        // A poisoned lock means a panic mid-publish; telemetry is
        // advisory, so serve the last consistent-enough state rather
        // than cascading the panic into every HTTP worker.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn bump(&self) {
        self.version.fetch_add(1, Ordering::Release);
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    // -- trainer side ------------------------------------------------------

    /// Publish one completed step (the [`StepObserver`] path).
    pub fn publish(&self, obs: &StepObservation<'_>) {
        // Serialize outside the lock: pollers and the cache rebuild are
        // never blocked on float formatting.
        let json = Arc::new(record_json(obs.record).to_string());
        let mut inner = self.lock_inner();
        inner.ring.push(obs.record.step, json);
        inner.loss_curve.push(obs.record.step as f64, obs.record.loss);
        let (ln, tot) = (obs.record.gns_layernorm, obs.record.gns_total);
        if ln.is_finite() && tot.is_finite() {
            if inner.predictor.len() == inner.predictor_cap {
                inner.predictor.pop_front();
            }
            inner.predictor.push_back((ln, tot));
        }
        inner.last = Some(obs.record.clone());
        inner.gns = Some(obs.gns.clone());
        inner.accum = obs.accum;
        inner.ranks = obs.ranks.clone();
        inner.checkpoint_error = obs.checkpoint_error.clone();
        drop(inner);
        self.bump();
    }

    /// Terminal state transition, called once by the training thread
    /// when `Trainer::run` returns (or dies).
    pub fn mark_done(&self, state: RunState, error: Option<String>, final_ckpt: Option<String>) {
        let mut inner = self.lock_inner();
        inner.state = state;
        inner.error = error;
        inner.final_checkpoint = final_ckpt;
        drop(inner);
        self.bump();
    }

    // -- shutdown handshake ------------------------------------------------

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.bump();
    }

    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    pub fn run_state(&self) -> RunState {
        self.lock_inner().state
    }

    /// The accept loop exits once shutdown was requested *and* the
    /// training thread has reached a terminal state (so the graceful
    /// checkpoint has been written and `/status` reflects it).
    pub fn server_should_exit(&self) -> bool {
        self.shutdown_requested() && self.run_state() != RunState::Running
    }

    // -- poller side -------------------------------------------------------

    /// Version-stamped response cache: returns the cached body when it
    /// matches the current hub version, else rebuilds via `build` and
    /// caches. `name` must be unique per endpoint.
    pub fn cached(&self, name: &'static str, build: impl FnOnce() -> String) -> Arc<String> {
        let v = self.version();
        {
            let cache = self.cache.lock().unwrap_or_else(|e| e.into_inner());
            if let Some((cv, body)) = cache.get(name) {
                if *cv == v {
                    return Arc::clone(body);
                }
            }
        }
        let body = Arc::new(build());
        let mut cache = self.cache.lock().unwrap_or_else(|e| e.into_inner());
        cache.insert(name, (v, Arc::clone(&body)));
        body
    }

    pub fn body_health(&self) -> String {
        let inner = self.lock_inner();
        let mut m = BTreeMap::new();
        // A run limping along on the in-memory checkpoint fallback is
        // alive but not healthy; monitors keying on "status" see it.
        let status = if inner.checkpoint_error.is_some() { "degraded" } else { "ok" };
        m.insert("status".into(), Value::Str(status.into()));
        m.insert("state".into(), Value::Str(inner.state.as_str().into()));
        m.insert(
            "step".into(),
            Value::Num(inner.last.as_ref().map(|r| r.step).unwrap_or(0) as f64),
        );
        m.insert(
            "checkpoint_error".into(),
            inner
                .checkpoint_error
                .as_ref()
                .map(|e| Value::Str(e.clone()))
                .unwrap_or(Value::Null),
        );
        drop(inner);
        m.insert("uptime_s".into(), Value::Num(self.started.elapsed().as_secs_f64()));
        Value::Obj(m).to_string()
    }

    pub fn body_status(&self) -> String {
        let inner = self.lock_inner();
        let mut m = BTreeMap::new();
        m.insert("model".into(), Value::Str(self.meta.model.clone()));
        m.insert("platform".into(), Value::Str(self.meta.platform.clone()));
        m.insert("norm_kind".into(), Value::Str(self.meta.norm_kind.name().into()));
        m.insert("norm_placement".into(), Value::Str(self.meta.norm_placement.name().into()));
        m.insert("state".into(), Value::Str(inner.state.as_str().into()));
        m.insert("total_steps".into(), Value::Num(self.meta.total_steps as f64));
        m.insert("n_params".into(), Value::Num(self.meta.n_params as f64));
        m.insert("ranks".into(), Value::Num(self.meta.ranks as f64));
        m.insert("microbatch".into(), Value::Num(self.meta.microbatch as f64));
        m.insert("uptime_s".into(), Value::Num(self.started.elapsed().as_secs_f64()));
        m.insert("shutdown_requested".into(), Value::Bool(self.shutdown_requested()));
        m.insert("checkpoint_dir".into(), Value::Str(self.meta.checkpoint_dir.clone()));
        m.insert("metrics_path".into(), Value::Str(self.meta.metrics_path.clone()));
        m.insert(
            "error".into(),
            inner.error.as_ref().map(|e| Value::Str(e.clone())).unwrap_or(Value::Null),
        );
        m.insert(
            "final_checkpoint".into(),
            inner
                .final_checkpoint
                .as_ref()
                .map(|p| Value::Str(p.clone()))
                .unwrap_or(Value::Null),
        );
        m.insert("last".into(), inner.last.as_ref().map(record_json).unwrap_or(Value::Null));
        let curve: Vec<Value> = inner
            .loss_curve
            .points()
            .iter()
            .map(|&(s, l)| Value::Arr(vec![Value::Num(s), Value::finite_or_null(l)]))
            .collect();
        m.insert("loss_curve".into(), Value::Arr(curve));
        m.insert("loss_curve_stride".into(), Value::Num(inner.loss_curve.stride() as f64));
        let mut ring = BTreeMap::new();
        ring.insert("capacity".into(), Value::Num(inner.ring.capacity() as f64));
        ring.insert("len".into(), Value::Num(inner.ring.len() as f64));
        ring.insert("dropped".into(), Value::Num(inner.ring.dropped() as f64));
        ring.insert(
            "first_step".into(),
            inner.ring.first_step().map(|s| Value::Num(s as f64)).unwrap_or(Value::Null),
        );
        ring.insert(
            "last_step".into(),
            inner.ring.last_step().map(|s| Value::Num(s as f64)).unwrap_or(Value::Null),
        );
        m.insert("ring".into(), Value::Obj(ring));
        m.insert("bench".into(), self.meta.bench.clone().unwrap_or(Value::Null));
        Value::Obj(m).to_string()
    }

    pub fn body_gns_layers(&self) -> String {
        let inner = self.lock_inner();
        let mut m = BTreeMap::new();
        m.insert(
            "step".into(),
            Value::Num(inner.last.as_ref().map(|r| r.step).unwrap_or(0) as f64),
        );
        m.insert("norm_kind".into(), Value::Str(self.meta.norm_kind.name().into()));
        m.insert("norm_placement".into(), Value::Str(self.meta.norm_placement.name().into()));
        match inner.gns.as_ref() {
            None => {
                m.insert("per_layer".into(), Value::Obj(BTreeMap::new()));
                m.insert("total".into(), Value::Null);
            }
            Some(snap) => {
                let mut per = BTreeMap::new();
                for (t, s) in &snap.per_type {
                    per.insert(t.clone(), type_snapshot_json(s));
                }
                m.insert("per_layer".into(), Value::Obj(per));
                m.insert("total".into(), type_snapshot_json(&snap.total));
            }
        }
        Value::Obj(m).to_string()
    }

    /// `/gns/predictor` body: the live norm-only-vs-total GNS fit over
    /// the ring-bounded pair history — OLS of total on norm-only GNS
    /// (slope/intercept/R²) plus the ratio of means, the same quantities
    /// `repro figures --report predictor` scores offline per matrix
    /// cell. `fit` is null until two finite pairs with x-variance exist.
    pub fn body_gns_predictor(&self) -> String {
        let inner = self.lock_inner();
        let mut m = BTreeMap::new();
        m.insert(
            "step".into(),
            Value::Num(inner.last.as_ref().map(|r| r.step).unwrap_or(0) as f64),
        );
        m.insert("norm_kind".into(), Value::Str(self.meta.norm_kind.name().into()));
        m.insert("norm_placement".into(), Value::Str(self.meta.norm_placement.name().into()));
        m.insert(
            "gns_layernorm".into(),
            inner
                .last
                .as_ref()
                .map(|r| Value::finite_or_null(r.gns_layernorm))
                .unwrap_or(Value::Null),
        );
        m.insert(
            "gns_total".into(),
            inner
                .last
                .as_ref()
                .map(|r| Value::finite_or_null(r.gns_total))
                .unwrap_or(Value::Null),
        );
        let (x, y): (Vec<f64>, Vec<f64>) = inner.predictor.iter().copied().unzip();
        drop(inner);
        m.insert("points".into(), Value::Num(x.len() as f64));
        let fit = linreg(&x, &y).map(|reg| {
            let mx = x.iter().sum::<f64>() / x.len() as f64;
            let my = y.iter().sum::<f64>() / y.len() as f64;
            let mut f = BTreeMap::new();
            f.insert("slope".into(), Value::finite_or_null(reg.slope));
            f.insert("intercept".into(), Value::finite_or_null(reg.intercept));
            f.insert("r2".into(), Value::finite_or_null(reg.r * reg.r));
            f.insert("ratio".into(), Value::finite_or_null(my / mx));
            Value::Obj(f)
        });
        m.insert("fit".into(), fit.unwrap_or(Value::Null));
        Value::Obj(m).to_string()
    }

    pub fn body_schedule(&self) -> String {
        let inner = self.lock_inner();
        let mut m = BTreeMap::new();
        m.insert("schedule".into(), self.meta.schedule.clone());
        m.insert("accum".into(), Value::Num(inner.accum as f64));
        m.insert(
            "b_big".into(),
            inner.last.as_ref().map(|r| Value::Num(r.b_big)).unwrap_or(Value::Null),
        );
        m.insert("microbatch".into(), Value::Num(self.meta.microbatch as f64));
        m.insert("ranks".into(), Value::Num(self.meta.ranks as f64));
        m.insert(
            "gns_total".into(),
            inner
                .last
                .as_ref()
                .map(|r| Value::finite_or_null(r.gns_total))
                .unwrap_or(Value::Null),
        );
        Value::Obj(m).to_string()
    }

    /// Prometheus text exposition (`text/plain`). NaN is a legal sample
    /// value in this format, so raw floats go out unguarded.
    pub fn body_metrics(&self) -> String {
        use std::fmt::Write as _;
        fn gauge(out: &mut String, name: &str, labels: &str, v: f64) {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name}{labels} {v}");
        }
        let inner = self.lock_inner();
        let mut out = String::with_capacity(1024);
        if let Some(r) = inner.last.as_ref() {
            gauge(&mut out, "nanogns_step", "", r.step as f64);
            gauge(&mut out, "nanogns_tokens", "", r.tokens as f64);
            gauge(&mut out, "nanogns_loss", "", r.loss);
            gauge(&mut out, "nanogns_lr", "", r.lr);
            gauge(&mut out, "nanogns_accum", "", r.accum as f64);
            gauge(&mut out, "nanogns_b_big", "", r.b_big);
            gauge(&mut out, "nanogns_gns_total", "", r.gns_total);
            gauge(&mut out, "nanogns_step_ms", "", r.step_ms);
        }
        if let Some(snap) = inner.gns.as_ref() {
            let _ = writeln!(out, "# TYPE nanogns_gns gauge");
            for (t, s) in &snap.per_type {
                let v = s.gns.unwrap_or(f64::NAN);
                let _ = writeln!(out, "nanogns_gns{{layer=\"{t}\"}} {v}");
            }
        }
        gauge(&mut out, "nanogns_ring_dropped", "", inner.ring.dropped() as f64);
        gauge(
            &mut out,
            "nanogns_ranks_alive",
            "",
            inner.ranks.iter().filter(|h| h.alive).count() as f64,
        );
        gauge(
            &mut out,
            "nanogns_rank_respawns_total",
            "",
            inner.ranks.iter().map(|h| h.respawns).sum::<u64>() as f64,
        );
        gauge(
            &mut out,
            "nanogns_ckpt_degraded",
            "",
            if inner.checkpoint_error.is_some() { 1.0 } else { 0.0 },
        );
        let state = inner.state;
        drop(inner);
        gauge(&mut out, "nanogns_uptime_seconds", "", self.started.elapsed().as_secs_f64());
        gauge(
            &mut out,
            "nanogns_http_requests_total",
            "",
            self.requests.load(Ordering::Relaxed) as f64,
        );
        gauge(
            &mut out,
            "nanogns_run_finished",
            "",
            if state == RunState::Running { 0.0 } else { 1.0 },
        );
        out
    }

    /// `/ranks` body: per-rank liveness as of the last published step
    /// (worker pids, heartbeat ages, and post-reconciliation survival in
    /// elastic process mode; synthesized always-alive entries in thread
    /// mode).
    pub fn body_ranks(&self) -> String {
        let inner = self.lock_inner();
        let mut m = BTreeMap::new();
        m.insert(
            "step".into(),
            Value::Num(inner.last.as_ref().map(|r| r.step).unwrap_or(0) as f64),
        );
        m.insert("configured_ranks".into(), Value::Num(self.meta.ranks as f64));
        let mode = inner.ranks.first().map(|h| h.mode).unwrap_or("thread");
        m.insert("mode".into(), Value::Str(mode.into()));
        m.insert(
            "alive".into(),
            Value::Num(inner.ranks.iter().filter(|h| h.alive).count() as f64),
        );
        m.insert(
            "respawns_total".into(),
            Value::Num(inner.ranks.iter().map(|h| h.respawns).sum::<u64>() as f64),
        );
        m.insert(
            "fault_plan".into(),
            crate::util::faultkit::plan()
                .map(|p| Value::Str(p.text().to_string()))
                .unwrap_or(Value::Null),
        );
        let arr: Vec<Value> = inner
            .ranks
            .iter()
            .map(|h| {
                let mut e = BTreeMap::new();
                e.insert("rank".into(), Value::Num(h.rank as f64));
                e.insert("alive".into(), Value::Bool(h.alive));
                e.insert(
                    "pid".into(),
                    h.pid.map(|p| Value::Num(p as f64)).unwrap_or(Value::Null),
                );
                e.insert("last_step".into(), Value::Num(h.last_step as f64));
                e.insert(
                    "heartbeat_age_ms".into(),
                    h.heartbeat_age_ms.map(Value::finite_or_null).unwrap_or(Value::Null),
                );
                e.insert("respawns".into(), Value::Num(h.respawns as f64));
                Value::Obj(e)
            })
            .collect();
        m.insert("ranks".into(), Value::Arr(arr));
        Value::Obj(m).to_string()
    }

    /// `/records?since=&limit=` body: assembled from the ring's
    /// pre-serialized fragments — no per-request float formatting.
    pub fn body_records(&self, since: u64, limit: usize) -> String {
        let slice: RingSlice;
        let (dropped, capacity, state) = {
            let inner = self.lock_inner();
            slice = inner.ring.since(since, limit);
            (inner.ring.dropped(), inner.ring.capacity(), inner.state)
        };
        let frag_bytes: usize = slice.entries.iter().map(|e| e.json.len() + 1).sum();
        let mut out = String::with_capacity(64 + frag_bytes);
        out.push_str("{\"records\":[");
        for (i, e) in slice.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&e.json);
        }
        out.push_str("],\"next_since\":");
        out.push_str(&slice.next_since.to_string());
        out.push_str(",\"truncated\":");
        out.push_str(if slice.truncated { "true" } else { "false" });
        // A cursor that fell off the ring would otherwise skip steps
        // silently; `gap` makes the loss explicit and `oldest_step` says
        // where the retained history restarts.
        out.push_str(",\"gap\":");
        out.push_str(if slice.gap { "true" } else { "false" });
        out.push_str(",\"oldest_step\":");
        match slice.oldest_step {
            Some(s) => out.push_str(&s.to_string()),
            None => out.push_str("null"),
        }
        out.push_str(",\"dropped\":");
        out.push_str(&dropped.to_string());
        out.push_str(",\"ring_capacity\":");
        out.push_str(&capacity.to_string());
        out.push_str(",\"state\":\"");
        out.push_str(state.as_str());
        out.push_str("\"}");
        out
    }
}

fn type_snapshot_json(s: &crate::gns::TypeSnapshot) -> Value {
    let mut m = BTreeMap::new();
    m.insert("g_sq".into(), Value::finite_or_null(s.g_sq));
    m.insert("s".into(), Value::finite_or_null(s.s));
    m.insert("gns".into(), s.gns.map(Value::finite_or_null).unwrap_or(Value::Null));
    Value::Obj(m)
}

impl StepObserver for TelemetryHub {
    fn on_step(&self, obs: &StepObservation<'_>) {
        self.publish(obs);
    }

    fn stop_requested(&self) -> bool {
        self.shutdown_requested()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_meta() -> HubMeta {
        HubMeta {
            model: "nano".into(),
            platform: "test".into(),
            norm_kind: NormKind::default(),
            norm_placement: NormPlacement::default(),
            total_steps: 10,
            n_params: 123,
            ranks: 1,
            microbatch: 4,
            schedule: crate::schedule::BatchSizeSchedule::Fixed { accum: 2 }.to_json(),
            checkpoint_dir: String::new(),
            metrics_path: String::new(),
            bench: None,
        }
    }

    fn rec(step: u64) -> StepRecord {
        StepRecord {
            step,
            tokens: step * 64,
            loss: 5.0 - step as f64 * 0.1,
            lr: 1e-3,
            accum: 2,
            b_big: 8.0,
            raw_g_sq: [1.0; crate::N_TYPES],
            raw_s: [2.0; crate::N_TYPES],
            raw_g_sq_total: 5.0,
            raw_s_total: 10.0,
            gns_layernorm: 2.0,
            gns_total: 2.0,
            step_ms: 1.0,
        }
    }

    fn publish_with(hub: &TelemetryHub, step: u64, checkpoint_error: Option<String>) {
        let r = rec(step);
        let mut tracker = crate::gns::GnsTracker::new(&crate::STATS_ORDER, 0.5);
        tracker.observe(8.0, &[1.0; crate::N_TYPES], &[3.0; crate::N_TYPES]);
        hub.publish(&StepObservation {
            record: &r,
            gns: tracker.snapshot(),
            accum: 2,
            total_steps: 10,
            ranks: vec![
                RankHealth {
                    rank: 0,
                    alive: true,
                    pid: Some(4242),
                    last_step: step,
                    heartbeat_age_ms: Some(12.5),
                    respawns: 2,
                    mode: "process",
                },
                RankHealth {
                    rank: 1,
                    alive: false,
                    pid: None,
                    last_step: step.saturating_sub(1),
                    heartbeat_age_ms: None,
                    respawns: 0,
                    mode: "process",
                },
            ],
            checkpoint_error,
        });
    }

    fn publish(hub: &TelemetryHub, step: u64) {
        publish_with(hub, step, None);
    }

    #[test]
    fn bodies_are_valid_json_and_track_state() {
        let hub = TelemetryHub::new(test_meta(), 8);
        // pre-first-step bodies parse too
        let bodies =
            [hub.body_health(), hub.body_status(), hub.body_gns_layers(), hub.body_schedule()];
        for body in bodies {
            Value::parse(&body).unwrap();
        }
        publish(&hub, 1);
        publish(&hub, 2);
        let st = Value::parse(&hub.body_status()).unwrap();
        assert_eq!(st.get("state").unwrap().as_str().unwrap(), "running");
        assert_eq!(st.get("last").unwrap().get("step").unwrap().as_u64().unwrap(), 2);
        assert_eq!(st.get("loss_curve").unwrap().as_arr().unwrap().len(), 2);
        let gl = Value::parse(&hub.body_gns_layers()).unwrap();
        assert_eq!(gl.get("per_layer").unwrap().as_obj().unwrap().len(), crate::N_TYPES);
        let recs = Value::parse(&hub.body_records(0, 100)).unwrap();
        assert_eq!(recs.get("records").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(recs.get("next_since").unwrap().as_u64().unwrap(), 2);
        hub.mark_done(RunState::Finished, None, None);
        let st = Value::parse(&hub.body_status()).unwrap();
        assert_eq!(st.get("state").unwrap().as_str().unwrap(), "finished");
    }

    /// Publish like [`publish`], but with explicit (norm-only, total)
    /// GNS values so the predictor fit has a known line to recover.
    fn publish_gns(hub: &TelemetryHub, step: u64, ln: f64, tot: f64) {
        let mut r = rec(step);
        r.gns_layernorm = ln;
        r.gns_total = tot;
        let mut tracker = crate::gns::GnsTracker::new(&crate::STATS_ORDER, 0.5);
        tracker.observe(8.0, &[1.0; crate::N_TYPES], &[3.0; crate::N_TYPES]);
        hub.publish(&StepObservation {
            record: &r,
            gns: tracker.snapshot(),
            accum: 2,
            total_steps: 10,
            ranks: Vec::new(),
            checkpoint_error: None,
        });
    }

    #[test]
    fn predictor_body_recovers_the_fit_and_reports_the_variant() {
        let hub = TelemetryHub::new(test_meta(), 8);
        // No data yet: valid JSON, null fit, zero points.
        let empty = Value::parse(&hub.body_gns_predictor()).unwrap();
        assert_eq!(empty.get("points").unwrap().as_u64().unwrap(), 0);
        assert!(matches!(empty.opt("fit"), Some(Value::Null)));
        assert_eq!(empty.get("norm_kind").unwrap().as_str().unwrap(), "layernorm");
        assert_eq!(empty.get("norm_placement").unwrap().as_str().unwrap(), "preln");
        // NaN pairs (EMA warm-up) never enter the fit window.
        publish_gns(&hub, 1, f64::NAN, 3.0);
        // total = 2.5 * norm_only exactly → slope 2.5, r2 1.
        for (i, ln) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            publish_gns(&hub, 2 + i as u64, *ln, 2.5 * ln);
        }
        let v = Value::parse(&hub.body_gns_predictor()).unwrap();
        assert_eq!(v.get("points").unwrap().as_u64().unwrap(), 4);
        let fit = v.get("fit").unwrap();
        assert!((fit.get("slope").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        assert!((fit.get("r2").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!((fit.get("ratio").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        assert_eq!(v.get("step").unwrap().as_u64().unwrap(), 5);
    }

    #[test]
    fn predictor_window_is_ring_bounded() {
        let hub = TelemetryHub::new(test_meta(), 4);
        for s in 1..=10u64 {
            publish_gns(&hub, s, s as f64, 2.0 * s as f64);
        }
        let v = Value::parse(&hub.body_gns_predictor()).unwrap();
        assert_eq!(v.get("points").unwrap().as_u64().unwrap(), 4);
    }

    #[test]
    fn cache_serves_same_arc_until_version_bump() {
        let hub = TelemetryHub::new(test_meta(), 8);
        publish(&hub, 1);
        let a = hub.cached("status", || hub.body_status());
        let b = hub.cached("status", || panic!("must not rebuild at same version"));
        assert!(Arc::ptr_eq(&a, &b));
        publish(&hub, 2);
        let c = hub.cached("status", || hub.body_status());
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn shutdown_handshake_gates_server_exit() {
        let hub = TelemetryHub::new(test_meta(), 8);
        assert!(!hub.server_should_exit());
        hub.request_shutdown();
        // training thread has not stopped yet
        assert!(!hub.server_should_exit());
        assert!(hub.stop_requested());
        hub.mark_done(RunState::Stopped, None, None);
        assert!(hub.server_should_exit());
    }

    #[test]
    fn ranks_body_reports_liveness_and_records_flag_gaps() {
        let hub = TelemetryHub::new(test_meta(), 4);
        // before any step: empty rank list, thread-mode default
        let empty = Value::parse(&hub.body_ranks()).unwrap();
        assert_eq!(empty.get("mode").unwrap().as_str().unwrap(), "thread");
        assert_eq!(empty.get("alive").unwrap().as_u64().unwrap(), 0);
        publish(&hub, 1);
        let v = Value::parse(&hub.body_ranks()).unwrap();
        assert_eq!(v.get("step").unwrap().as_u64().unwrap(), 1);
        assert_eq!(v.get("mode").unwrap().as_str().unwrap(), "process");
        assert_eq!(v.get("alive").unwrap().as_u64().unwrap(), 1);
        let ranks = v.get("ranks").unwrap().as_arr().unwrap();
        assert_eq!(ranks.len(), 2);
        assert_eq!(ranks[0].get("pid").unwrap().as_u64().unwrap(), 4242);
        assert!(matches!(ranks[1].opt("pid"), Some(Value::Null)));
        assert_eq!(ranks[0].get("respawns").unwrap().as_u64().unwrap(), 2);
        assert_eq!(v.get("respawns_total").unwrap().as_u64().unwrap(), 2);
        // ring holds 4: steps 1..=6 evict 1 and 2 → cursor 1 has a gap
        for s in 2..=6 {
            publish(&hub, s);
        }
        let recs = Value::parse(&hub.body_records(1, 100)).unwrap();
        assert!(recs.get("gap").unwrap().as_bool().unwrap());
        assert_eq!(recs.get("oldest_step").unwrap().as_u64().unwrap(), 3);
        let ok = Value::parse(&hub.body_records(5, 100)).unwrap();
        assert!(!ok.get("gap").unwrap().as_bool().unwrap());
    }

    #[test]
    fn metrics_exposition_contains_core_series() {
        let hub = TelemetryHub::new(test_meta(), 8);
        publish(&hub, 3);
        let m = hub.body_metrics();
        let needles = [
            "nanogns_step 3",
            "nanogns_gns{layer=\"layernorm\"}",
            "nanogns_uptime_seconds",
            "nanogns_ranks_alive 1",
            "nanogns_rank_respawns_total 2",
            "nanogns_ckpt_degraded 0",
        ];
        for needle in needles {
            assert!(m.contains(needle), "missing {needle} in:\n{m}");
        }
    }

    #[test]
    fn checkpoint_degradation_surfaces_on_health_and_metrics() {
        let hub = TelemetryHub::new(test_meta(), 8);
        publish(&hub, 1);
        let h = Value::parse(&hub.body_health()).unwrap();
        assert_eq!(h.get("status").unwrap().as_str().unwrap(), "ok");
        assert!(matches!(h.opt("checkpoint_error"), Some(Value::Null)));

        publish_with(&hub, 2, Some("checkpoint writes failing: no space".into()));
        let h = Value::parse(&hub.body_health()).unwrap();
        assert_eq!(h.get("status").unwrap().as_str().unwrap(), "degraded");
        assert!(h
            .get("checkpoint_error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("no space"));
        assert!(hub.body_metrics().contains("nanogns_ckpt_degraded 1"));

        // recovery clears the flag
        publish(&hub, 3);
        let h = Value::parse(&hub.body_health()).unwrap();
        assert_eq!(h.get("status").unwrap().as_str().unwrap(), "ok");
    }
}
