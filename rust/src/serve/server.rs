//! The daemon's network half: a nonblocking accept loop feeding a small
//! worker pool over an mpsc channel. Workers route requests against the
//! [`TelemetryHub`]; the accept loop polls `hub.server_should_exit()`
//! between accepts so a graceful `POST /shutdown` unwinds the whole
//! daemon once the training thread has parked its final checkpoint.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use super::http::{bad_request, read_request, Request, Response};
use super::hub::TelemetryHub;

/// Workers serving requests concurrently. Small on purpose: responses
/// are cached `Arc<String>` clones, so per-request work is socket I/O.
const WORKERS: usize = 4;

/// Accept-loop poll interval while the listener has no pending client.
const IDLE_POLL: Duration = Duration::from_millis(5);

/// Per-connection socket deadline so one stalled client cannot wedge a
/// worker forever.
const IO_TIMEOUT: Duration = Duration::from_millis(2000);

/// Overall budget for receiving one full request head. Unlike
/// `IO_TIMEOUT` (which resets on every byte and so can be ridden
/// indefinitely by a trickling client), this bounds the whole read.
const REQUEST_DEADLINE: Duration = Duration::from_secs(10);

/// Default and maximum `limit` for `GET /records`.
const RECORDS_DEFAULT_LIMIT: usize = 256;
const RECORDS_MAX_LIMIT: usize = 4096;

pub struct Server {
    listener: TcpListener,
    hub: Arc<TelemetryHub>,
}

impl Server {
    /// Bind `bind:port` (port 0 picks an ephemeral port — used by the
    /// integration tests) and report the bound address.
    pub fn bind(bind: &str, port: u16, hub: Arc<TelemetryHub>) -> Result<Self> {
        let listener = TcpListener::bind((bind, port))
            .with_context(|| format!("binding telemetry server to {bind}:{port}"))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        Ok(Self { listener, hub })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until [`TelemetryHub::server_should_exit`] turns true:
    /// shutdown was requested *and* the training thread reached a
    /// terminal state (its graceful checkpoint is on disk).
    pub fn serve(self) -> Result<()> {
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(WORKERS);
        for w in 0..WORKERS {
            let rx = Arc::clone(&rx);
            let hub = Arc::clone(&self.hub);
            workers.push(
                thread::Builder::new()
                    .name(format!("serve-worker-{w}"))
                    .spawn(move || worker_loop(&rx, &hub))
                    .context("spawning serve worker")?,
            );
        }

        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
                    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
                    if tx.send(stream).is_err() {
                        break; // all workers gone (unreachable in practice)
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if self.hub.server_should_exit() {
                        break;
                    }
                    thread::sleep(IDLE_POLL);
                }
                Err(e) => return Err(e).context("accepting connection"),
            }
        }

        // Dropping the sender disconnects the channel; workers drain any
        // queued connections, observe the disconnect, and exit.
        drop(tx);
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}

fn worker_loop(rx: &Arc<Mutex<mpsc::Receiver<TcpStream>>>, hub: &Arc<TelemetryHub>) {
    loop {
        let stream = {
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv()
        };
        let Ok(mut stream) = stream else { return };
        hub.requests.fetch_add(1, Ordering::Relaxed);
        let response = match read_request(&stream, REQUEST_DEADLINE) {
            Ok(req) => route(hub, &req),
            Err(e) => bad_request(&e),
        };
        // The client may already be gone; that's its problem, not ours.
        let _ = response.write_to(&mut stream);
    }
}

/// Map one request to a response. GET endpoints funnel through the
/// hub's version-keyed cache; only `/records` (cursor-parameterized)
/// and `/metrics` (carries the live request counter) rebuild per call.
pub fn route(hub: &TelemetryHub, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            Response::json_shared(200, hub.cached("health", || hub.body_health()))
        }
        ("GET", "/status") => {
            Response::json_shared(200, hub.cached("status", || hub.body_status()))
        }
        ("GET", "/gns/layers") => {
            Response::json_shared(200, hub.cached("gns_layers", || hub.body_gns_layers()))
        }
        ("GET", "/gns/predictor") => {
            Response::json_shared(200, hub.cached("gns_predictor", || hub.body_gns_predictor()))
        }
        ("GET", "/schedule") => {
            Response::json_shared(200, hub.cached("schedule", || hub.body_schedule()))
        }
        ("GET", "/ranks") => Response::json_shared(200, hub.cached("ranks", || hub.body_ranks())),
        ("GET", "/records") => {
            let since = match req.query_num::<u64>("since", 0) {
                Ok(v) => v,
                Err(e) => return bad_request(&e),
            };
            let limit = match req.query_num::<usize>("limit", RECORDS_DEFAULT_LIMIT) {
                Ok(v) => v.clamp(1, RECORDS_MAX_LIMIT),
                Err(e) => return bad_request(&e),
            };
            Response::json(200, hub.body_records(since, limit))
        }
        ("GET", "/metrics") => Response::text(200, hub.body_metrics()),
        ("POST", "/shutdown") => {
            hub.request_shutdown();
            let mut m = std::collections::BTreeMap::new();
            m.insert("ok".to_string(), crate::util::json::Value::Bool(true));
            m.insert(
                "state".to_string(),
                crate::util::json::Value::Str(hub.run_state().as_str().to_string()),
            );
            m.insert(
                "checkpointing".to_string(),
                crate::util::json::Value::Bool(!hub.meta().checkpoint_dir.is_empty()),
            );
            Response::json(200, crate::util::json::Value::Obj(m).to_string())
        }
        ("GET", "/shutdown") => Response::error(405, "use POST /shutdown"),
        (m, p) if p == "/health" || p == "/status" || p == "/gns/layers"
            || p == "/gns/predictor" || p == "/schedule" || p == "/ranks" || p == "/records"
            || p == "/metrics" || p == "/shutdown" =>
        {
            Response::error(405, &format!("{m} not allowed on {p}"))
        }
        (_, p) => Response::error(404, &format!("no such endpoint {p}")),
    }
}
