//! Fixed-capacity ring of recent step records, pre-serialized.
//!
//! The trainer publishes each step exactly once; pollers read any suffix
//! of the ring via a `since` cursor (`GET /records?since=STEP`). Records
//! are stored as `Arc<String>` JSON fragments serialized *at publish
//! time*, so serving N concurrent pollers costs N buffer copies and
//! zero float formatting — the hot path for "many dashboards, one run".

use std::collections::VecDeque;
use std::sync::Arc;

/// One ring slot: the record's step plus its serialized JSON object.
#[derive(Debug, Clone)]
pub struct RingEntry {
    pub step: u64,
    pub json: Arc<String>,
}

/// Result of a cursor read ([`RecordRing::since`]).
#[derive(Debug, Clone)]
pub struct RingSlice {
    pub entries: Vec<RingEntry>,
    /// Cursor for the next poll: the last returned step, or the request
    /// cursor when nothing new was available. Strictly monotone across
    /// polls of a live run.
    pub next_since: u64,
    /// True when `limit` cut the result short (more records are ready).
    pub truncated: bool,
    /// Oldest step still retained by the ring (None when empty). Lets a
    /// poller see how far back it could rewind.
    pub oldest_step: Option<u64>,
    /// True when records between `since` and the oldest retained step
    /// were evicted: the poller's cursor fell off the ring and the
    /// response silently skips steps. Without this flag a slow dashboard
    /// cannot tell a quiet run from a lossy one.
    pub gap: bool,
}

#[derive(Debug)]
pub struct RecordRing {
    cap: usize,
    buf: VecDeque<RingEntry>,
    /// Records evicted over the ring's lifetime (a poller whose cursor
    /// fell behind by more than `cap` steps can detect the gap).
    dropped: u64,
    /// Step of the most recently evicted record; a cursor below it has
    /// missed data.
    last_evicted_step: Option<u64>,
}

impl RecordRing {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring capacity must be positive");
        Self { cap, buf: VecDeque::with_capacity(cap), dropped: 0, last_evicted_step: None }
    }

    /// Append a record. Steps must arrive strictly increasing (the
    /// trainer's step counter); the oldest record is evicted when full.
    pub fn push(&mut self, step: u64, json: Arc<String>) {
        if let Some(last) = self.buf.back() {
            debug_assert!(step > last.step, "ring pushes must be monotone");
        }
        if self.buf.len() == self.cap {
            if let Some(evicted) = self.buf.pop_front() {
                self.last_evicted_step = Some(evicted.step);
            }
            self.dropped += 1;
        }
        self.buf.push_back(RingEntry { step, json });
    }

    /// Records with `step > since`, oldest first, at most `limit`.
    pub fn since(&self, since: u64, limit: usize) -> RingSlice {
        let start = self.buf.partition_point(|e| e.step <= since);
        let avail = self.buf.len() - start;
        let take = avail.min(limit);
        let entries: Vec<RingEntry> = self.buf.iter().skip(start).take(take).cloned().collect();
        let next_since = entries.last().map(|e| e.step).unwrap_or(since);
        // A cursor at exactly the last evicted step has *seen* that
        // record: only cursors strictly below it missed data.
        let gap = self.last_evicted_step.is_some_and(|evicted| since < evicted);
        RingSlice {
            entries,
            next_since,
            truncated: take < avail,
            oldest_step: self.first_step(),
            gap,
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn first_step(&self) -> Option<u64> {
        self.buf.front().map(|e| e.step)
    }

    pub fn last_step(&self) -> Option<u64> {
        self.buf.back().map(|e| e.step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: u64) -> Arc<String> {
        Arc::new(format!("{{\"step\":{n}}}"))
    }

    #[test]
    fn since_returns_suffix_with_monotone_cursor() {
        let mut r = RecordRing::new(16);
        for s in 1..=10 {
            r.push(s, mk(s));
        }
        let a = r.since(0, 100);
        assert_eq!(a.entries.len(), 10);
        assert_eq!(a.next_since, 10);
        assert!(!a.truncated);
        let b = r.since(7, 100);
        assert_eq!(b.entries.iter().map(|e| e.step).collect::<Vec<_>>(), vec![8, 9, 10]);
        // caught up: cursor sticks
        let c = r.since(10, 100);
        assert!(c.entries.is_empty());
        assert_eq!(c.next_since, 10);
    }

    #[test]
    fn limit_truncates_and_cursor_resumes() {
        let mut r = RecordRing::new(16);
        for s in 1..=10 {
            r.push(s, mk(s));
        }
        let a = r.since(0, 4);
        assert_eq!(a.entries.len(), 4);
        assert_eq!(a.next_since, 4);
        assert!(a.truncated);
        let b = r.since(a.next_since, 4);
        assert_eq!(b.entries.first().unwrap().step, 5);
    }

    #[test]
    fn eviction_counts_dropped_and_keeps_newest() {
        let mut r = RecordRing::new(4);
        for s in 1..=10 {
            r.push(s, mk(s));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        assert_eq!(r.first_step(), Some(7));
        assert_eq!(r.last_step(), Some(10));
        // a cursor that fell behind the ring resumes at the oldest kept
        let a = r.since(2, 100);
        assert_eq!(a.entries.first().unwrap().step, 7);
    }

    /// A cursor that fell off the ring gets `gap = true`; the boundary
    /// cursor (exactly the last evicted step) saw everything and does
    /// not.
    #[test]
    fn gap_flags_evicted_cursors_exactly() {
        let mut r = RecordRing::new(4);
        for s in 1..=6 {
            r.push(s, mk(s));
        }
        // retained: 3..=6; evicted: 1, 2
        assert_eq!(r.first_step(), Some(3));
        let lost = r.since(1, 100);
        assert!(lost.gap, "cursor 1 missed step 2");
        assert_eq!(lost.oldest_step, Some(3));
        assert_eq!(lost.entries.first().unwrap().step, 3);
        // Boundary: cursor 2 already consumed the last evicted record —
        // records 3.. are all still here, no data was missed.
        let boundary = r.since(2, 100);
        assert!(!boundary.gap, "cursor at last evicted step missed nothing");
        assert_eq!(boundary.entries.first().unwrap().step, 3);
        // Fresh ring (nothing evicted yet): never a gap, even from 0.
        let mut fresh = RecordRing::new(8);
        fresh.push(1, mk(1));
        let a = fresh.since(0, 100);
        assert!(!a.gap);
        assert_eq!(a.oldest_step, Some(1));
        assert!(RecordRing::new(2).since(0, 10).oldest_step.is_none());
    }
}
