//! Just-enough HTTP/1.1 for a localhost telemetry daemon: parse one
//! request head off a `TcpStream`, write one `Connection: close`
//! response. No keep-alive, no chunked bodies, no TLS — pollers issue
//! short-lived GETs and the interesting concurrency lives in the hub,
//! not the protocol layer.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

/// Upper bound on accepted request heads; anything larger is hostile
/// or broken (our longest legitimate request line is ~60 bytes).
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Enforces an overall per-request deadline on top of the socket's
/// per-read timeout. A per-read timeout alone resets on every byte, so
/// a client trickling one byte per interval holds a worker for as long
/// as it likes (slowloris); here each read gets only the *remaining*
/// request budget.
struct DeadlineStream<'a> {
    inner: &'a TcpStream,
    start: Instant,
    deadline: Duration,
}

impl Read for DeadlineStream<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let elapsed = self.start.elapsed();
        if elapsed >= self.deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request deadline exceeded",
            ));
        }
        self.inner.set_read_timeout(Some(self.deadline - elapsed))?;
        let mut s = self.inner;
        s.read(buf)
    }
}

/// A parsed request head: method, path (query split off), query pairs.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    query: Vec<(String, String)>,
}

impl Request {
    /// First query value for `key`, if present.
    pub fn query(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Parse a query parameter with `FromStr`, erroring (for a 400) on
    /// malformed values and falling back to `default` when absent.
    pub fn query_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.query(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("bad query parameter {key}={raw:?}")),
        }
    }
}

/// Read and parse one request head (request line + headers). The body,
/// if any, is drained per `Content-Length` and discarded — the daemon's
/// only non-GET endpoint (`POST /shutdown`) takes no payload. The whole
/// request (head + body drain) must arrive within `deadline`, however
/// slowly the client trickles bytes.
pub fn read_request(stream: &TcpStream, deadline: Duration) -> Result<Request> {
    let mut reader =
        BufReader::new(DeadlineStream { inner: stream, start: Instant::now(), deadline });
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    ensure!(!line.is_empty(), "empty request");
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let target = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or_default();
    ensure!(version.starts_with("HTTP/1."), "unsupported protocol {version:?}");
    ensure!(!method.is_empty() && target.starts_with('/'), "malformed request line");

    let mut content_length = 0usize;
    let mut head_bytes = line.len();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("reading header")?;
        head_bytes += h.len();
        ensure!(head_bytes <= MAX_HEAD_BYTES, "request head too large");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    if content_length > 0 {
        ensure!(content_length <= MAX_HEAD_BYTES, "request body too large");
        let mut sink = vec![0u8; content_length];
        reader.read_exact(&mut sink).context("draining body")?;
    }

    let (path, query_raw) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.clone(), ""),
    };
    let query = query_raw
        .split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (pct_decode(k), pct_decode(v)),
            None => (pct_decode(pair), String::new()),
        })
        .collect();
    Ok(Request { method, path, query })
}

/// Minimal percent-decoding (cursors and limits are plain digits, but a
/// polite client may still encode them).
fn pct_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'%' {
            if let (Some(hi), Some(lo)) = (
                b.get(i + 1).and_then(|c| (*c as char).to_digit(16)),
                b.get(i + 2).and_then(|c| (*c as char).to_digit(16)),
            ) {
                out.push((hi * 16 + lo) as u8);
                i += 3;
                continue;
            }
        }
        out.push(if b[i] == b'+' { b' ' } else { b[i] });
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// One response to write back; always `Connection: close`.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: ResponseBody,
}

/// Bodies are either borrowed from the hub's cache (`Shared`) or built
/// per-request (`Owned`); both write without copying into a new buffer.
pub enum ResponseBody {
    Owned(String),
    Shared(std::sync::Arc<String>),
}

impl ResponseBody {
    fn as_bytes(&self) -> &[u8] {
        match self {
            ResponseBody::Owned(s) => s.as_bytes(),
            ResponseBody::Shared(s) => s.as_bytes(),
        }
    }
}

impl Response {
    pub fn json(status: u16, body: String) -> Self {
        Self { status, content_type: "application/json", body: ResponseBody::Owned(body) }
    }

    pub fn json_shared(status: u16, body: std::sync::Arc<String>) -> Self {
        Self { status, content_type: "application/json", body: ResponseBody::Shared(body) }
    }

    pub fn text(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: ResponseBody::Owned(body),
        }
    }

    /// JSON error envelope: `{"error": "..."}`.
    pub fn error(status: u16, msg: &str) -> Self {
        let mut m = std::collections::BTreeMap::new();
        m.insert("error".to_string(), crate::util::json::Value::Str(msg.to_string()));
        Self::json(status, crate::util::json::Value::Obj(m).to_string())
    }

    pub fn write_to(&self, stream: &mut TcpStream) -> Result<()> {
        let body = self.body.as_bytes();
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(body)?;
        stream.flush()?;
        Ok(())
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Parse-or-400 helper used by the router: turns a parse error into a
/// client-visible 400 instead of a dropped connection.
pub fn bad_request(err: &anyhow::Error) -> Response {
    Response::error(400, &format!("{err:#}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    /// Round-trip one raw request through a real socket pair.
    fn parse_raw(raw: &str) -> Result<Request> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        client.write_all(raw.as_bytes()).unwrap();
        client.flush().unwrap();
        let (server_side, _) = listener.accept().unwrap();
        read_request(&server_side, Duration::from_secs(2))
    }

    #[test]
    fn parses_get_with_query() {
        let req = parse_raw("GET /records?since=42&limit=10 HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/records");
        assert_eq!(req.query("since"), Some("42"));
        assert_eq!(req.query_num::<u64>("limit", 0).unwrap(), 10);
        assert_eq!(req.query_num::<u64>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_query_number_and_garbage() {
        let req = parse_raw("GET /records?since=abc HTTP/1.1\r\n\r\n").unwrap();
        assert!(req.query_num::<u64>("since", 0).is_err());
        assert!(parse_raw("NONSENSE\r\n\r\n").is_err());
        assert!(parse_raw("GET /x SPDY/9\r\n\r\n").is_err());
    }

    #[test]
    fn drains_post_body() {
        let req =
            parse_raw("POST /shutdown HTTP/1.1\r\nContent-Length: 2\r\n\r\nok").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/shutdown");
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(pct_decode("a%20b+c"), "a b c");
        assert_eq!(pct_decode("plain"), "plain");
        assert_eq!(pct_decode("bad%zz"), "bad%zz");
    }

    /// Regression: a half-sent request that then stalls must error out
    /// within the request deadline, not hold the worker until the client
    /// gives up.
    #[test]
    fn stalled_half_request_errors_within_deadline() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        // Valid start, no terminating blank line — then silence.
        client.write_all(b"GET /status HTTP/1.1\r\nHost: x\r\nX-Sl").unwrap();
        client.flush().unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let t0 = Instant::now();
        assert!(read_request(&server_side, Duration::from_millis(300)).is_err());
        let elapsed = t0.elapsed();
        assert!(elapsed < Duration::from_secs(5), "deadline not enforced: {elapsed:?}");
        drop(client);
    }

    /// Regression (slowloris): trickled bytes reset a naive per-read
    /// timeout indefinitely; the overall deadline must still cut the
    /// request off.
    #[test]
    fn trickling_client_cannot_extend_the_deadline() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let writer = std::thread::spawn(move || {
            // One byte per 50ms: each byte arrives well inside any
            // per-read timeout, but the full head never does.
            for b in b"GET /health HTTP/1.1\r\nHost".iter() {
                if client.write_all(&[*b]).is_err() {
                    return;
                }
                let _ = client.flush();
                std::thread::sleep(Duration::from_millis(50));
            }
        });
        let (server_side, _) = listener.accept().unwrap();
        let t0 = Instant::now();
        assert!(read_request(&server_side, Duration::from_millis(400)).is_err());
        let elapsed = t0.elapsed();
        assert!(elapsed < Duration::from_secs(3), "trickle extended the deadline: {elapsed:?}");
        drop(server_side);
        writer.join().unwrap();
    }
}
