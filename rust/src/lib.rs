//! nanoGNS-rs: Rust + JAX + Pallas reproduction of *"Normalization Layer
//! Per-Example Gradients are Sufficient to Predict Gradient Noise Scale in
//! Transformers"* (Gray et al., NeurIPS 2024).
//!
//! Layer map (see DESIGN.md):
//! - L1 (Pallas) + L2 (JAX) live in `python/compile/` and are compiled
//!   **once** by `make artifacts` into HLO-text artifacts;
//! - L3 — this crate — is the training coordinator: it drives a model
//!   through the [`runtime::Backend`] abstraction, runs the microbatch
//!   gradient-accumulation loop ([`coordinator`]), tracks the gradient
//!   noise scale online ([`gns`]) and drives GNS-guided batch-size
//!   schedules ([`schedule`]). Python is never on the training path.
//!
//! Two backends implement the trait: the hermetic pure-Rust
//! [`runtime::reference`] transformer (default — builds and trains on a
//! bare machine) and the PJRT/HLO-artifact path (`--features pjrt`).

// Numeric code throughout (reference kernels, estimators, figures)
// indexes several parallel slices per loop; the indexed form is the
// readable one there. `too_many_arguments` is scoped to the places
// that need it (`runtime::reference`, the `Backend` trait).
#![allow(clippy::needless_range_loop)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod figures;
pub mod gns;
pub mod norms;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod telemetry;
pub mod util;

/// Canonical layer-type order of the stats vector crossing the L2→L3
/// boundary. Must match `python/compile/layers.py::STATS_ORDER`.
pub const STATS_ORDER: [&str; 5] = ["embedding", "layernorm", "attention", "mlp", "lm_head"];

/// Number of layer types tracked in the stats vector.
pub const N_TYPES: usize = STATS_ORDER.len();
