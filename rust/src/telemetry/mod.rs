//! Metrics telemetry: CSV series consumed by the figure harness.
//!
//! One row per optimizer step, wide format. The figure harness re-reads
//! these files to regenerate the paper's plots (phase plots, regressions,
//! loss curves), so schema changes must update `figures/`.

pub mod summary;

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{ensure, Result};

/// Append-only CSV writer with a fixed header.
pub struct CsvLogger {
    out: Box<dyn Write>,
    n_cols: usize,
}

impl CsvLogger {
    pub fn to_file(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out: Box<dyn Write> = Box::new(BufWriter::new(File::create(path)?));
        writeln!(out, "{}", header.join(","))?;
        Ok(Self { out, n_cols: header.len() })
    }

    /// Append to an existing series (checkpoint resume): rows logged
    /// before the interruption are kept and the header is written only
    /// when the file does not exist yet or is empty.
    pub fn append_to_file(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let fresh = std::fs::metadata(path.as_ref()).map(|m| m.len() == 0).unwrap_or(true);
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        let mut out: Box<dyn Write> = Box::new(BufWriter::new(file));
        if fresh {
            writeln!(out, "{}", header.join(","))?;
        }
        Ok(Self { out, n_cols: header.len() })
    }

    /// [`Self::append_to_file`] for resuming from a checkpoint that may
    /// predate the interruption point: rows whose first column (the step)
    /// exceeds `max_first_col` are dropped first, so steps the resumed
    /// run will re-execute are not logged twice.
    pub fn resume_file(
        path: impl AsRef<Path>,
        header: &[&str],
        max_first_col: f64,
    ) -> Result<Self> {
        let path = path.as_ref();
        if path.exists() && std::fs::metadata(path)?.len() > 0 {
            let text = std::fs::read_to_string(path)?;
            let mut kept = String::with_capacity(text.len());
            for (i, line) in text.lines().enumerate() {
                let keep = i == 0
                    || line.trim().is_empty()
                    || line
                        .split(',')
                        .next()
                        .and_then(|tok| tok.parse::<f64>().ok())
                        .is_none_or(|step| step <= max_first_col);
                if keep {
                    kept.push_str(line);
                    kept.push('\n');
                }
            }
            std::fs::write(path, kept)?;
        }
        Self::append_to_file(path, header)
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        ensure!(
            values.len() == self.n_cols,
            "row arity {} != header {}",
            values.len(),
            self.n_cols
        );
        let mut line = String::with_capacity(values.len() * 12);
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format!("{v:.9e}"));
        }
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Read a CSV produced by [`CsvLogger`] back into (header, columns).
pub fn read_csv(path: impl AsRef<Path>) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let f = BufReader::new(File::open(path.as_ref())?);
    let mut lines = f.lines();
    let header: Vec<String> = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty csv"))??
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); header.len()];
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        for (i, tok) in line.split(',').enumerate() {
            ensure!(i < cols.len(), "row wider than header");
            cols[i].push(tok.parse::<f64>()?);
        }
    }
    Ok((header, cols))
}

/// Column accessor helper for figure code.
pub fn column<'a>(header: &[String], cols: &'a [Vec<f64>], name: &str) -> Result<&'a [f64]> {
    let i = header
        .iter()
        .position(|h| h == name)
        .ok_or_else(|| anyhow::anyhow!("column {name} not in {header:?}"))?;
    Ok(&cols[i])
}

/// The standard per-step training metrics schema.
pub const TRAIN_HEADER: &[&str] = &[
    "step", "tokens", "loss", "lr", "accum", "b_big",
    "gsq_embedding", "s_embedding",
    "gsq_layernorm", "s_layernorm",
    "gsq_attention", "s_attention",
    "gsq_mlp", "s_mlp",
    "gsq_lm_head", "s_lm_head",
    "gsq_total", "s_total",
    "gns_layernorm", "gns_total",
    "step_ms",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("nanogns_test_telemetry");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut log = CsvLogger::to_file(&path, &["a", "b"]).unwrap();
            log.row(&[1.0, 2.0]).unwrap();
            log.row(&[3.5, -1e-9]).unwrap();
            log.flush().unwrap();
        }
        let (hdr, cols) = read_csv(&path).unwrap();
        assert_eq!(hdr, vec!["a", "b"]);
        assert_eq!(cols[0], vec![1.0, 3.5]);
        assert!((cols[1][1] + 1e-9).abs() < 1e-18);
        assert_eq!(column(&hdr, &cols, "b").unwrap().len(), 2);
        assert!(column(&hdr, &cols, "zz").is_err());
    }

    #[test]
    fn append_keeps_existing_rows_and_skips_header() {
        let dir = std::env::temp_dir().join("nanogns_test_telemetry3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume.csv");
        std::fs::remove_file(&path).ok();
        {
            let mut log = CsvLogger::append_to_file(&path, &["a", "b"]).unwrap();
            log.row(&[1.0, 2.0]).unwrap();
            log.flush().unwrap();
        }
        {
            let mut log = CsvLogger::append_to_file(&path, &["a", "b"]).unwrap();
            log.row(&[3.0, 4.0]).unwrap();
            log.flush().unwrap();
        }
        let (hdr, cols) = read_csv(&path).unwrap();
        assert_eq!(hdr, vec!["a", "b"]);
        assert_eq!(cols[0], vec![1.0, 3.0]);
        assert_eq!(cols[1], vec![2.0, 4.0]);
    }

    #[test]
    fn resume_drops_rows_past_the_checkpoint() {
        let dir = std::env::temp_dir().join("nanogns_test_telemetry4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dup.csv");
        std::fs::remove_file(&path).ok();
        {
            // interrupted run: logged through step 5, checkpoint at step 3
            let mut log = CsvLogger::to_file(&path, &["step", "x"]).unwrap();
            for s in 1..=5 {
                log.row(&[s as f64, 10.0 * s as f64]).unwrap();
            }
            log.flush().unwrap();
        }
        {
            let mut log = CsvLogger::resume_file(&path, &["step", "x"], 3.0).unwrap();
            for s in 4..=6 {
                log.row(&[s as f64, 10.0 * s as f64]).unwrap();
            }
            log.flush().unwrap();
        }
        let (_, cols) = read_csv(&path).unwrap();
        assert_eq!(cols[0], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(cols[1][3], 40.0);
    }

    #[test]
    fn arity_checked() {
        let dir = std::env::temp_dir().join("nanogns_test_telemetry2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut log = CsvLogger::to_file(dir.join("u.csv"), &["a", "b"]).unwrap();
        assert!(log.row(&[1.0]).is_err());
    }
}
