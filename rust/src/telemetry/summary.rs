//! Loss-curve analytics shared by the figure harnesses and reports:
//! token-grid interpolation, running-min smoothing, tokens-to-loss and
//! tokens-saved computations (the Fig. 9 right panel).

/// Bounded-memory curve decimator: keeps at most `max` points of an
/// append-only series by doubling its sampling stride whenever the
/// buffer fills. The kept points are always an evenly strided subsample
/// (every `stride()`-th appended point, starting from the first), so a
/// decimated loss curve stays faithful in shape no matter how long the
/// run gets. Used by the serve daemon to cap `/status` payloads at
/// ≤`max` loss-curve points.
#[derive(Debug, Clone)]
pub struct Decimated {
    pts: Vec<(f64, f64)>,
    stride: u64,
    /// Points appended so far (kept or not).
    seen: u64,
    max: usize,
}

impl Decimated {
    pub fn new(max: usize) -> Self {
        assert!(max >= 2, "decimation needs at least 2 points");
        Self { pts: Vec::new(), stride: 1, seen: 0, max }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        if self.seen % self.stride == 0 {
            if self.pts.len() == self.max {
                // compact: keep even positions (appended indices that are
                // multiples of the doubled stride), halving the buffer
                let mut i = 0;
                self.pts.retain(|_| {
                    let keep = i % 2 == 0;
                    i += 1;
                    keep
                });
                self.stride *= 2;
                if self.seen % self.stride != 0 {
                    self.seen += 1;
                    return;
                }
            }
            self.pts.push((x, y));
        }
        self.seen += 1;
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.pts
    }

    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }
}

/// Linear interpolation of a (tokens, loss) series at `tok`.
pub fn interp(series: &[(u64, f64)], tok: u64) -> f64 {
    assert!(!series.is_empty());
    match series.binary_search_by_key(&tok, |&(t, _)| t) {
        Ok(i) => series[i].1,
        Err(0) => series[0].1,
        Err(i) if i >= series.len() => series[series.len() - 1].1,
        Err(i) => {
            let (t0, l0) = series[i - 1];
            let (t1, l1) = series[i];
            let f = (tok - t0) as f64 / (t1 - t0).max(1) as f64;
            l0 + f * (l1 - l0)
        }
    }
}

/// Average several runs onto the first run's token grid.
pub fn mean_curve(runs: &[Vec<(u64, f64)>]) -> Vec<(u64, f64)> {
    assert!(!runs.is_empty());
    runs[0]
        .iter()
        .map(|&(tok, _)| {
            let sum: f64 = runs.iter().map(|r| interp(r, tok)).sum();
            (tok, sum / runs.len() as f64)
        })
        .collect()
}

/// First token count at which the running-min of the series reaches
/// `target` loss (noise-tolerant "time to loss").
pub fn tokens_to_reach(series: &[(u64, f64)], target: f64) -> Option<u64> {
    let mut best = f64::INFINITY;
    for &(tok, loss) in series {
        best = best.min(loss);
        if best <= target {
            return Some(tok);
        }
    }
    None
}

/// Tokens saved (fractional) by `faster` relative to `baseline` at the
/// loss `baseline` reaches after `frac` of its run.
pub fn tokens_saved_at(baseline: &[(u64, f64)], faster: &[(u64, f64)], frac: f64) -> Option<f64> {
    let idx = ((baseline.len() as f64 * frac) as usize).min(baseline.len() - 1);
    let (bt, bl) = baseline[idx];
    let ft = tokens_to_reach(faster, bl)?;
    Some((bt as f64 - ft as f64) / bt as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(n: u64, slope: f64, offset: f64) -> Vec<(u64, f64)> {
        (1..=n).map(|i| (i * 100, offset - slope * i as f64)).collect()
    }

    #[test]
    fn decimated_caps_length_and_keeps_strided_subsample() {
        let mut d = Decimated::new(8);
        for i in 0..1000u64 {
            d.push(i as f64, (i * 10) as f64);
        }
        assert!(d.points().len() <= 8, "{}", d.points().len());
        assert_eq!(d.seen(), 1000);
        // every kept point is an original point at a stride-multiple index
        let k = d.stride() as f64;
        for (j, &(x, y)) in d.points().iter().enumerate() {
            assert_eq!(x, j as f64 * k, "point {j}");
            assert_eq!(y, x * 10.0);
        }
        // first point always survives
        assert_eq!(d.points()[0].0, 0.0);
    }

    #[test]
    fn decimated_short_series_kept_verbatim() {
        let mut d = Decimated::new(100);
        for i in 0..20u64 {
            d.push(i as f64, -(i as f64));
        }
        assert_eq!(d.points().len(), 20);
        assert_eq!(d.stride(), 1);
    }

    #[test]
    fn interp_endpoints_and_middle() {
        let s = vec![(100u64, 5.0), (200, 3.0)];
        assert_eq!(interp(&s, 50), 5.0);
        assert_eq!(interp(&s, 100), 5.0);
        assert!((interp(&s, 150) - 4.0).abs() < 1e-12);
        assert_eq!(interp(&s, 999), 3.0);
    }

    #[test]
    fn mean_curve_of_identical_runs_is_identity() {
        let r = line(10, 0.1, 5.0);
        let m = mean_curve(&[r.clone(), r.clone(), r.clone()]);
        for (a, b) in m.iter().zip(&r) {
            assert!((a.1 - b.1).abs() < 1e-12);
        }
    }

    #[test]
    fn tokens_to_reach_monotone_tolerant() {
        // noisy series: running min must ignore upward blips
        let s = vec![(100u64, 5.0), (200, 4.0), (300, 4.5), (400, 3.0)];
        assert_eq!(tokens_to_reach(&s, 4.0), Some(200));
        assert_eq!(tokens_to_reach(&s, 3.5), Some(400));
        assert_eq!(tokens_to_reach(&s, 1.0), None);
    }

    #[test]
    fn faster_run_saves_tokens() {
        let slow = line(100, 0.01, 5.0);
        let fast = line(100, 0.02, 5.0); // reaches any loss in half the tokens
        let saved = tokens_saved_at(&slow, &fast, 0.8).unwrap();
        assert!((saved - 0.5).abs() < 0.02, "{saved}");
        // baseline vs itself: zero saving
        let zero = tokens_saved_at(&slow, &slow, 0.8).unwrap();
        assert!(zero.abs() < 0.02, "{zero}");
    }
}
