//! Learning-rate schedules: linear warmup + cosine decay to a floor
//! (the Cerebras-GPT / nanoGPT recipe used by the paper's experiments).

#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub max_lr: f64,
    pub min_lr: f64,
    pub warmup_steps: u64,
    pub decay_steps: u64,
}

impl LrSchedule {
    pub fn constant(lr: f64) -> Self {
        Self { max_lr: lr, min_lr: lr, warmup_steps: 0, decay_steps: 1 }
    }

    /// LR at optimizer step `step` (0-based).
    pub fn at(&self, step: u64) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.max_lr * (step + 1) as f64 / self.warmup_steps as f64;
        }
        let t = step.saturating_sub(self.warmup_steps);
        if t >= self.decay_steps {
            return self.min_lr;
        }
        let frac = t as f64 / self.decay_steps as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * frac).cos());
        self.min_lr + (self.max_lr - self.min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> LrSchedule {
        LrSchedule { max_lr: 6e-4, min_lr: 6e-5, warmup_steps: 100, decay_steps: 1000 }
    }

    #[test]
    fn warmup_is_linear() {
        let s = sched();
        assert!((s.at(0) - 6e-6).abs() < 1e-12);
        assert!((s.at(49) - 3e-4).abs() < 1e-6);
        assert!((s.at(99) - 6e-4).abs() < 1e-12);
    }

    #[test]
    fn decays_to_floor() {
        let s = sched();
        assert!((s.at(100) - 6e-4).abs() < 1e-6);
        assert!((s.at(1100) - 6e-5).abs() < 1e-12);
        assert!((s.at(99999) - 6e-5).abs() < 1e-12);
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(1e-3);
        for step in [0u64, 10, 100000] {
            assert_eq!(s.at(step), 1e-3);
        }
    }

    /// LR always within [min_lr, max_lr].
    #[test]
    fn prop_bounded() {
        crate::util::prop::forall(
            71,
            500,
            |r| r.next_u64() % 100_000,
            |&step| {
                let s = sched();
                let lr = s.at(step);
                crate::prop_check!(
                    lr >= s.min_lr - 1e-15 && lr <= s.max_lr + 1e-15,
                    "lr {lr} out of bounds at step {step}"
                );
                Ok(())
            },
        );
    }

    /// Monotone non-increasing after warmup.
    #[test]
    fn prop_monotone_decay() {
        crate::util::prop::forall(
            72,
            500,
            |r| 100 + r.next_u64() % 1_100,
            |&step| {
                let s = sched();
                crate::prop_check!(
                    s.at(step + 1) <= s.at(step) + 1e-15,
                    "not monotone at {step}"
                );
                Ok(())
            },
        );
    }
}
