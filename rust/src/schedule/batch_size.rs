//! Batch-size schedules (paper Section 5.2) and the GNS-guided controller.
//!
//! All schedules emit an *accumulation-step count* at fixed microbatch
//! size; effective batch = microbatch * accum * ranks. The paper's case
//! study uses `Linear`: ramp the batch size linearly in tokens processed
//! up to the fixed baseline batch (Fig. 15), which tracks the growing GNS.

#[derive(Debug, Clone)]
pub enum BatchSizeSchedule {
    /// Constant effective batch (the paper's baseline).
    Fixed { accum: usize },
    /// Linear ramp in tokens processed: accum rises from `min_accum` to
    /// `max_accum` by `ramp_tokens`, then stays (Fig. 15's schedule).
    Linear { min_accum: usize, max_accum: usize, ramp_tokens: u64 },
    /// Track the measured GNS: batch ~ gain * B_simple, clamped.
    Adaptive { min_accum: usize, max_accum: usize, gain: f64 },
}

impl BatchSizeSchedule {
    /// JSON description of the schedule, mirroring the config-file
    /// encoding (`{"kind": ..., ...}`) so the serve daemon's
    /// `/schedule` endpoint and `TrainConfig` speak the same shape.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let mut m = std::collections::BTreeMap::new();
        match self {
            Self::Fixed { accum } => {
                m.insert("kind".into(), Value::Str("fixed".into()));
                m.insert("accum".into(), Value::Num(*accum as f64));
            }
            Self::Linear { min_accum, max_accum, ramp_tokens } => {
                m.insert("kind".into(), Value::Str("linear".into()));
                m.insert("min_accum".into(), Value::Num(*min_accum as f64));
                m.insert("max_accum".into(), Value::Num(*max_accum as f64));
                m.insert("ramp_tokens".into(), Value::Num(*ramp_tokens as f64));
            }
            Self::Adaptive { min_accum, max_accum, gain } => {
                m.insert("kind".into(), Value::Str("adaptive".into()));
                m.insert("min_accum".into(), Value::Num(*min_accum as f64));
                m.insert("max_accum".into(), Value::Num(*max_accum as f64));
                m.insert("gain".into(), Value::Num(*gain));
            }
        }
        Value::Obj(m)
    }

    /// Accumulation steps for the next optimizer step.
    ///
    /// * `tokens_processed` — total tokens consumed so far;
    /// * `gns` — current smoothed total GNS estimate in *examples*
    ///   (B_small = 1 example in our estimator), None early on;
    /// * `microbatch_examples` — examples per microbatch.
    pub fn accum_steps(
        &self,
        tokens_processed: u64,
        gns: Option<f64>,
        microbatch_examples: usize,
    ) -> usize {
        match self {
            Self::Fixed { accum } => (*accum).max(1),
            Self::Linear { min_accum, max_accum, ramp_tokens } => {
                let frac = (tokens_processed as f64 / (*ramp_tokens).max(1) as f64).min(1.0);
                let a = *min_accum as f64 + frac * (*max_accum as f64 - *min_accum as f64);
                (a.round() as usize).clamp(*min_accum, *max_accum)
            }
            Self::Adaptive { min_accum, max_accum, gain } => {
                let Some(g) = gns else { return *min_accum };
                // target batch (examples) = gain * B_simple
                let target_accum =
                    (gain * g.max(0.0) / microbatch_examples.max(1) as f64).round() as usize;
                target_accum.clamp(*min_accum, *max_accum)
            }
        }
    }
}

/// Closed-loop GNS controller: smooths the raw schedule decision to avoid
/// thrashing the accumulation count step-to-step (hysteresis of one step).
#[derive(Debug, Clone)]
pub struct GnsController {
    pub schedule: BatchSizeSchedule,
    last: usize,
}

impl GnsController {
    pub fn new(schedule: BatchSizeSchedule) -> Self {
        Self { schedule, last: 1 }
    }

    /// Controller whose hysteresis starts at `start` (mid-run forking,
    /// checkpoint resume).
    pub fn with_start(schedule: BatchSizeSchedule, start: usize) -> Self {
        Self { schedule, last: start.max(1) }
    }

    /// Current hysteresis anchor (the last decision), for checkpointing;
    /// [`Self::with_start`] restores it.
    pub fn last(&self) -> usize {
        self.last
    }

    pub fn decide(&mut self, tokens: u64, gns: Option<f64>, microbatch_examples: usize) -> usize {
        let raw = self.schedule.accum_steps(tokens, gns, microbatch_examples);
        // move at most one accumulation step per decision (hysteresis)
        let next = if raw > self.last {
            self.last + 1
        } else if raw < self.last {
            self.last.saturating_sub(1).max(1)
        } else {
            raw
        };
        self.last = next;
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_constant() {
        let s = BatchSizeSchedule::Fixed { accum: 8 };
        for t in [0u64, 1_000_000, u64::MAX / 2] {
            assert_eq!(s.accum_steps(t, None, 4), 8);
        }
    }

    #[test]
    fn linear_ramps_and_saturates() {
        let s = BatchSizeSchedule::Linear { min_accum: 1, max_accum: 9, ramp_tokens: 1000 };
        assert_eq!(s.accum_steps(0, None, 4), 1);
        assert_eq!(s.accum_steps(500, None, 4), 5);
        assert_eq!(s.accum_steps(1000, None, 4), 9);
        assert_eq!(s.accum_steps(99_999, None, 4), 9);
    }

    #[test]
    fn adaptive_clamps() {
        let s = BatchSizeSchedule::Adaptive { min_accum: 2, max_accum: 16, gain: 1.0 };
        // no GNS yet -> min
        assert_eq!(s.accum_steps(0, None, 4), 2);
        // huge GNS -> max
        assert_eq!(s.accum_steps(0, Some(1e9), 4), 16);
        // negative (noisy early estimate) -> min
        assert_eq!(s.accum_steps(0, Some(-5.0), 4), 2);
    }

    #[test]
    fn controller_hysteresis() {
        let mut c = GnsController::new(BatchSizeSchedule::Fixed { accum: 10 });
        // from 1, may only climb one per decision
        assert_eq!(c.decide(0, None, 4), 2);
        assert_eq!(c.decide(0, None, 4), 3);
        for _ in 0..20 {
            c.decide(0, None, 4);
        }
        assert_eq!(c.decide(0, None, 4), 10);
    }

    /// Linear schedule is monotone in tokens and always within bounds.
    #[test]
    fn prop_linear_monotone() {
        crate::util::prop::forall(
            81,
            500,
            |r| (r.next_u64() % 10_000, r.next_u64() % 10_000),
            |&(t1, dt)| {
                let s =
                    BatchSizeSchedule::Linear { min_accum: 1, max_accum: 32, ramp_tokens: 5000 };
                let a = s.accum_steps(t1, None, 4);
                let b = s.accum_steps(t1 + dt, None, 4);
                crate::prop_check!(b >= a, "not monotone");
                crate::prop_check!(
                    (1..=32).contains(&a) && (1..=32).contains(&b),
                    "out of bounds"
                );
                Ok(())
            },
        );
    }

    /// Controller never returns 0 and never jumps more than 1.
    #[test]
    fn prop_controller_steps_bounded() {
        crate::util::prop::forall(
            82,
            300,
            |r| {
                let gns = if r.bool(0.3) { None } else { Some(r.range_f64(-10.0, 1e6)) };
                (gns, r.range(1, 30))
            },
            |&(gns, n)| {
                let mut c = GnsController::new(BatchSizeSchedule::Adaptive {
                    min_accum: 1,
                    max_accum: 64,
                    gain: 0.01,
                });
                let mut prev = 1usize;
                for _ in 0..n {
                    let a = c.decide(0, gns, 4);
                    crate::prop_check!(a >= 1, "returned 0");
                    crate::prop_check!(a.abs_diff(prev) <= 1, "jumped > 1");
                    prev = a;
                }
                Ok(())
            },
        );
    }
}
