//! Learning-rate and batch-size schedules (paper Section 5.2).
//!
//! Batch size is varied by changing the number of gradient-accumulation
//! steps at fixed microbatch size — exactly the mechanism of the paper's
//! case study — so no re-compilation is ever needed.

pub mod batch_size;
pub mod lr;

pub use batch_size::{BatchSizeSchedule, GnsController};
pub use lr::LrSchedule;
