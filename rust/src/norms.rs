//! Typed normalization-variant selection: [`NormKind`] × [`NormPlacement`].
//!
//! The normalization/architecture matrix (ROADMAP item 3) is addressed
//! everywhere — config keys, `NANOGNS_NORM`/`NANOGNS_PLACEMENT` env vars,
//! `--norm`/`--placement` flags, checkpoint headers, the serve surface,
//! the predictor report — through these two enums. Both follow the
//! field-selection idiom from `cli::inspect`: canonical lowercase names
//! via `Display`, forgiving aliases via `FromStr`, and a Levenshtein
//! did-you-mean on bad values.
//!
//! Selection sources are resolved by [`resolve`]: a value may arrive from
//! any one source (flag, env, config key), and *agreeing* duplicates are
//! fine, but two sources that disagree are rejected with a typed
//! [`ConflictError`] instead of silently preferring one layering.

use std::fmt;
use std::str::FromStr;

use anyhow::Result;

/// Which normalization layer the model's norm sites use.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NormKind {
    /// Mean-centered LayerNorm with learnable `γ`/`β` (the paper's config).
    #[default]
    LayerNorm,
    /// RMSNorm: `y = γ ⊙ x / rms(x)` — no centering, no `β`.
    RmsNorm,
}

/// Where the normalization layers sit relative to each residual block.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NormPlacement {
    /// `x += Module(Norm(x))`, plus a final norm (GPT-2 style; default).
    #[default]
    PreLn,
    /// `x = Norm(x + Module(x))` (original transformer).
    PostLn,
    /// `x += NormOut(Module(NormIn(x)))` — norms on both module input and
    /// output (arXiv:2502.02732).
    PeriLn,
}

impl NormKind {
    /// Every kind, in matrix order (stable across releases: report cells
    /// and CI matrix entries index into this).
    pub const ALL: [NormKind; 2] = [NormKind::LayerNorm, NormKind::RmsNorm];

    /// Canonical lowercase name (config/JSON/report spelling).
    pub fn name(self) -> &'static str {
        match self {
            NormKind::LayerNorm => "layernorm",
            NormKind::RmsNorm => "rmsnorm",
        }
    }

    fn aliases(self) -> &'static [&'static str] {
        match self {
            NormKind::LayerNorm => &["layernorm", "ln", "layer-norm"],
            NormKind::RmsNorm => &["rmsnorm", "rms", "rms-norm"],
        }
    }
}

impl NormPlacement {
    /// Every placement, in matrix order.
    pub const ALL: [NormPlacement; 3] =
        [NormPlacement::PreLn, NormPlacement::PostLn, NormPlacement::PeriLn];

    /// Canonical lowercase name (config/JSON/report spelling).
    pub fn name(self) -> &'static str {
        match self {
            NormPlacement::PreLn => "preln",
            NormPlacement::PostLn => "postln",
            NormPlacement::PeriLn => "periln",
        }
    }

    fn aliases(self) -> &'static [&'static str] {
        match self {
            NormPlacement::PreLn => &["preln", "pre", "pre-ln"],
            NormPlacement::PostLn => &["postln", "post", "post-ln"],
            NormPlacement::PeriLn => &["periln", "peri", "peri-ln"],
        }
    }
}

impl fmt::Display for NormKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl fmt::Display for NormPlacement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Shared parse body: exact alias match, else a did-you-mean error.
fn parse_with<T: Copy>(
    what: &str,
    s: &str,
    all: &[T],
    aliases: impl Fn(T) -> &'static [&'static str],
    names: &str,
) -> Result<T, anyhow::Error> {
    let needle = s.trim().to_ascii_lowercase();
    for &v in all {
        if aliases(v).iter().any(|a| *a == needle) {
            return Ok(v);
        }
    }
    let mut candidates: Vec<&'static str> = Vec::new();
    for &v in all {
        candidates.extend_from_slice(aliases(v));
    }
    match suggest(&needle, &candidates) {
        Some(hint) => Err(anyhow::anyhow!(
            "unknown {what} {s:?} (one of: {names}; did you mean {hint:?}?)"
        )),
        None => Err(anyhow::anyhow!("unknown {what} {s:?} (one of: {names})")),
    }
}

impl FromStr for NormKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        parse_with("norm kind", s, &Self::ALL, NormKind::aliases, "layernorm, rmsnorm")
    }
}

impl FromStr for NormPlacement {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        parse_with(
            "norm placement",
            s,
            &Self::ALL,
            NormPlacement::aliases,
            "preln, postln, periln",
        )
    }
}

/// Edit distance for the did-you-mean hint (same metric as the CLI's
/// unknown-flag suggestions).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + sub);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

fn suggest<'a>(input: &str, options: &[&'a str]) -> Option<&'a str> {
    options
        .iter()
        .map(|&o| (levenshtein(input, o), o))
        .filter(|&(d, _)| d <= 2 && d < input.len())
        .min_by_key(|&(d, _)| d)
        .map(|(_, o)| o)
}

/// Two selection sources disagreed about the same setting. Carried
/// through `anyhow` so callers can `downcast_ref::<ConflictError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConflictError {
    /// What was being selected ("norm kind" / "norm placement").
    pub what: String,
    /// `(source label, raw value)` for each disagreeing source.
    pub sources: Vec<(String, String)>,
}

impl fmt::Display for ConflictError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "conflicting {} settings: ", self.what)?;
        for (i, (src, val)) in self.sources.iter().enumerate() {
            if i > 0 {
                f.write_str(" vs ")?;
            }
            write!(f, "{src}={val:?}")?;
        }
        f.write_str(" — make the sources agree or drop all but one")
    }
}

impl std::error::Error for ConflictError {}

/// Resolve one setting offered by several sources (`(label, value)`
/// pairs, e.g. `("--norm", Some("rms"))`, `("NANOGNS_NORM", None)`,
/// `("config key \"norm_kind\"", Some("layernorm"))`).
///
/// * no source present → `Ok(None)` (caller keeps its default);
/// * any number of sources that parse to the *same* variant → that value;
/// * sources parsing to different variants → [`ConflictError`];
/// * an unparseable value → the did-you-mean parse error.
pub fn resolve<T>(what: &str, sources: &[(&str, Option<&str>)]) -> Result<Option<T>>
where
    T: FromStr<Err = anyhow::Error> + PartialEq + Copy + fmt::Display,
{
    let mut picked: Option<(&str, &str, T)> = None;
    for &(label, raw) in sources {
        let Some(raw) = raw else { continue };
        let value: T = raw.parse().map_err(|e: anyhow::Error| e.context(label.to_string()))?;
        match picked {
            None => picked = Some((label, raw, value)),
            Some((plabel, praw, pvalue)) => {
                if pvalue != value {
                    return Err(ConflictError {
                        what: what.to_string(),
                        sources: vec![
                            (plabel.to_string(), praw.to_string()),
                            (label.to_string(), raw.to_string()),
                        ],
                    }
                    .into());
                }
            }
        }
    }
    Ok(picked.map(|(_, _, v)| v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_round_trip() {
        for k in NormKind::ALL {
            assert_eq!(k.name().parse::<NormKind>().unwrap(), k);
            assert_eq!(format!("{k}").parse::<NormKind>().unwrap(), k);
        }
        for p in NormPlacement::ALL {
            assert_eq!(p.name().parse::<NormPlacement>().unwrap(), p);
            assert_eq!(format!("{p}").parse::<NormPlacement>().unwrap(), p);
        }
    }

    #[test]
    fn aliases_and_case_are_accepted() {
        assert_eq!("RMS".parse::<NormKind>().unwrap(), NormKind::RmsNorm);
        assert_eq!("layer-norm".parse::<NormKind>().unwrap(), NormKind::LayerNorm);
        assert_eq!(" pre-ln ".parse::<NormPlacement>().unwrap(), NormPlacement::PreLn);
        assert_eq!("peri".parse::<NormPlacement>().unwrap(), NormPlacement::PeriLn);
    }

    #[test]
    fn bad_values_get_did_you_mean() {
        let e = "rmsnrom".parse::<NormKind>().unwrap_err().to_string();
        assert!(e.contains("did you mean"), "{e}");
        assert!(e.contains("rmsnorm"), "{e}");
        let e = "perlin".parse::<NormPlacement>().unwrap_err().to_string();
        assert!(e.contains("periln"), "{e}");
        // nothing close: menu only, no bogus hint
        let e = "zzz".parse::<NormKind>().unwrap_err().to_string();
        assert!(!e.contains("did you mean"), "{e}");
        assert!(e.contains("layernorm, rmsnorm"), "{e}");
    }

    #[test]
    fn resolve_prefers_agreement_and_rejects_conflict() {
        // no source → None
        let r: Option<NormKind> =
            resolve("norm kind", &[("--norm", None), ("NANOGNS_NORM", None)]).unwrap();
        assert!(r.is_none());
        // one source
        let r: Option<NormKind> = resolve("norm kind", &[("--norm", Some("rms"))]).unwrap();
        assert_eq!(r, Some(NormKind::RmsNorm));
        // agreeing duplicates (different aliases) are fine
        let r: Option<NormKind> = resolve(
            "norm kind",
            &[("--norm", Some("rms")), ("config key \"norm_kind\"", Some("rmsnorm"))],
        )
        .unwrap();
        assert_eq!(r, Some(NormKind::RmsNorm));
        // conflicting sources: typed error naming both
        let err = resolve::<NormKind>(
            "norm kind",
            &[("--norm", Some("rmsnorm")), ("config key \"norm_kind\"", Some("layernorm"))],
        )
        .unwrap_err();
        let conflict = err.downcast_ref::<ConflictError>().expect("typed ConflictError");
        assert_eq!(conflict.sources.len(), 2);
        let msg = err.to_string();
        assert!(msg.contains("--norm") && msg.contains("norm_kind"), "{msg}");
    }

    #[test]
    fn resolve_reports_parse_errors_with_source() {
        let err =
            resolve::<NormPlacement>("norm placement", &[("NANOGNS_PLACEMENT", Some("nope"))])
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("NANOGNS_PLACEMENT"), "{msg}");
        assert!(msg.contains("unknown norm placement"), "{msg}");
    }
}
