//! `benchcmp` — the CI bench-regression gate.
//!
//! Compares a current `BENCH_*.json` report (from
//! `cargo bench --bench train_step -- --json [--smoke]`) against a
//! committed baseline (`bench/baseline.json`) and fails when the fused
//! path regressed.
//!
//! The primary gated metric is the *within-run* speedup of the fused
//! batched `grad_microbatch` over the retained per-example oracle:
//! absolute nanoseconds differ wildly across CI machines, but the
//! fused/oracle ratio measures the same kernels on the same hardware in
//! the same run, so it transfers. When the baseline was recorded on the
//! CI hardware pool itself (`_meta.recorded = true`, stamped by the
//! record-baseline workflow), `kernel_*` microbench medians are
//! additionally gated on absolute time under `--max-abs-regress-pct`.
//! Raw median deltas are printed for information only.
//!
//! ```sh
//! cargo run --release --bin benchcmp -- \
//!   --baseline bench/baseline.json --current BENCH_train_step.json \
//!   --max-regress-pct 15 --max-abs-regress-pct 50
//! ```
//!
//! Exit code 0 = all gates pass, 1 = regression, 2 = usage/IO error.

use nanogns::util::benchkit::{compare_bench_reports, fmt_ns, BenchCompare};
use nanogns::util::json::Value;

const USAGE: &str = "\
benchcmp — compare BENCH_*.json reports and gate fused-path regressions

USAGE:
  benchcmp --baseline bench/baseline.json --current BENCH_train_step.json
           [--max-regress-pct 15] [--max-abs-regress-pct 50]
";

fn run() -> Result<BenchCompare, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut baseline_path = None;
    let mut current_path = None;
    let mut max_regress_pct = 15.0f64;
    let mut max_abs_regress_pct = 50.0f64;
    let mut i = 0;
    while i < args.len() {
        let key = args[i].clone();
        let val = args.get(i + 1).cloned();
        let need = |v: Option<String>| v.ok_or_else(|| format!("{key} needs a value\n{USAGE}"));
        match key.as_str() {
            "--baseline" => baseline_path = Some(need(val)?),
            "--current" => current_path = Some(need(val)?),
            "--max-regress-pct" => {
                max_regress_pct = need(val)?
                    .parse()
                    .map_err(|e| format!("--max-regress-pct: {e}\n{USAGE}"))?
            }
            "--max-abs-regress-pct" => {
                max_abs_regress_pct = need(val)?
                    .parse()
                    .map_err(|e| format!("--max-abs-regress-pct: {e}\n{USAGE}"))?
            }
            "--help" | "-h" => return Err(USAGE.to_string()),
            other => return Err(format!("unknown flag {other:?}\n{USAGE}")),
        }
        i += 2;
    }
    let baseline_path = baseline_path.ok_or_else(|| format!("--baseline required\n{USAGE}"))?;
    let current_path = current_path.ok_or_else(|| format!("--current required\n{USAGE}"))?;

    let read = |path: &str| -> Result<Value, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        Value::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
    };
    let baseline = read(&baseline_path)?;
    let current = read(&current_path)?;

    let out = compare_bench_reports(&baseline, &current, max_regress_pct, max_abs_regress_pct)
        .map_err(|e| format!("{e}"))?;

    println!("benchcmp: {baseline_path} vs {current_path}");
    println!("{:<44} {:>12} {:>12} {:>9}", "entry", "baseline", "current", "delta");
    for d in &out.deltas {
        println!(
            "{:<44} {:>12} {:>12} {:>+8.1}%",
            d.name,
            fmt_ns(d.baseline_ns),
            fmt_ns(d.current_ns),
            d.delta_pct
        );
    }
    println!();
    println!("fused-path gate (speedup vs per-example oracle, {max_regress_pct}% budget):");
    for g in &out.gates {
        println!(
            "  {} {:<12} {:.2}x -> {:.2}x ({:+.1}% speedup loss)",
            if g.pass { "PASS" } else { "FAIL" },
            g.group,
            g.baseline_speedup,
            g.current_speedup,
            g.regress_pct
        );
    }
    println!();
    if out.baseline_recorded {
        println!("absolute kernel gates (recorded baseline, {max_abs_regress_pct}% budget):");
        for g in &out.abs_gates {
            println!(
                "  {} {:<44} {} -> {} ({:+.1}%)",
                if g.pass { "PASS" } else { "FAIL" },
                g.name,
                fmt_ns(g.baseline_ns),
                fmt_ns(g.current_ns),
                g.regress_pct
            );
        }
        if out.abs_gates.is_empty() {
            println!("  (baseline has no kernel_* entries)");
        }
    } else {
        println!(
            "absolute kernel gates: skipped (baseline not stamped _meta.recorded; \
             run the record-baseline workflow to arm them)"
        );
    }
    Ok(out)
}

fn main() {
    match run() {
        Ok(out) if out.all_pass() => {}
        Ok(_) => {
            eprintln!("benchcmp: a perf gate failed (fused-path ratio or absolute kernel median)");
            std::process::exit(1);
        }
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}
