//! Typed CLI surface for the `repro` binary.
//!
//! [`args`] holds one argument struct per subcommand over a shared
//! spec-driven lexer (unknown flags error with a suggestion; valued
//! flags never swallow a following `--flag`). [`inspect`] implements
//! `repro inspect`'s field-selection enums over on-disk artifacts. The
//! binary's `main` is a thin dispatcher over these types, so every
//! parse rule is unit-testable without spawning a process.

pub mod args;
pub mod inspect;

pub use args::{
    FiguresArgs, InfoArgs, InspectArgs, RankWorkerArgs, ServeArgs, TrainArgs, FIGURES_USAGE,
    INFO_USAGE, INSPECT_USAGE, RANK_WORKER_USAGE, SERVE_USAGE, TRAIN_USAGE,
};
