//! Typed per-subcommand argument structs for the `repro` launcher.
//!
//! Each subcommand owns a struct with a `parse(&[String]) -> Result<Self>`
//! constructor over a declared flag spec: which flags take a value, which
//! are switches, whether positionals are allowed. Declaring the spec up
//! front fixes the two failure modes of the old stringly parser:
//!
//! * **unknown flags fail loudly** — `repro train --step 100` errors with
//!   a "did you mean `--steps`?" suggestion instead of silently training
//!   the default 50 steps;
//! * **no `--key --switch` mis-tokenization** — a valued flag followed by
//!   another flag is a missing-value error, and a switch never swallows
//!   the token after it (the old lookahead guessed, and guessed wrong
//!   for `--metrics --json`).

use anyhow::{bail, Result};

// ---------------------------------------------------------------------------
// Per-command usage text (shown on `--help` and embedded in parse errors)
// ---------------------------------------------------------------------------

pub const TRAIN_USAGE: &str = "\
USAGE: repro train [--config F.json] [--model NAME] [--steps N] [--seed N]
                   [--metrics F.csv] [--ranks N] [--rank-mode threads|process]
                   [--checkpoint-dir DIR] [--checkpoint-every N] [--keep-last N]
                   [--resume CKPT] [--norm KIND] [--placement PLACEMENT]
                   [--backend reference|pjrt] [--artifacts DIR] [--json]
  --rank-mode  how data-parallel ranks execute: scoped threads in this
               process (threads, default) or supervised child processes
               with crash reconciliation (process)
  --keep-last N  retain only the newest N step checkpoints (N >= 1;
               latest.ckpt is always kept). N >= 2 gives --resume a
               fallback chain past a corrupt newest checkpoint.
  --norm       normalization kind: layernorm (default) | rmsnorm. Also
               settable via NANOGNS_NORM or the \"norm_kind\" config key;
               sources that disagree are an error.
  --placement  normalization placement: preln (default) | postln | periln.
               Also NANOGNS_PLACEMENT / \"norm_placement\" config key.
  --json    emit a machine-readable run summary on stdout (human logs go
            to stderr)
";

pub const SERVE_USAGE: &str = "\
USAGE: repro serve [train flags ...] [--port N] [--bind ADDR] [--ring-capacity N]
  Runs the training job like `repro train` and serves live telemetry over
  HTTP until POST /shutdown. Endpoints: /health /status /gns/layers
  /schedule /ranks /records?since=S&limit=N /metrics (Prometheus) /shutdown.
  --port N            listen port (default 7878; 0 = ephemeral)
  --bind ADDR         bind address (default 127.0.0.1)
  --ring-capacity N   in-memory record ring size (default 4096)
";

pub const FIGURES_USAGE: &str = "\
USAGE: repro figures (--fig N | --table N | --report NAME | --all)
                     [--model NAME] [--steps N] [--seeds N] [--ranks N]
                     [--backend reference|pjrt] [--artifacts DIR] [--json]
  Figures 2..16 map to the paper (8 = bench-only; 11..13 need pjrt),
  tables 1..2. Exactly one of --fig/--table/--report/--all must be given.
  --report predictor   train every cell of the normalization matrix
            (norm kind x placement) and report per-layer GNS trajectories
            plus the norm-only vs total GNS fit per cell
  --json    print the generated artifact paths as JSON on stdout
";

pub const INFO_USAGE: &str = "\
USAGE: repro info [--backend reference|pjrt] [--artifacts DIR] [--json]
  Lists the available model configs for the selected backend.
";

pub const INSPECT_USAGE: &str = "\
USAGE: repro inspect PATH [--kind checkpoint|bench|tracker|predictor] [--field NAME] [--json]
  Inspects an on-disk artifact without loading tensors or a backend:
    checkpoint  v3 checkpoint header (step, tokens, norm-kind, lr-scale, ...)
    bench       BENCH_*.json / bench/baseline.json report (medians, ...)
    tracker     GNS tracker state embedded in a v3 checkpoint
    predictor   results/predictor_report.json (verdicts, fits per cell)
  The kind is sniffed from the file when --kind is omitted. With --field,
  prints that one field; with --json, prints the full object as JSON;
  with neither, prints every field as `name = value` lines.
";

// ---------------------------------------------------------------------------
// Spec-driven lexer
// ---------------------------------------------------------------------------

/// Flag spec for one subcommand (names without the leading `--`).
struct Spec {
    valued: &'static [&'static str],
    switches: &'static [&'static str],
    positionals: bool,
    usage: &'static str,
}

/// Lexed argv: resolved `--key value` pairs, switches, positionals.
struct Parsed {
    values: Vec<(String, String)>,
    switches: Vec<String>,
    positionals: Vec<String>,
}

impl Parsed {
    /// Last occurrence wins, shell-convention style.
    fn value(&self, key: &str) -> Option<&str> {
        self.values.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn value_or(&self, key: &str, default: &str) -> String {
        self.value(key).unwrap_or(default).to_string()
    }

    fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_num(key)?.unwrap_or(default))
    }

    fn opt_num<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.value(key) {
            None => Ok(None),
            Some(s) => {
                s.parse::<T>().map(Some).map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}"))
            }
        }
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

fn lex(argv: &[String], spec: &Spec) -> Result<Parsed> {
    let mut out =
        Parsed { values: Vec::new(), switches: Vec::new(), positionals: Vec::new() };
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        let body = match a.strip_prefix("--") {
            Some(b) if !b.is_empty() => b,
            _ if a == "-h" => "help",
            _ => {
                if spec.positionals {
                    out.positionals.push(a.clone());
                    i += 1;
                    continue;
                }
                bail!("unexpected argument {a:?}\n\n{}", spec.usage);
            }
        };
        // `--key=value` binds unambiguously, even to flag-looking values.
        if let Some((k, v)) = body.split_once('=') {
            if spec.switches.contains(&k) {
                bail!("--{k} is a switch and takes no value\n\n{}", spec.usage);
            }
            if !spec.valued.contains(&k) {
                bail!("{}", unknown_flag(k, spec));
            }
            out.values.push((k.to_string(), v.to_string()));
            i += 1;
        } else if spec.valued.contains(&body) {
            match argv.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    out.values.push((body.to_string(), v.clone()));
                    i += 2;
                }
                Some(v) => bail!(
                    "missing value for --{body}: next argument {v:?} is a flag \
                     (use --{body}=VALUE to pass a value starting with --)\n\n{}",
                    spec.usage
                ),
                None => bail!("missing value for --{body}\n\n{}", spec.usage),
            }
        } else if spec.switches.contains(&body) {
            out.switches.push(body.to_string());
            i += 1;
        } else {
            bail!("{}", unknown_flag(body, spec));
        }
    }
    Ok(out)
}

fn unknown_flag(name: &str, spec: &Spec) -> String {
    let hint = spec
        .valued
        .iter()
        .chain(spec.switches)
        .map(|cand| (levenshtein(name, cand), *cand))
        .min()
        .filter(|(d, _)| *d <= 2 && *d < name.len())
        .map(|(_, cand)| format!(" (did you mean --{cand}?)"))
        .unwrap_or_default();
    format!("unknown flag --{name}{hint}\n\n{}", spec.usage)
}

/// Classic two-row edit distance; flag names are short, so O(a*b) is fine.
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

// ---------------------------------------------------------------------------
// repro train
// ---------------------------------------------------------------------------

const TRAIN_VALUED: &[&str] = &[
    "config",
    "model",
    "steps",
    "seed",
    "metrics",
    "ranks",
    "rank-mode",
    "checkpoint-dir",
    "checkpoint-every",
    "keep-last",
    "resume",
    "norm",
    "placement",
    "backend",
    "artifacts",
];
const TRAIN_SWITCHES: &[&str] = &["json", "help"];

#[derive(Debug, Clone)]
pub struct TrainArgs {
    pub config: Option<String>,
    pub model: String,
    pub steps: u64,
    pub seed: u64,
    pub metrics: String,
    pub ranks: usize,
    /// `threads` or `process`; `None` keeps the config-file value.
    pub rank_mode: Option<String>,
    pub checkpoint_dir: Option<String>,
    pub checkpoint_every: Option<u64>,
    /// `--keep-last N` retention override; `None` keeps the config value.
    pub keep_last: Option<usize>,
    pub resume: Option<String>,
    /// Raw `--norm` value; resolved (against env + config sources, with
    /// conflict rejection) by `crate::norms::resolve` in the launcher.
    pub norm: Option<String>,
    /// Raw `--placement` value; same resolution story.
    pub placement: Option<String>,
    pub backend: String,
    pub artifacts: String,
    pub json: bool,
    pub help: bool,
}

impl TrainArgs {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let spec = Spec {
            valued: TRAIN_VALUED,
            switches: TRAIN_SWITCHES,
            positionals: false,
            usage: TRAIN_USAGE,
        };
        Self::from_parsed(&lex(argv, &spec)?)
    }

    fn from_parsed(p: &Parsed) -> Result<Self> {
        let keep_last = p.opt_num::<usize>("keep-last")?;
        if keep_last == Some(0) {
            bail!(
                "--keep-last 0 would retain no checkpoints; pass N >= 1, or omit \
                 the flag to keep every checkpoint\n\n{TRAIN_USAGE}"
            );
        }
        Ok(Self {
            config: p.value("config").map(str::to_string),
            model: p.value_or("model", "small"),
            steps: p.num("steps", 50u64)?,
            seed: p.num("seed", 0u64)?,
            metrics: p.value_or("metrics", ""),
            ranks: p.num("ranks", 1usize)?,
            rank_mode: p.value("rank-mode").map(str::to_string),
            checkpoint_dir: p.value("checkpoint-dir").map(str::to_string),
            checkpoint_every: p.opt_num("checkpoint-every")?,
            keep_last,
            resume: p.value("resume").map(str::to_string),
            norm: p.value("norm").map(str::to_string),
            placement: p.value("placement").map(str::to_string),
            backend: p.value_or("backend", "reference"),
            artifacts: p.value_or("artifacts", "artifacts"),
            json: p.has("json"),
            help: p.has("help"),
        })
    }
}

// ---------------------------------------------------------------------------
// repro serve (train flags + daemon flags)
// ---------------------------------------------------------------------------

const SERVE_VALUED: &[&str] = &[
    "config",
    "model",
    "steps",
    "seed",
    "metrics",
    "ranks",
    "rank-mode",
    "checkpoint-dir",
    "checkpoint-every",
    "keep-last",
    "resume",
    "norm",
    "placement",
    "backend",
    "artifacts",
    "port",
    "bind",
    "ring-capacity",
];
const SERVE_SWITCHES: &[&str] = &["help"];

#[derive(Debug, Clone)]
pub struct ServeArgs {
    pub train: TrainArgs,
    /// CLI overrides for [`crate::config::ServeConfig`]; `None` keeps the
    /// config-file (or default) value.
    pub port: Option<u16>,
    pub bind: Option<String>,
    pub ring_capacity: Option<usize>,
}

impl ServeArgs {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let spec = Spec {
            valued: SERVE_VALUED,
            switches: SERVE_SWITCHES,
            positionals: false,
            usage: SERVE_USAGE,
        };
        let p = lex(argv, &spec)?;
        let ring_capacity = p.opt_num::<usize>("ring-capacity")?;
        if ring_capacity == Some(0) {
            bail!("--ring-capacity must be positive\n\n{SERVE_USAGE}");
        }
        Ok(Self {
            train: TrainArgs::from_parsed(&p)?,
            port: p.opt_num("port")?,
            bind: p.value("bind").map(str::to_string),
            ring_capacity,
        })
    }
}

// ---------------------------------------------------------------------------
// repro figures
// ---------------------------------------------------------------------------

const FIGURES_VALUED: &[&str] =
    &["fig", "table", "report", "model", "steps", "seeds", "ranks", "backend", "artifacts"];
const FIGURES_SWITCHES: &[&str] = &["all", "json", "help"];

#[derive(Debug, Clone)]
pub struct FiguresArgs {
    pub fig: Option<u32>,
    pub table: Option<u32>,
    /// Named report ("predictor": the normalization-matrix GNS
    /// predictor report).
    pub report: Option<String>,
    pub all: bool,
    pub model: String,
    pub steps: u64,
    pub seeds: u64,
    pub ranks: usize,
    pub backend: String,
    pub artifacts: String,
    pub json: bool,
    pub help: bool,
}

impl FiguresArgs {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let spec = Spec {
            valued: FIGURES_VALUED,
            switches: FIGURES_SWITCHES,
            positionals: false,
            usage: FIGURES_USAGE,
        };
        let p = lex(argv, &spec)?;
        let out = Self {
            fig: p.opt_num("fig")?,
            table: p.opt_num("table")?,
            report: p.value("report").map(str::to_string),
            all: p.has("all"),
            model: p.value_or("model", "micro"),
            steps: p.num("steps", 60u64)?,
            seeds: p.num("seeds", 3u64)?,
            ranks: p.num("ranks", 4usize)?,
            backend: p.value_or("backend", "reference"),
            artifacts: p.value_or("artifacts", "artifacts"),
            json: p.has("json"),
            help: p.has("help"),
        };
        if !out.help {
            let selectors = usize::from(out.fig.is_some())
                + usize::from(out.table.is_some())
                + usize::from(out.report.is_some())
                + usize::from(out.all);
            if selectors != 1 {
                bail!(
                    "pass exactly one of --fig N, --table N, --report NAME, or --all\
                     \n\n{FIGURES_USAGE}"
                );
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// repro info
// ---------------------------------------------------------------------------

const INFO_VALUED: &[&str] = &["backend", "artifacts"];
const INFO_SWITCHES: &[&str] = &["json", "help"];

#[derive(Debug, Clone)]
pub struct InfoArgs {
    pub backend: String,
    pub artifacts: String,
    pub json: bool,
    pub help: bool,
}

impl InfoArgs {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let spec = Spec {
            valued: INFO_VALUED,
            switches: INFO_SWITCHES,
            positionals: false,
            usage: INFO_USAGE,
        };
        let p = lex(argv, &spec)?;
        Ok(Self {
            backend: p.value_or("backend", "reference"),
            artifacts: p.value_or("artifacts", "artifacts"),
            json: p.has("json"),
            help: p.has("help"),
        })
    }
}

// ---------------------------------------------------------------------------
// repro inspect
// ---------------------------------------------------------------------------

const INSPECT_VALUED: &[&str] = &["kind", "field"];
const INSPECT_SWITCHES: &[&str] = &["json", "help"];

#[derive(Debug, Clone)]
pub struct InspectArgs {
    /// Path to the artifact (positional).
    pub path: String,
    /// Artifact kind; `None` sniffs from the file contents.
    pub kind: Option<String>,
    /// Field name to print (see the field enums in [`super::inspect`]).
    pub field: Option<String>,
    pub json: bool,
    pub help: bool,
}

impl InspectArgs {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let spec = Spec {
            valued: INSPECT_VALUED,
            switches: INSPECT_SWITCHES,
            positionals: true,
            usage: INSPECT_USAGE,
        };
        let p = lex(argv, &spec)?;
        let help = p.has("help");
        let path = match p.positionals.as_slice() {
            [one] => one.clone(),
            [] if help => String::new(),
            [] => bail!("inspect needs a PATH argument\n\n{INSPECT_USAGE}"),
            many => bail!("inspect takes exactly one PATH, got {many:?}\n\n{INSPECT_USAGE}"),
        };
        Ok(Self {
            path,
            kind: p.value("kind").map(str::to_string),
            field: p.value("field").map(str::to_string),
            json: p.has("json"),
            help,
        })
    }
}

// ---------------------------------------------------------------------------
// repro rank-worker (hidden; spawned by the elastic coordinator)
// ---------------------------------------------------------------------------

pub const RANK_WORKER_USAGE: &str = "\
USAGE: repro rank-worker --connect unix:PATH|tcp:ADDR --worker N
  Internal: an elastic rank worker child process. Spawned by the
  coordinator when rank_mode = process; not meant to be run by hand.
";

const RANK_WORKER_VALUED: &[&str] = &["connect", "worker"];
const RANK_WORKER_SWITCHES: &[&str] = &["help"];

#[derive(Debug, Clone)]
pub struct RankWorkerArgs {
    /// Coordinator endpoint: `unix:/path/to.sock` or `tcp:127.0.0.1:PORT`.
    pub connect: String,
    /// Worker slot index assigned by the coordinator.
    pub worker: usize,
    pub help: bool,
}

impl RankWorkerArgs {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let spec = Spec {
            valued: RANK_WORKER_VALUED,
            switches: RANK_WORKER_SWITCHES,
            positionals: false,
            usage: RANK_WORKER_USAGE,
        };
        let p = lex(argv, &spec)?;
        let help = p.has("help");
        let connect = p.value_or("connect", "");
        if connect.is_empty() && !help {
            bail!("rank-worker needs --connect\n\n{RANK_WORKER_USAGE}");
        }
        Ok(Self { connect, worker: p.num("worker", 0usize)?, help })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn train_defaults_and_values() {
        let a = TrainArgs::parse(&v(&[])).unwrap();
        assert_eq!(a.model, "small");
        assert_eq!(a.steps, 50);
        assert!(!a.json);
        let a = TrainArgs::parse(&v(&[
            "--model", "nano", "--steps", "7", "--metrics", "m.csv", "--json",
        ]))
        .unwrap();
        assert_eq!(
            (a.model.as_str(), a.steps, a.metrics.as_str(), a.json),
            ("nano", 7, "m.csv", true)
        );
    }

    #[test]
    fn train_unknown_flag_suggests() {
        let err = TrainArgs::parse(&v(&["--step", "100"])).unwrap_err().to_string();
        assert!(err.contains("unknown flag --step"), "{err}");
        assert!(err.contains("did you mean --steps?"), "{err}");
        assert!(err.contains("USAGE"), "{err}");
        // far-off names get no bogus suggestion
        let err = TrainArgs::parse(&v(&["--zzzzzzzz"])).unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn train_missing_value_not_mistokenized() {
        // the old parser turned `--metrics --json` into switch soup
        let err = TrainArgs::parse(&v(&["--metrics", "--json"])).unwrap_err().to_string();
        assert!(err.contains("missing value for --metrics"), "{err}");
        let err = TrainArgs::parse(&v(&["--metrics"])).unwrap_err().to_string();
        assert!(err.contains("missing value for --metrics"), "{err}");
        // the = form still lets a value start with --
        let a = TrainArgs::parse(&v(&["--metrics=--weird.csv"])).unwrap();
        assert_eq!(a.metrics, "--weird.csv");
    }

    #[test]
    fn train_bad_number_and_switch_with_value() {
        let err = TrainArgs::parse(&v(&["--steps", "many"])).unwrap_err().to_string();
        assert!(err.contains("--steps"), "{err}");
        let err = TrainArgs::parse(&v(&["--json=1"])).unwrap_err().to_string();
        assert!(err.contains("takes no value"), "{err}");
        let err = TrainArgs::parse(&v(&["positional"])).unwrap_err().to_string();
        assert!(err.contains("unexpected argument"), "{err}");
    }

    #[test]
    fn last_occurrence_wins() {
        let a = TrainArgs::parse(&v(&["--steps", "5", "--steps", "9"])).unwrap();
        assert_eq!(a.steps, 9);
    }

    #[test]
    fn serve_extends_train() {
        let a = ServeArgs::parse(&v(&["--steps", "30", "--port", "0", "--bind", "0.0.0.0"]))
            .unwrap();
        assert_eq!(a.train.steps, 30);
        assert_eq!(a.port, Some(0));
        assert_eq!(a.bind.as_deref(), Some("0.0.0.0"));
        assert_eq!(a.ring_capacity, None);
        let err = ServeArgs::parse(&v(&["--ring-capacity", "0"])).unwrap_err().to_string();
        assert!(err.contains("positive"), "{err}");
        // train does NOT accept serve flags
        let err = TrainArgs::parse(&v(&["--port", "7878"])).unwrap_err().to_string();
        assert!(err.contains("unknown flag --port"), "{err}");
    }

    #[test]
    fn figures_selector_validation() {
        assert!(FiguresArgs::parse(&v(&["--fig", "5"])).unwrap().fig == Some(5));
        assert!(FiguresArgs::parse(&v(&["--all"])).unwrap().all);
        let err = FiguresArgs::parse(&v(&[])).unwrap_err().to_string();
        assert!(err.contains("exactly one"), "{err}");
        let err = FiguresArgs::parse(&v(&["--all", "--fig", "5"])).unwrap_err().to_string();
        assert!(err.contains("exactly one"), "{err}");
        // --help short-circuits the selector requirement
        assert!(FiguresArgs::parse(&v(&["--help"])).unwrap().help);
    }

    #[test]
    fn inspect_positional_and_flags() {
        let a = InspectArgs::parse(&v(&["run/latest.ckpt", "--field", "step"])).unwrap();
        assert_eq!(a.path, "run/latest.ckpt");
        assert_eq!(a.field.as_deref(), Some("step"));
        let err = InspectArgs::parse(&v(&[])).unwrap_err().to_string();
        assert!(err.contains("needs a PATH"), "{err}");
        let err = InspectArgs::parse(&v(&["a", "b"])).unwrap_err().to_string();
        assert!(err.contains("exactly one"), "{err}");
        assert!(InspectArgs::parse(&v(&["--help"])).unwrap().help);
    }

    #[test]
    fn info_json_switch() {
        assert!(InfoArgs::parse(&v(&["--json"])).unwrap().json);
        let err = InfoArgs::parse(&v(&["--jsno"])).unwrap_err().to_string();
        assert!(err.contains("did you mean --json?"), "{err}");
    }

    #[test]
    fn levenshtein_sanity() {
        assert_eq!(levenshtein("step", "steps"), 1);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("same", "same"), 0);
    }

    #[test]
    fn short_help_alias() {
        assert!(TrainArgs::parse(&v(&["-h"])).unwrap().help);
    }

    #[test]
    fn train_rank_mode_passthrough() {
        let a = TrainArgs::parse(&v(&[])).unwrap();
        assert_eq!(a.rank_mode, None);
        let a = TrainArgs::parse(&v(&["--rank-mode", "process", "--ranks", "3"])).unwrap();
        assert_eq!(a.rank_mode.as_deref(), Some("process"));
        assert_eq!(a.ranks, 3);
        let a = ServeArgs::parse(&v(&["--rank-mode", "threads"])).unwrap();
        assert_eq!(a.train.rank_mode.as_deref(), Some("threads"));
    }

    #[test]
    fn keep_last_validates() {
        let a = TrainArgs::parse(&v(&["--keep-last", "3"])).unwrap();
        assert_eq!(a.keep_last, Some(3));
        let a = TrainArgs::parse(&v(&[])).unwrap();
        assert_eq!(a.keep_last, None);
        let err = TrainArgs::parse(&v(&["--keep-last", "0"])).unwrap_err().to_string();
        assert!(err.contains("--keep-last 0"), "{err}");
        // serve shares the train flag set
        let a = ServeArgs::parse(&v(&["--keep-last", "2"])).unwrap();
        assert_eq!(a.train.keep_last, Some(2));
    }

    #[test]
    fn norm_and_placement_flags_pass_through() {
        let a = TrainArgs::parse(&v(&[])).unwrap();
        assert_eq!(a.norm, None);
        assert_eq!(a.placement, None);
        let a = TrainArgs::parse(&v(&["--norm", "rms", "--placement", "peri-ln"])).unwrap();
        assert_eq!(a.norm.as_deref(), Some("rms"));
        assert_eq!(a.placement.as_deref(), Some("peri-ln"));
        // serve shares the train flag set
        let a = ServeArgs::parse(&v(&["--norm", "layernorm"])).unwrap();
        assert_eq!(a.train.norm.as_deref(), Some("layernorm"));
        let err = TrainArgs::parse(&v(&["--nrom", "rms"])).unwrap_err().to_string();
        assert!(err.contains("did you mean --norm?"), "{err}");
    }

    #[test]
    fn figures_report_is_a_selector() {
        let a = FiguresArgs::parse(&v(&["--report", "predictor"])).unwrap();
        assert_eq!(a.report.as_deref(), Some("predictor"));
        let err =
            FiguresArgs::parse(&v(&["--report", "predictor", "--fig", "5"])).unwrap_err();
        assert!(err.to_string().contains("exactly one"), "{err}");
    }

    #[test]
    fn rank_worker_requires_connect() {
        let a = RankWorkerArgs::parse(&v(&[
            "--connect",
            "unix:/tmp/x.sock",
            "--worker",
            "2",
        ]))
        .unwrap();
        assert_eq!(a.connect, "unix:/tmp/x.sock");
        assert_eq!(a.worker, 2);
        let err = RankWorkerArgs::parse(&v(&[])).unwrap_err().to_string();
        assert!(err.contains("--connect"), "{err}");
        assert!(RankWorkerArgs::parse(&v(&["--help"])).unwrap().help);
    }
}
