//! `repro inspect`: read-only views over on-disk artifacts.
//!
//! Foundry-style field selection: each artifact kind carries an enum of
//! its inspectable fields with `Display` (canonical kebab-case name) and
//! `FromStr` (accepting underscore and shorthand aliases), so
//! `repro inspect run/latest.ckpt --field lr-scale` and `--field lr_scale`
//! both work, and an unknown field errors with the full menu. No backend,
//! manifest, or tensor payload is touched — a checkpoint inspect reads
//! only the v3 JSON header (and verifies its header CRC).

use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::checkpoint;
use crate::gns::{EmaParts, TrackerState};
use crate::util::json::Value;

use super::args::InspectArgs;

// ---------------------------------------------------------------------------
// Artifact kinds
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// v3 checkpoint header (`NGNSCKP3`).
    Checkpoint,
    /// `BENCH_*.json` / `bench/baseline.json` report.
    Bench,
    /// GNS tracker state embedded in a v3 checkpoint.
    Tracker,
    /// `results/predictor_report.json` (the norm/placement matrix).
    Predictor,
}

impl fmt::Display for Kind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Kind::Checkpoint => "checkpoint",
            Kind::Bench => "bench",
            Kind::Tracker => "tracker",
            Kind::Predictor => "predictor",
        })
    }
}

impl FromStr for Kind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "checkpoint" | "ckpt" => Ok(Kind::Checkpoint),
            "bench" | "report" => Ok(Kind::Bench),
            "tracker" | "gns" => Ok(Kind::Tracker),
            "predictor" | "matrix" => Ok(Kind::Predictor),
            other => bail!("unknown kind {other:?} (checkpoint|bench|tracker|predictor)"),
        }
    }
}

/// Decide what a file is from its first bytes: checkpoint magic wins; a
/// JSON file stamped `"report":"predictor"` is a predictor report;
/// anything else that parses as JSON is a bench report.
pub fn sniff_kind(path: &str) -> Result<Kind> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.starts_with(b"NGNSCKP3")
        || bytes.starts_with(b"NGNSCKP2")
        || bytes.starts_with(b"NANOGNS1")
    {
        return Ok(Kind::Checkpoint);
    }
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| anyhow!("{path:?} is neither a checkpoint nor JSON"))?;
    let v = Value::parse(text)
        .map_err(|_| anyhow!("{path:?} is neither a checkpoint nor JSON"))?;
    match v.opt("report").and_then(|r| r.as_str().ok()) {
        Some("predictor") => Ok(Kind::Predictor),
        _ => Ok(Kind::Bench),
    }
}

// ---------------------------------------------------------------------------
// Field enums
// ---------------------------------------------------------------------------

macro_rules! field_enum {
    ($name:ident { $($variant:ident => $canon:literal [$($alias:literal),*]),+ $(,)? }) => {
        #[derive(Debug, Clone, Copy, PartialEq, Eq)]
        pub enum $name {
            $($variant,)+
        }

        impl $name {
            pub const ALL: &[$name] = &[$($name::$variant,)+];
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str(match self {
                    $($name::$variant => $canon,)+
                })
            }
        }

        impl FromStr for $name {
            type Err = anyhow::Error;
            fn from_str(s: &str) -> Result<Self> {
                match s {
                    $($canon $(| $alias)* => Ok($name::$variant),)+
                    other => {
                        let menu = [$($canon,)+].join(", ");
                        bail!("unknown field {other:?} (one of: {menu})")
                    }
                }
            }
        }
    };
}

field_enum!(CheckpointField {
    Version => "version" [],
    Model => "model" [],
    Seed => "seed" [],
    CorpusBytes => "corpus-bytes" ["corpus_bytes", "corpus"],
    Step => "step" [],
    Tokens => "tokens" [],
    LrScale => "lr-scale" ["lr_scale", "lr"],
    ControllerLast => "controller-last" ["controller_last", "controller", "accum"],
    Loaders => "loaders" ["cursors", "ranks"],
    Tensors => "tensors" [],
    Tracker => "tracker" ["gns"],
    NormKind => "norm-kind" ["norm_kind", "norm"],
    NormPlacement => "norm-placement" ["norm_placement", "placement"],
});

field_enum!(BenchField {
    Recorded => "recorded" [],
    Source => "source" [],
    Entries => "entries" ["count"],
    Medians => "medians" ["median", "median-ns", "median_ns"],
    Throughput => "throughput" ["thr"],
});

field_enum!(PredictorField {
    Model => "model" [],
    Steps => "steps" [],
    Cells => "cells" ["count"],
    Verdicts => "verdicts" ["verdict"],
    Fits => "fits" ["fit"],
});

field_enum!(GnsField {
    Alpha => "alpha" [],
    Types => "types" [],
    Total => "total" [],
    Embedding => "embedding" ["embed"],
    Layernorm => "layernorm" ["ln"],
    Attention => "attention" ["attn"],
    Mlp => "mlp" [],
    LmHead => "lm-head" ["lm_head", "lmhead"],
});

// ---------------------------------------------------------------------------
// Field extraction
// ---------------------------------------------------------------------------

/// Decode the checkpoint header's exact `0x…` f64 bit-pattern encoding.
fn f64_from_hex(v: &Value) -> Result<f64> {
    let s = v.as_str()?;
    let hex = s.strip_prefix("0x").ok_or_else(|| anyhow!("bad f64 bits {s:?}"))?;
    Ok(f64::from_bits(u64::from_str_radix(hex, 16).context("bad f64 bits")?))
}

pub fn checkpoint_field(header: &Value, field: CheckpointField) -> Result<Value> {
    Ok(match field {
        CheckpointField::Version => header.get("version")?.clone(),
        CheckpointField::Model => header.get("model")?.clone(),
        // seed/step/tokens/corpus-bytes are exact decimal strings in the
        // header; pass them through untouched (no f64 round-trip).
        CheckpointField::Seed => header.get("seed")?.clone(),
        CheckpointField::CorpusBytes => header.get("corpus_bytes")?.clone(),
        CheckpointField::Step => header.get("step")?.clone(),
        CheckpointField::Tokens => header.get("tokens")?.clone(),
        CheckpointField::LrScale => {
            let x = f64_from_hex(header.get("lr_scale")?)?;
            if x.is_finite() {
                Value::Num(x)
            } else {
                header.get("lr_scale")?.clone()
            }
        }
        CheckpointField::ControllerLast => header.get("controller_last")?.clone(),
        CheckpointField::Loaders => Value::Num(header.get("loaders")?.as_arr()?.len() as f64),
        CheckpointField::Tensors => Value::Num(header.get("tensors")?.as_arr()?.len() as f64),
        CheckpointField::Tracker => header.get("tracker")?.clone(),
        // Absent on pre-matrix checkpoints: decode through the same
        // defaulting path resume uses, so inspect and resume agree.
        CheckpointField::NormKind => {
            Value::Str(checkpoint::variant_from_header(header)?.0.name().into())
        }
        CheckpointField::NormPlacement => {
            Value::Str(checkpoint::variant_from_header(header)?.1.name().into())
        }
    })
}

/// One `"norm/placement"` key per matrix cell, in report order.
fn predictor_cells(report: &Value) -> Result<Vec<(String, &Value)>> {
    report
        .get("cells")?
        .as_arr()?
        .iter()
        .map(|c| {
            let key =
                format!("{}/{}", c.get("norm_kind")?.as_str()?, c.get("norm_placement")?.as_str()?);
            Ok((key, c))
        })
        .collect()
}

pub fn predictor_field(report: &Value, field: PredictorField) -> Result<Value> {
    Ok(match field {
        PredictorField::Model => report.get("model")?.clone(),
        PredictorField::Steps => report.get("steps")?.clone(),
        PredictorField::Cells => Value::Num(predictor_cells(report)?.len() as f64),
        PredictorField::Verdicts => {
            let mut m = BTreeMap::new();
            for (key, c) in predictor_cells(report)? {
                m.insert(key, c.get("verdict")?.clone());
            }
            Value::Obj(m)
        }
        PredictorField::Fits => {
            let mut m = BTreeMap::new();
            for (key, c) in predictor_cells(report)? {
                m.insert(key, c.get("fit")?.clone());
            }
            Value::Obj(m)
        }
    })
}

pub fn bench_field(report: &Value, field: BenchField) -> Result<Value> {
    let meta = report.opt("_meta");
    let entries = || -> Result<Vec<(&String, &Value)>> {
        Ok(report.as_obj()?.iter().filter(|(k, _)| !k.starts_with('_')).collect())
    };
    Ok(match field {
        BenchField::Recorded => Value::Bool(
            meta.and_then(|m| m.opt("recorded"))
                .and_then(|v| v.as_bool().ok())
                .unwrap_or(false),
        ),
        BenchField::Source => meta
            .and_then(|m| m.opt("source"))
            .cloned()
            .unwrap_or(Value::Null),
        BenchField::Entries => Value::Num(entries()?.len() as f64),
        BenchField::Medians => {
            let mut m = BTreeMap::new();
            for (name, e) in entries()? {
                m.insert(name.clone(), e.opt("median_ns").cloned().unwrap_or(Value::Null));
            }
            Value::Obj(m)
        }
        BenchField::Throughput => {
            let mut m = BTreeMap::new();
            for (name, e) in entries()? {
                m.insert(name.clone(), e.opt("throughput").cloned().unwrap_or(Value::Null));
            }
            Value::Obj(m)
        }
    })
}

/// Smoothed `{g_sq, s, gns}` triple from a pair of exported EMAs.
fn ema_pair_json(g_sq: &EmaParts, s: &EmaParts) -> Value {
    let mut m = BTreeMap::new();
    let g = g_sq.state;
    let sv = s.state;
    m.insert("g_sq".into(), g.map(Value::finite_or_null).unwrap_or(Value::Null));
    m.insert("s".into(), sv.map(Value::finite_or_null).unwrap_or(Value::Null));
    let gns = match (g, sv) {
        (Some(g), Some(sv)) if g != 0.0 => Value::finite_or_null(sv / g),
        _ => Value::Null,
    };
    m.insert("gns".into(), gns);
    m.insert("observations".into(), Value::Num(g_sq.t as f64));
    Value::Obj(m)
}

/// The full tracker view `repro inspect --kind tracker` prints: smoothed
/// per-type and total components with their GNS ratios.
pub fn tracker_object(st: &TrackerState) -> Value {
    let mut per = BTreeMap::new();
    for (i, t) in st.types.iter().enumerate() {
        per.insert(t.clone(), ema_pair_json(&st.g_sq[i], &st.s[i]));
    }
    let mut top = BTreeMap::new();
    top.insert("alpha".into(), Value::finite_or_null(st.g_sq_total.alpha));
    top.insert(
        "types".into(),
        Value::Arr(st.types.iter().map(|t| Value::Str(t.clone())).collect()),
    );
    top.insert("per_type".into(), Value::Obj(per));
    top.insert("total".into(), ema_pair_json(&st.g_sq_total, &st.s_total));
    Value::Obj(top)
}

pub fn gns_field(st: &TrackerState, field: GnsField) -> Result<Value> {
    let by_type = |name: &str| -> Result<Value> {
        let i = st
            .types
            .iter()
            .position(|t| t == name)
            .ok_or_else(|| anyhow!("tracker has no type {name:?} (has {:?})", st.types))?;
        Ok(ema_pair_json(&st.g_sq[i], &st.s[i]))
    };
    Ok(match field {
        GnsField::Alpha => Value::finite_or_null(st.g_sq_total.alpha),
        GnsField::Types => Value::Arr(st.types.iter().map(|t| Value::Str(t.clone())).collect()),
        GnsField::Total => ema_pair_json(&st.g_sq_total, &st.s_total),
        GnsField::Embedding => by_type("embedding")?,
        GnsField::Layernorm => by_type("layernorm")?,
        GnsField::Attention => by_type("attention")?,
        GnsField::Mlp => by_type("mlp")?,
        GnsField::LmHead => by_type("lm_head")?,
    })
}

// ---------------------------------------------------------------------------
// Entry point
// ---------------------------------------------------------------------------

/// Render one scalar-or-structure for output: bare strings print
/// unquoted (shell-friendly), everything else prints as JSON.
fn render(v: &Value) -> String {
    match v {
        Value::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

/// Run the inspection and return the text to print on stdout.
pub fn run(args: &InspectArgs) -> Result<String> {
    let kind = match args.kind.as_deref() {
        Some(k) => k.parse::<Kind>()?,
        None => sniff_kind(&args.path)?,
    };
    match kind {
        Kind::Checkpoint => {
            let header = checkpoint::read_header(&args.path)?;
            match (&args.field, args.json) {
                (Some(f), _) => Ok(render(&checkpoint_field(&header, f.parse()?)?)),
                (None, true) => Ok(header.to_string()),
                (None, false) => {
                    let mut out = String::new();
                    for f in CheckpointField::ALL {
                        let v = checkpoint_field(&header, *f)?;
                        out.push_str(&format!("{f} = {}\n", render(&v)));
                    }
                    Ok(out)
                }
            }
        }
        Kind::Bench => {
            let text = std::fs::read_to_string(&args.path)
                .with_context(|| format!("reading {:?}", args.path))?;
            let report = Value::parse(&text)
                .with_context(|| format!("parsing {:?} as a bench report", args.path))?;
            match (&args.field, args.json) {
                (Some(f), _) => Ok(render(&bench_field(&report, f.parse()?)?)),
                (None, true) => Ok(report.to_string()),
                (None, false) => {
                    let mut out = String::new();
                    for f in BenchField::ALL {
                        let v = bench_field(&report, *f)?;
                        out.push_str(&format!("{f} = {}\n", render(&v)));
                    }
                    Ok(out)
                }
            }
        }
        Kind::Tracker => {
            let header = checkpoint::read_header(&args.path)?;
            let state = checkpoint::tracker_from_header(&header)?;
            match (&args.field, args.json) {
                (Some(f), _) => Ok(render(&gns_field(&state, f.parse()?)?)),
                (None, true) => Ok(tracker_object(&state).to_string()),
                (None, false) => {
                    let mut out = String::new();
                    for f in GnsField::ALL {
                        let v = gns_field(&state, *f)?;
                        out.push_str(&format!("{f} = {}\n", render(&v)));
                    }
                    Ok(out)
                }
            }
        }
        Kind::Predictor => {
            let text = std::fs::read_to_string(&args.path)
                .with_context(|| format!("reading {:?}", args.path))?;
            let report = Value::parse(&text)
                .with_context(|| format!("parsing {:?} as a predictor report", args.path))?;
            match (&args.field, args.json) {
                (Some(f), _) => Ok(render(&predictor_field(&report, f.parse()?)?)),
                (None, true) => Ok(report.to_string()),
                (None, false) => {
                    let mut out = String::new();
                    for f in PredictorField::ALL {
                        let v = predictor_field(&report, *f)?;
                        out.push_str(&format!("{f} = {}\n", render(&v)));
                    }
                    Ok(out)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_names_round_trip_display_fromstr() {
        for f in CheckpointField::ALL {
            assert_eq!(f.to_string().parse::<CheckpointField>().unwrap(), *f);
        }
        for f in BenchField::ALL {
            assert_eq!(f.to_string().parse::<BenchField>().unwrap(), *f);
        }
        for f in GnsField::ALL {
            assert_eq!(f.to_string().parse::<GnsField>().unwrap(), *f);
        }
        for f in PredictorField::ALL {
            assert_eq!(f.to_string().parse::<PredictorField>().unwrap(), *f);
        }
    }

    #[test]
    fn aliases_resolve() {
        assert_eq!("lr_scale".parse::<CheckpointField>().unwrap(), CheckpointField::LrScale);
        assert_eq!("lr".parse::<CheckpointField>().unwrap(), CheckpointField::LrScale);
        assert_eq!("gns".parse::<CheckpointField>().unwrap(), CheckpointField::Tracker);
        assert_eq!("ln".parse::<GnsField>().unwrap(), GnsField::Layernorm);
        assert_eq!("lm_head".parse::<GnsField>().unwrap(), GnsField::LmHead);
        assert_eq!("median_ns".parse::<BenchField>().unwrap(), BenchField::Medians);
        let err = "bogus".parse::<CheckpointField>().unwrap_err().to_string();
        assert!(err.contains("one of:") && err.contains("lr-scale"), "{err}");
    }

    #[test]
    fn kind_parse_and_sniff() {
        assert_eq!("ckpt".parse::<Kind>().unwrap(), Kind::Checkpoint);
        assert_eq!("gns".parse::<Kind>().unwrap(), Kind::Tracker);
        assert!("nope".parse::<Kind>().is_err());

        let dir = std::env::temp_dir().join(format!("nanogns-sniff-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("x.ckpt");
        std::fs::write(&ckpt, b"NGNSCKP3rest").unwrap();
        assert_eq!(sniff_kind(ckpt.to_str().unwrap()).unwrap(), Kind::Checkpoint);
        let old = dir.join("old.ckpt");
        std::fs::write(&old, b"NGNSCKP2rest").unwrap();
        assert_eq!(sniff_kind(old.to_str().unwrap()).unwrap(), Kind::Checkpoint);
        let bench = dir.join("BENCH_x.json");
        std::fs::write(&bench, "{}").unwrap();
        assert_eq!(sniff_kind(bench.to_str().unwrap()).unwrap(), Kind::Bench);
        let pred = dir.join("predictor_report.json");
        std::fs::write(&pred, r#"{"report":"predictor","cells":[]}"#).unwrap();
        assert_eq!(sniff_kind(pred.to_str().unwrap()).unwrap(), Kind::Predictor);
        // a different report stamp stays a bench report
        let other = dir.join("other.json");
        std::fs::write(&other, r#"{"report":"else"}"#).unwrap();
        assert_eq!(sniff_kind(other.to_str().unwrap()).unwrap(), Kind::Bench);
        let junk = dir.join("junk.bin");
        std::fs::write(&junk, b"not json at all").unwrap();
        assert!(sniff_kind(junk.to_str().unwrap()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn sample_report() -> Value {
        Value::parse(
            r#"{
                "_meta": {"recorded": true, "source": "ci-run-1"},
                "step_small/grad_microbatch": {"median_ns": 1000, "samples": 5, "throughput": 2.0},
                "kernel_matmul/xwt": {"median_ns": 10, "samples": 5, "throughput": 9.0}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn bench_fields_extract() {
        let r = sample_report();
        assert_eq!(bench_field(&r, BenchField::Recorded).unwrap(), Value::Bool(true));
        assert_eq!(bench_field(&r, BenchField::Source).unwrap(), Value::Str("ci-run-1".into()));
        assert_eq!(bench_field(&r, BenchField::Entries).unwrap(), Value::Num(2.0));
        let med = bench_field(&r, BenchField::Medians).unwrap();
        assert_eq!(med.get("kernel_matmul/xwt").unwrap(), &Value::Num(10.0));
        // report with no _meta: recorded defaults false
        let bare = Value::parse(r#"{"a":{"median_ns":1}}"#).unwrap();
        assert_eq!(bench_field(&bare, BenchField::Recorded).unwrap(), Value::Bool(false));
    }

    #[test]
    fn predictor_fields_extract() {
        let r = Value::parse(
            r#"{
                "report": "predictor", "model": "nano", "steps": 24,
                "cells": [
                    {"norm_kind": "layernorm", "norm_placement": "preln",
                     "verdict": "holds", "fit": {"r2": 0.98}},
                    {"norm_kind": "rmsnorm", "norm_placement": "periln",
                     "verdict": "weak", "fit": {"r2": 0.4}}
                ]
            }"#,
        )
        .unwrap();
        assert_eq!(predictor_field(&r, PredictorField::Model).unwrap(), Value::Str("nano".into()));
        assert_eq!(predictor_field(&r, PredictorField::Steps).unwrap(), Value::Num(24.0));
        assert_eq!(predictor_field(&r, PredictorField::Cells).unwrap(), Value::Num(2.0));
        let v = predictor_field(&r, PredictorField::Verdicts).unwrap();
        assert_eq!(v.get("layernorm/preln").unwrap(), &Value::Str("holds".into()));
        assert_eq!(v.get("rmsnorm/periln").unwrap(), &Value::Str("weak".into()));
        let fits = predictor_field(&r, PredictorField::Fits).unwrap();
        assert_eq!(fits.get("rmsnorm/periln").unwrap().get("r2").unwrap(), &Value::Num(0.4));
        // malformed cell: missing verdict is an error, not a silent skip
        let bad = Value::parse(
            r#"{"cells": [{"norm_kind": "layernorm", "norm_placement": "preln"}]}"#,
        )
        .unwrap();
        assert!(predictor_field(&bad, PredictorField::Verdicts).is_err());
    }

    #[test]
    fn checkpoint_variant_fields_default_for_old_headers() {
        // pre-matrix header: no norm keys → the defaults resume assumes
        let header = Value::parse(r#"{"model": "nano"}"#).unwrap();
        let k = checkpoint_field(&header, CheckpointField::NormKind).unwrap();
        assert_eq!(k, Value::Str("layernorm".into()));
        let p = checkpoint_field(&header, CheckpointField::NormPlacement).unwrap();
        assert_eq!(p, Value::Str("preln".into()));
        // stamped header round-trips the stamped names
        let header =
            Value::parse(r#"{"norm_kind": "rmsnorm", "norm_placement": "periln"}"#).unwrap();
        let k = checkpoint_field(&header, CheckpointField::NormKind).unwrap();
        assert_eq!(k, Value::Str("rmsnorm".into()));
        let p = checkpoint_field(&header, CheckpointField::NormPlacement).unwrap();
        assert_eq!(p, Value::Str("periln".into()));
    }

    fn sample_tracker() -> TrackerState {
        let ema = |state: Option<f64>| EmaParts { alpha: 0.05, state, t: 3, bias_correct: false };
        TrackerState {
            types: vec!["embedding".into(), "layernorm".into(), "lm_head".into()],
            g_sq: vec![ema(Some(2.0)), ema(Some(4.0)), ema(None)],
            s: vec![ema(Some(6.0)), ema(Some(2.0)), ema(None)],
            g_sq_total: ema(Some(10.0)),
            s_total: ema(Some(5.0)),
        }
    }

    #[test]
    fn tracker_fields_extract() {
        let st = sample_tracker();
        let total = gns_field(&st, GnsField::Total).unwrap();
        assert_eq!(total.get("gns").unwrap(), &Value::Num(0.5));
        let ln = gns_field(&st, GnsField::Layernorm).unwrap();
        assert_eq!(ln.get("gns").unwrap(), &Value::Num(0.5));
        // un-observed EMA: null components, null ratio
        let head = gns_field(&st, GnsField::LmHead).unwrap();
        assert_eq!(head.get("gns").unwrap(), &Value::Null);
        // type missing from this tracker
        assert!(gns_field(&st, GnsField::Mlp).is_err());
        let obj = tracker_object(&st);
        assert_eq!(obj.get("types").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(obj.get("alpha").unwrap(), &Value::Num(0.05));
    }

    #[test]
    fn render_strings_bare_rest_json() {
        assert_eq!(render(&Value::Str("micro".into())), "micro");
        assert_eq!(render(&Value::Num(3.0)), "3");
        assert_eq!(render(&Value::Bool(true)), "true");
        assert_eq!(render(&Value::parse(r#"{"a":1}"#).unwrap()), r#"{"a":1}"#);
    }
}
