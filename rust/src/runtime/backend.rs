//! The [`Backend`] trait: everything the coordinator needs from an
//! execution engine, and nothing else.
//!
//! `coordinator::{runner, trainer, ddp}` and the figure harnesses are
//! written against this trait, so the same training loop runs on:
//!
//! * [`crate::runtime::reference`] — a pure-Rust CPU transformer whose
//!   batched backward emits per-example gradient norms simultaneously
//!   with the parameter gradients via the fused
//!   [`crate::runtime::kernels`] (hermetic; the default);
//! * [`crate::runtime::pjrt`] — the AOT HLO-artifact path through the
//!   PJRT C API (feature `pjrt`).
//!
//! The interchange value is [`Buffer`], an opaque per-backend tensor
//! handle. Backends are *stateless with respect to training*: parameters
//! and Adam moments are owned by `ModelRunner` and passed in explicitly,
//! which is what makes run forking (Fig. 6) and checkpointing uniform
//! across backends.

use anyhow::Result;

use crate::data::Batch;
use crate::runtime::manifest::ModelEntry;
use crate::runtime::tensor::Tensor;
use crate::N_TYPES;

/// Opaque tensor handle owned by a backend.
#[derive(Clone)]
pub enum Buffer {
    /// Host-resident f32 tensor (reference backend, checkpoints).
    Host(Tensor),
    /// Literal owned by the PJRT runtime.
    #[cfg(feature = "pjrt")]
    Pjrt(xla::Literal),
}

impl std::fmt::Debug for Buffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Buffer::Host(t) => write!(f, "Buffer::Host(shape={:?})", t.shape),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => write!(f, "Buffer::Pjrt(..)"),
        }
    }
}

impl Buffer {
    pub fn from_tensor(t: Tensor) -> Self {
        Buffer::Host(t)
    }

    /// Copy out to a host tensor.
    pub fn to_tensor(&self) -> Result<Tensor> {
        match self {
            Buffer::Host(t) => Ok(t.clone()),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(l) => crate::runtime::pjrt::literal_to_tensor(l),
        }
    }

    /// Borrow the host tensor; fails on device-resident buffers.
    pub fn as_host(&self) -> Result<&Tensor> {
        match self {
            Buffer::Host(t) => Ok(t),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => anyhow::bail!("buffer is device-resident, expected host tensor"),
        }
    }

    /// Take the host tensor, converting device buffers if necessary.
    pub fn into_host(self) -> Result<Tensor> {
        match self {
            Buffer::Host(t) => Ok(t),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(l) => crate::runtime::pjrt::literal_to_tensor(&l),
        }
    }
}

/// Output of one microbatch gradient step.
pub struct GradOut {
    pub loss: f32,
    pub grads: Vec<Buffer>,
    /// Raw per-layer-type `sum_b ||w'_b||^2` (pre-correction) stats, in
    /// `crate::STATS_ORDER` order. See `gns::GnsAccumulator` for the
    /// per-example scale correction.
    pub stats: [f32; N_TYPES],
}

/// An execution engine for one model configuration.
///
/// `Send + Sync` is part of the contract: the rank-parallel coordinator
/// ([`crate::coordinator::parallel`]) drives one backend instance per
/// worker thread and shares `&[Buffer]` parameter slices across those
/// threads. Both in-tree backends are host-data structs (the reference
/// backend guards its scratch workspace with a `Mutex`), so the bounds
/// hold without unsafe code; a future device backend must either be
/// thread-safe or wrap its client handle accordingly.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("reference", "pjrt").
    fn name(&self) -> &'static str;

    /// Model shape/params/optimizer metadata (the L2→L3 contract).
    fn entry(&self) -> &ModelEntry;

    /// Initialize parameters from a seed (deterministic, seed-sensitive).
    fn init(&self, seed: i32) -> Result<Vec<Buffer>>;

    /// Forward+backward on one microbatch: loss, gradients of the
    /// mean-microbatch loss, and the per-layer-type GNS stats vector.
    /// Implementations compute the stats *with* the gradient contraction
    /// (paper §3), not from materialized per-example gradients.
    fn grad_step(&self, params: &[Buffer], batch: &Batch) -> Result<GradOut>;

    /// Element-wise `acc + grads` over the whole parameter list.
    fn accumulate(&self, acc: Vec<Buffer>, grads: &[Buffer]) -> Result<Vec<Buffer>>;

    /// Per-layer-type squared norms of a gradient set.
    fn grad_sqnorms(&self, grads: &[Buffer]) -> Result<[f64; N_TYPES]>;

    /// One AdamW update with `grads * grad_scale`; `step` is the 1-based
    /// optimizer step for bias correction. Returns (params, m, v).
    #[allow(clippy::too_many_arguments)]
    fn adamw_update(
        &self,
        params: Vec<Buffer>,
        m: Vec<Buffer>,
        v: Vec<Buffer>,
        grads: &[Buffer],
        step: u64,
        lr: f64,
        grad_scale: f64,
    ) -> Result<(Vec<Buffer>, Vec<Buffer>, Vec<Buffer>)>;

    /// Evaluation loss on one batch (no stats, no grads).
    fn eval(&self, params: &[Buffer], batch: &Batch) -> Result<f32>;

    /// Zero-filled gradient accumulator buffer set.
    fn zero_grads(&self) -> Result<Vec<Buffer>> {
        Ok(self
            .entry()
            .params
            .iter()
            .map(|s| Buffer::Host(Tensor::zeros(&s.shape)))
            .collect())
    }
}

/// Creates [`Backend`]s by model name; what the launcher and figure
/// harnesses hold instead of a (Runtime, Manifest) pair.
pub trait BackendFactory {
    /// Instantiate a backend for a named model config.
    fn create(&self, model: &str) -> Result<Box<dyn Backend>>;

    /// Instantiate a backend dedicated to one data-parallel rank worker.
    ///
    /// The default is rank-oblivious (every worker gets an identical
    /// instance, which is exactly right for the CPU reference backend:
    /// each instance is an independent workspace lease). A device factory
    /// can override this to map ranks onto devices — e.g. the pjrt path
    /// binding `rank -> PJRT device ordinal` — without the coordinator
    /// changing.
    ///
    /// This seam now has two callers: the thread engine
    /// ([`crate::coordinator::parallel`]) calls it in-process, and under
    /// `rank_mode = process` each `repro rank-worker` child rebuilds its
    /// factory from the coordinator's `Hello` frame and calls it in its
    /// own address space ([`crate::coordinator::elastic`]). Both paths
    /// must stay deterministic in `(model, rank)` alone — any ambient
    /// state consulted here would silently break the bitwise
    /// thread/process equivalence contract.
    fn create_for_rank(&self, model: &str, _rank: usize) -> Result<Box<dyn Backend>> {
        self.create(model)
    }

    /// Model metadata without paying for backend construction.
    fn describe(&self, model: &str) -> Result<ModelEntry>;

    /// Names of the model configs this factory can create.
    fn models(&self) -> Vec<String>;

    /// Human-readable execution platform ("reference-cpu", "Host", ...).
    fn platform(&self) -> String;
}
