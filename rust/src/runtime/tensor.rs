//! Minimal host tensor.
//!
//! [`Tensor`] is the host-side value type of the [`Buffer`] interchange
//! (`crate::runtime::backend::Buffer`): the reference backend computes on
//! it directly, and checkpoints/metrics serialize through it. Conversions
//! to/from device literals live in `runtime::pjrt` (feature `pjrt`).

use anyhow::{ensure, Result};

/// A host-resident f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        ensure!(n == data.len(), "shape {:?} != data len {}", shape, data.len());
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(&[4, 2]).numel(), 8);
        assert_eq!(Tensor::scalar(2.5).numel(), 1);
    }

    #[test]
    fn sq_norm() {
        let t = Tensor::new(vec![3], vec![1.0, 2.0, 2.0]).unwrap();
        assert!((t.sq_norm() - 9.0).abs() < 1e-12);
    }
}
