//! Minimal host tensor + Literal conversions.
//!
//! The coordinator mostly shuttles opaque `xla::Literal`s between
//! artifacts; [`Tensor`] exists for the places where host-side math or
//! serialization is needed (checkpoints, metrics, token batches).

use anyhow::{ensure, anyhow, Result};
use xla::{ElementType, Literal};

/// A host-resident f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        ensure!(n == data.len(), "shape {:?} != data len {}", shape, data.len());
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(&self.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape to {:?}: {e:?}", self.shape))
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape().map_err(|e| anyhow!("{e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e:?}"))?;
        Tensor::new(dims, data)
    }
}

/// Build an i32 literal of the given shape (token id batches).
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<Literal> {
    ensure!(shape.iter().product::<usize>() == data.len(), "i32 literal shape mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e:?}"))
}

/// Scalar literals for artifact hyper-parameter inputs.
pub fn f32_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn i32_scalar(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Read a scalar f32 out of a literal.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))
}

/// Read an f32 vector (e.g. the (5,) stats vector).
pub fn vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    ensure!(
        lit.ty().map_err(|e| anyhow!("{e:?}"))? == ElementType::F32,
        "expected f32 literal"
    );
    lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(&[4, 2]).numel(), 8);
    }

    #[test]
    fn sq_norm() {
        let t = Tensor::new(vec![3], vec![1.0, 2.0, 2.0]).unwrap();
        assert!((t.sq_norm() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let l = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&l).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn i32_literal_round_trip() {
        let l = i32_literal(&[2, 3], &[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
    }
}
