//! The L2→L3 artifact contract: `artifacts/manifest.json`.
//!
//! Rust never parses HLO text; everything it must know about an artifact —
//! parameter order, shapes, dtypes, layer-type tags, microbatch size, the
//! stats-vector layout — is carried by the manifest written by
//! `python/compile/aot.py`. The manifest is versioned and validated here.
//! Parsing goes through the in-tree JSON substrate (`util::json`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::util::json::Value;

/// Manifest schema version this crate understands.
pub const SCHEMA_VERSION: u64 = 2;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub schema_version: u64,
    pub stats_order: Vec<String>,
    pub configs: HashMap<String, ModelEntry>,
    pub ln_bench: Vec<LnBenchEntry>,
    /// Appendix C.2 teacher–student artifacts (optional).
    pub instability: Option<InstabilityEntry>,
    /// Directory the manifest was loaded from; artifact paths are relative
    /// to it.
    pub root: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// Microbatch size baked into grad_step/eval_step artifact shapes.
    pub microbatch: usize,
    pub n_params: u64,
    pub pallas_ln: bool,
    pub adam: AdamHypers,
    pub params: Vec<ParamSpec>,
    pub artifacts: HashMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct AdamHypers {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub wd: f64,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// Layer type tag: one of `crate::STATS_ORDER`.
    pub ltype: String,
    /// Whether AdamW weight decay applies.
    pub decay: bool,
}

#[derive(Debug, Clone)]
pub struct InstabilityEntry {
    pub b: usize,
    pub t: usize,
    pub d: usize,
    pub n_heads: usize,
    pub bias_noise: f64,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub artifacts: HashMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct LnBenchEntry {
    pub b: usize,
    pub t: usize,
    pub k: usize,
    pub variants: HashMap<String, String>,
    pub vmem_fused: u64,
    pub vmem_plain: u64,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

fn str_map(v: &Value) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    for (k, x) in v.as_obj()? {
        out.insert(k.clone(), x.as_str()?.to_string());
    }
    Ok(out)
}

fn usize_vec(v: &Value) -> Result<Vec<usize>> {
    v.as_arr()?.iter().map(|x| x.as_usize()).collect()
}

impl ModelEntry {
    fn from_json(v: &Value) -> Result<Self> {
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: usize_vec(p.get("shape")?)?,
                    dtype: p.get("dtype")?.as_str()?.to_string(),
                    ltype: p.get("ltype")?.as_str()?.to_string(),
                    decay: p.get("decay")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let adam = v.get("adam")?;
        Ok(Self {
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            seq_len: v.get("seq_len")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            microbatch: v.get("microbatch")?.as_usize()?,
            n_params: v.get("n_params")?.as_u64()?,
            pallas_ln: v.get("pallas_ln")?.as_bool()?,
            adam: AdamHypers {
                beta1: adam.get("beta1")?.as_f64()?,
                beta2: adam.get("beta2")?.as_f64()?,
                eps: adam.get("eps")?.as_f64()?,
                wd: adam.get("wd")?.as_f64()?,
            },
            params,
            artifacts: str_map(v.get("artifacts")?)?,
        })
    }

    /// Absolute path of a named artifact (e.g. "grad_step").
    pub fn artifact_path(&self, root: &Path, name: &str) -> Result<PathBuf> {
        let rel = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' missing from manifest"))?;
        Ok(root.join(rel))
    }

    /// Index of each parameter whose layer type is `ltype`.
    pub fn params_of_type(&self, ltype: &str) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.ltype == ltype)
            .map(|(i, _)| i)
            .collect()
    }
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        let mut m = Self::from_json_text(&text).context("parsing manifest.json")?;
        m.root = dir.to_path_buf();
        m.validate()?;
        Ok(m)
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let mut configs = HashMap::new();
        for (name, c) in v.get("configs")?.as_obj()? {
            configs.insert(
                name.clone(),
                ModelEntry::from_json(c).with_context(|| format!("config {name}"))?,
            );
        }
        let ln_bench = match v.opt("ln_bench") {
            None => Vec::new(),
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(LnBenchEntry {
                        b: e.get("b")?.as_usize()?,
                        t: e.get("t")?.as_usize()?,
                        k: e.get("k")?.as_usize()?,
                        variants: str_map(e.get("variants")?)?,
                        vmem_fused: e.get("vmem_fused")?.as_u64()?,
                        vmem_plain: e.get("vmem_plain")?.as_u64()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        };
        let instability = match v.opt("instability") {
            None | Some(Value::Null) => None,
            Some(e) => Some(InstabilityEntry {
                b: e.get("b")?.as_usize()?,
                t: e.get("t")?.as_usize()?,
                d: e.get("d")?.as_usize()?,
                n_heads: e.get("n_heads")?.as_usize()?,
                bias_noise: e.get("bias_noise")?.as_f64()?,
                param_names: e
                    .get("param_names")?
                    .as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
                param_shapes: e
                    .get("param_shapes")?
                    .as_arr()?
                    .iter()
                    .map(usize_vec)
                    .collect::<Result<Vec<_>>>()?,
                artifacts: str_map(e.get("artifacts")?)?,
            }),
        };
        Ok(Self {
            schema_version: v.get("schema_version")?.as_u64()?,
            stats_order: v
                .get("stats_order")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            configs,
            ln_bench,
            instability,
            root: PathBuf::new(),
        })
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.schema_version == SCHEMA_VERSION,
            "manifest schema {} != supported {}",
            self.schema_version,
            SCHEMA_VERSION
        );
        ensure!(
            self.stats_order == crate::STATS_ORDER,
            "stats_order mismatch between manifest and crate"
        );
        for (name, cfg) in &self.configs {
            let total: u64 = cfg.params.iter().map(|p| p.numel() as u64).sum();
            ensure!(
                total == cfg.n_params,
                "config {name}: param element counts ({total}) != n_params ({})",
                cfg.n_params
            );
            for p in &cfg.params {
                ensure!(
                    crate::STATS_ORDER.contains(&p.ltype.as_str()),
                    "config {name}: unknown layer type {:?} on {}",
                    p.ltype,
                    p.name
                );
                ensure!(p.dtype == "f32", "only f32 params supported, got {}", p.dtype);
            }
            for k in
                ["init", "grad_step", "grad_sqnorms", "accumulate", "adamw_update", "eval_step"]
            {
                ensure!(cfg.artifacts.contains_key(k), "config {name}: artifact {k} missing");
            }
        }
        Ok(())
    }

    pub fn config(&self, name: &str) -> Result<&ModelEntry> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("config '{name}' not in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
          "schema_version": 2,
          "stats_order": ["embedding", "layernorm", "attention", "mlp", "lm_head"],
          "configs": {
            "t": {
              "d_model": 4, "n_layers": 1, "n_heads": 1, "seq_len": 2,
              "vocab": 3, "microbatch": 2, "n_params": 14, "pallas_ln": false,
              "adam": {"beta1": 0.9, "beta2": 0.95, "eps": 1e-8, "wd": 0.1},
              "params": [
                {"name": "wte", "shape": [3, 4], "dtype": "f32",
                 "ltype": "embedding", "decay": true},
                {"name": "lnf.g", "shape": [2], "dtype": "f32",
                 "ltype": "layernorm", "decay": false}
              ],
              "artifacts": {
                "init": "t/init.hlo.txt", "grad_step": "t/grad_step.hlo.txt",
                "grad_sqnorms": "t/x.hlo.txt", "accumulate": "t/a.hlo.txt",
                "adamw_update": "t/u.hlo.txt", "eval_step": "t/e.hlo.txt"
              }
            }
          },
          "ln_bench": []
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let m = Manifest::from_json_text(&sample_json()).unwrap();
        m.validate().unwrap();
        let c = m.config("t").unwrap();
        assert_eq!(c.params[0].numel(), 12);
        assert_eq!(c.params_of_type("embedding"), vec![0]);
        assert_eq!(c.params_of_type("layernorm"), vec![1]);
        assert!((c.adam.eps - 1e-8).abs() < 1e-20);
        assert!(m.instability.is_none());
    }

    #[test]
    fn rejects_bad_schema_version() {
        let bad = sample_json().replace("\"schema_version\": 2", "\"schema_version\": 1");
        let m = Manifest::from_json_text(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn rejects_wrong_param_total() {
        let bad = sample_json().replace("\"n_params\": 14", "\"n_params\": 15");
        let m = Manifest::from_json_text(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn rejects_unknown_ltype() {
        let bad = sample_json().replace("\"ltype\": \"embedding\"", "\"ltype\": \"conv\"");
        let m = Manifest::from_json_text(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn missing_artifact_detected() {
        let bad = sample_json().replace("\"init\": \"t/init.hlo.txt\",", "");
        let m = Manifest::from_json_text(&bad).unwrap();
        assert!(m.validate().is_err());
    }
}
