//! Pure-Rust reference backend: a hermetic CPU transformer.
//!
//! Implements [`Backend`] with a hand-written forward/backward for a small
//! decoder-only transformer (embedding → N× {LayerNorm, causal attention,
//! MLP} → LayerNorm → lm_head), so the whole coordinator — trainer, DDP
//! estimator, GNS tracking, schedules, figures — runs end-to-end with zero
//! native dependencies.
//!
//! Per-example gradient statistics follow the *reference formula* pattern
//! of Goodfellow, "Efficient Per-Example Gradient Computations"
//! (arXiv:1510.01799): the backward pass is evaluated one example at a
//! time, so the per-layer-type `sum_b ||w'_b||^2` stats vector (the
//! quantity the paper's fused kernels compute on-device) is obtained from
//! the definitionally-correct per-example gradients. This is the oracle
//! the Pallas kernels in `python/compile/kernels/` are validated against,
//! now available to the Rust coordinator directly.
//!
//! Conventions match the PJRT artifacts (see DESIGN.md §3):
//! * `grad_step` returns gradients of the **mean-microbatch** loss, i.e.
//!   `sum_b w'_b` with `w'_b = (1/B) dL_b/dw`;
//! * `stats[t] = sum_b ||w'_b||^2` restricted to layer type `t`;
//! * losses are mean cross-entropy per token, in nats.

// Backward-pass helpers thread several gradient slices explicitly; the
// many-argument form is the readable one here.
#![allow(clippy::too_many_arguments)]

use std::collections::HashMap;

use anyhow::{anyhow, ensure, Result};

use crate::data::Batch;
use crate::runtime::backend::{Backend, BackendFactory, Buffer, GradOut};
use crate::runtime::manifest::{AdamHypers, ModelEntry, ParamSpec};
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;
use crate::{N_TYPES, STATS_ORDER};

const LN_EPS: f32 = 1e-5;

/// Shape of a reference-backend model.
#[derive(Debug, Clone, Copy)]
pub struct RefModelConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub microbatch: usize,
}

const fn preset(d: usize, l: usize, h: usize, t: usize) -> RefModelConfig {
    RefModelConfig { d_model: d, n_layers: l, n_heads: h, seq_len: t, vocab: 256, microbatch: 4 }
}

/// Built-in model configs, mirroring the artifact manifest's names.
pub const PRESETS: [(&str, RefModelConfig); 5] = [
    ("nano", preset(16, 2, 2, 32)),
    ("micro", preset(32, 2, 2, 48)),
    ("small", preset(48, 3, 4, 64)),
    ("sweep70", preset(24, 2, 2, 48)),
    ("sweep161", preset(48, 2, 4, 48)),
];

// Per-block parameter offsets from the block base index (2 + 12*i).
const LN1_G: usize = 0;
const LN1_B: usize = 1;
const W_QKV: usize = 2;
const B_QKV: usize = 3;
const W_O: usize = 4;
const B_O: usize = 5;
const LN2_G: usize = 6;
const LN2_B: usize = 7;
const W_FC: usize = 8;
const B_FC: usize = 9;
const W_PROJ: usize = 10;
const B_PROJ: usize = 11;

fn spec(name: &str, shape: Vec<usize>, ltype: &str, decay: bool) -> ParamSpec {
    ParamSpec {
        name: name.to_string(),
        shape,
        dtype: "f32".to_string(),
        ltype: ltype.to_string(),
        decay,
    }
}

fn build_entry(cfg: &RefModelConfig) -> ModelEntry {
    let d = cfg.d_model;
    let mut params = vec![
        spec("wte", vec![cfg.vocab, d], "embedding", true),
        spec("wpe", vec![cfg.seq_len, d], "embedding", true),
    ];
    for i in 0..cfg.n_layers {
        params.push(spec(&format!("h{i}.ln1.g"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.ln1.b"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.attn.w_qkv"), vec![d, 3 * d], "attention", true));
        params.push(spec(&format!("h{i}.attn.b_qkv"), vec![3 * d], "attention", false));
        params.push(spec(&format!("h{i}.attn.w_o"), vec![d, d], "attention", true));
        params.push(spec(&format!("h{i}.attn.b_o"), vec![d], "attention", false));
        params.push(spec(&format!("h{i}.ln2.g"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.ln2.b"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.mlp.w_fc"), vec![d, 4 * d], "mlp", true));
        params.push(spec(&format!("h{i}.mlp.b_fc"), vec![4 * d], "mlp", false));
        params.push(spec(&format!("h{i}.mlp.w_proj"), vec![4 * d, d], "mlp", true));
        params.push(spec(&format!("h{i}.mlp.b_proj"), vec![d], "mlp", false));
    }
    params.push(spec("lnf.g", vec![d], "layernorm", false));
    params.push(spec("lnf.b", vec![d], "layernorm", false));
    params.push(spec("lm_head.w", vec![d, cfg.vocab], "lm_head", true));
    let n_params = params.iter().map(|p| p.numel() as u64).sum();
    ModelEntry {
        d_model: d,
        n_layers: cfg.n_layers,
        n_heads: cfg.n_heads,
        seq_len: cfg.seq_len,
        vocab: cfg.vocab,
        microbatch: cfg.microbatch,
        n_params,
        pallas_ln: false,
        adam: AdamHypers { beta1: 0.9, beta2: 0.95, eps: 1e-8, wd: 0.1 },
        params,
        artifacts: HashMap::new(),
    }
}

// ---------------------------------------------------------------------------
// Dense math helpers (row-major, f32)
// ---------------------------------------------------------------------------

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y = x @ w (+ b)` with `x: [t, k]`, `w: [k, n]`.
fn linear_fwd(x: &[f32], w: &[f32], b: Option<&[f32]>, t: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0f32; t * n];
    for ti in 0..t {
        let yrow = &mut y[ti * n..(ti + 1) * n];
        if let Some(b) = b {
            yrow.copy_from_slice(&b[..n]);
        }
        for kk in 0..k {
            let xv = x[ti * k + kk];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for j in 0..n {
                yrow[j] += xv * wrow[j];
            }
        }
    }
    y
}

/// Backward of [`linear_fwd`]: accumulates `dw += x^T dy`,
/// `db += colsum(dy)`, returns `dx = dy @ w^T`.
fn linear_bwd(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    t: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
    db: Option<&mut [f32]>,
) -> Vec<f32> {
    if let Some(db) = db {
        for ti in 0..t {
            let dyr = &dy[ti * n..(ti + 1) * n];
            for j in 0..n {
                db[j] += dyr[j];
            }
        }
    }
    for ti in 0..t {
        let dyr = &dy[ti * n..(ti + 1) * n];
        for kk in 0..k {
            let xv = x[ti * k + kk];
            if xv == 0.0 {
                continue;
            }
            let dwr = &mut dw[kk * n..(kk + 1) * n];
            for j in 0..n {
                dwr[j] += xv * dyr[j];
            }
        }
    }
    let mut dx = vec![0f32; t * k];
    for ti in 0..t {
        let dyr = &dy[ti * n..(ti + 1) * n];
        for kk in 0..k {
            dx[ti * k + kk] = dot(dyr, &w[kk * n..(kk + 1) * n]);
        }
    }
    dx
}

/// Per-row LayerNorm; returns (out, xhat, rstd).
fn layernorm_fwd(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    t: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut out = vec![0f32; t * d];
    let mut xhat = vec![0f32; t * d];
    let mut rstd = vec![0f32; t];
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let r = 1.0 / (var + LN_EPS).sqrt();
        rstd[ti] = r;
        for j in 0..d {
            let xh = (row[j] - mean) * r;
            xhat[ti * d + j] = xh;
            out[ti * d + j] = g[j] * xh + b[j];
        }
    }
    (out, xhat, rstd)
}

/// Backward of [`layernorm_fwd`]: accumulates `dg`, `db`, returns `dx`.
fn layernorm_bwd(
    dout: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    t: usize,
    d: usize,
    dg: &mut [f32],
    db: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0f32; t * d];
    for ti in 0..t {
        let mut m1 = 0f32; // mean(dxhat)
        let mut m2 = 0f32; // mean(dxhat * xhat)
        for j in 0..d {
            let dy = dout[ti * d + j];
            let xh = xhat[ti * d + j];
            dg[j] += dy * xh;
            db[j] += dy;
            let dxh = dy * g[j];
            m1 += dxh;
            m2 += dxh * xh;
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for j in 0..d {
            let dxh = dout[ti * d + j] * g[j];
            dx[ti * d + j] = rstd[ti] * (dxh - m1 - xhat[ti * d + j] * m2);
        }
    }
    dx
}

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_A: f32 = 0.044715;

fn gelu(v: f32) -> f32 {
    0.5 * v * (1.0 + (GELU_C * (v + GELU_A * v * v * v)).tanh())
}

fn gelu_grad(v: f32) -> f32 {
    let u = GELU_C * (v + GELU_A * v * v * v);
    let th = u.tanh();
    let sech2 = 1.0 - th * th;
    0.5 * (1.0 + th) + 0.5 * v * sech2 * GELU_C * (1.0 + 3.0 * GELU_A * v * v)
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// Per-example activation caches from one forward pass.
struct BlockCache {
    ln1_xhat: Vec<f32>,
    ln1_rstd: Vec<f32>,
    ln1_out: Vec<f32>,
    /// `[t, 3d]` rows of `[q | k | v]` (post-bias).
    qkv: Vec<f32>,
    /// Softmax attention weights, `[heads, t, t]` (causal; upper zero).
    att_p: Vec<f32>,
    /// Concatenated head outputs before the output projection, `[t, d]`.
    att_out: Vec<f32>,
    ln2_xhat: Vec<f32>,
    ln2_rstd: Vec<f32>,
    ln2_out: Vec<f32>,
    fc_pre: Vec<f32>,
    fc_act: Vec<f32>,
}

struct Caches {
    blocks: Vec<BlockCache>,
    lnf_xhat: Vec<f32>,
    lnf_rstd: Vec<f32>,
    lnf_out: Vec<f32>,
    /// Softmax over logits, `[t, vocab]`.
    probs: Vec<f32>,
}

/// Pure-Rust CPU implementation of [`Backend`].
pub struct ReferenceBackend {
    cfg: RefModelConfig,
    entry: ModelEntry,
    /// Per-parameter index into `STATS_ORDER`.
    ltype_idx: Vec<usize>,
}

impl ReferenceBackend {
    pub fn new(cfg: RefModelConfig) -> Result<Self> {
        ensure!(cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0, "d_model must divide by heads");
        ensure!(
            cfg.n_layers > 0 && cfg.seq_len > 0 && cfg.vocab > 1 && cfg.microbatch > 0,
            "degenerate reference model config {cfg:?}"
        );
        let entry = build_entry(&cfg);
        let ltype_idx = entry
            .params
            .iter()
            .map(|p| {
                STATS_ORDER
                    .iter()
                    .position(|t| *t == p.ltype)
                    .ok_or_else(|| anyhow!("unknown ltype {}", p.ltype))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { cfg, entry, ltype_idx })
    }

    pub fn from_preset(name: &str) -> Result<Self> {
        let cfg = PRESETS
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, c)| *c)
            .ok_or_else(|| {
                anyhow!(
                    "unknown reference model {name:?} (have: {:?})",
                    PRESETS.map(|(n, _)| n)
                )
            })?;
        Self::new(cfg)
    }

    pub fn config(&self) -> &RefModelConfig {
        &self.cfg
    }

    fn block_base(&self, i: usize) -> usize {
        2 + 12 * i
    }

    fn lnf_g_idx(&self) -> usize {
        2 + 12 * self.cfg.n_layers
    }

    fn host_params<'a>(&self, params: &'a [Buffer]) -> Result<Vec<&'a [f32]>> {
        ensure!(
            params.len() == self.entry.params.len(),
            "got {} param tensors, model has {}",
            params.len(),
            self.entry.params.len()
        );
        params.iter().map(|b| Ok(b.as_host()?.data.as_slice())).collect()
    }

    /// Forward pass for one example; returns (mean token loss, caches).
    fn example_forward(
        &self,
        ps: &[&[f32]],
        ids: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Caches)> {
        let d = self.cfg.d_model;
        let t = ids.len();
        let v = self.cfg.vocab;
        let heads = self.cfg.n_heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();

        // Embedding: wte[id] + wpe[pos].
        let mut x = vec![0f32; t * d];
        for ti in 0..t {
            let id = ids[ti] as usize;
            ensure!(id < v, "token id {id} out of vocab {v}");
            for j in 0..d {
                x[ti * d + j] = ps[0][id * d + j] + ps[1][ti * d + j];
            }
        }

        let mut blocks = Vec::with_capacity(self.cfg.n_layers);
        for i in 0..self.cfg.n_layers {
            let base = self.block_base(i);
            let (ln1_out, ln1_xhat, ln1_rstd) =
                layernorm_fwd(&x, ps[base + LN1_G], ps[base + LN1_B], t, d);
            let qkv = linear_fwd(&ln1_out, ps[base + W_QKV], Some(ps[base + B_QKV]), t, d, 3 * d);

            // Causal multi-head attention.
            let mut att_p = vec![0f32; heads * t * t];
            let mut att_out = vec![0f32; t * d];
            for h in 0..heads {
                let q_off = h * hd;
                let k_off = d + h * hd;
                let v_off = 2 * d + h * hd;
                for ti in 0..t {
                    let q_row = &qkv[ti * 3 * d + q_off..ti * 3 * d + q_off + hd];
                    let mut row = vec![0f32; ti + 1];
                    let mut maxv = f32::NEG_INFINITY;
                    for s in 0..=ti {
                        let k_row = &qkv[s * 3 * d + k_off..s * 3 * d + k_off + hd];
                        let sc = scale * dot(q_row, k_row);
                        row[s] = sc;
                        maxv = maxv.max(sc);
                    }
                    let mut sum = 0f32;
                    for r in row.iter_mut() {
                        *r = (*r - maxv).exp();
                        sum += *r;
                    }
                    for (s, r) in row.iter().enumerate() {
                        let pv = r / sum;
                        att_p[h * t * t + ti * t + s] = pv;
                        let v_row = &qkv[s * 3 * d + v_off..s * 3 * d + v_off + hd];
                        for j in 0..hd {
                            att_out[ti * d + q_off + j] += pv * v_row[j];
                        }
                    }
                }
            }

            let o = linear_fwd(&att_out, ps[base + W_O], Some(ps[base + B_O]), t, d, d);
            for (xv, ov) in x.iter_mut().zip(&o) {
                *xv += *ov;
            }

            let (ln2_out, ln2_xhat, ln2_rstd) =
                layernorm_fwd(&x, ps[base + LN2_G], ps[base + LN2_B], t, d);
            let fc_pre =
                linear_fwd(&ln2_out, ps[base + W_FC], Some(ps[base + B_FC]), t, d, 4 * d);
            let fc_act: Vec<f32> = fc_pre.iter().map(|&u| gelu(u)).collect();
            let p = linear_fwd(&fc_act, ps[base + W_PROJ], Some(ps[base + B_PROJ]), t, 4 * d, d);
            for (xv, pv) in x.iter_mut().zip(&p) {
                *xv += *pv;
            }

            blocks.push(BlockCache {
                ln1_xhat,
                ln1_rstd,
                ln1_out,
                qkv,
                att_p,
                att_out,
                ln2_xhat,
                ln2_rstd,
                ln2_out,
                fc_pre,
                fc_act,
            });
        }

        let gi = self.lnf_g_idx();
        let (lnf_out, lnf_xhat, lnf_rstd) = layernorm_fwd(&x, ps[gi], ps[gi + 1], t, d);
        let logits = linear_fwd(&lnf_out, ps[gi + 2], None, t, d, v);

        // Softmax cross-entropy, mean over tokens.
        let mut probs = vec![0f32; t * v];
        let mut loss = 0f64;
        for ti in 0..t {
            let row = &logits[ti * v..(ti + 1) * v];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for j in 0..v {
                let e = (row[j] - maxv).exp();
                probs[ti * v + j] = e;
                sum += e;
            }
            for j in 0..v {
                probs[ti * v + j] /= sum;
            }
            let y = targets[ti] as usize;
            ensure!(y < v, "target id {y} out of vocab {v}");
            loss -= (probs[ti * v + y].max(1e-30) as f64).ln();
        }
        let loss = (loss / t as f64) as f32;

        Ok((loss, Caches { blocks, lnf_xhat, lnf_rstd, lnf_out, probs }))
    }

    /// Backward pass for one example; accumulates `dL_b/dw` into `eg`.
    fn example_backward(
        &self,
        ps: &[&[f32]],
        ids: &[i32],
        targets: &[i32],
        caches: &Caches,
        eg: &mut [Vec<f32>],
    ) {
        let d = self.cfg.d_model;
        let t = ids.len();
        let v = self.cfg.vocab;
        let heads = self.cfg.n_heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let gi = self.lnf_g_idx();

        // dlogits = (softmax - onehot) / t.
        let mut dlogits = vec![0f32; t * v];
        let inv_t = 1.0 / t as f32;
        for ti in 0..t {
            for j in 0..v {
                dlogits[ti * v + j] = caches.probs[ti * v + j] * inv_t;
            }
            dlogits[ti * v + targets[ti] as usize] -= inv_t;
        }

        // lm_head (no bias).
        let dlnf_out =
            linear_bwd(&caches.lnf_out, ps[gi + 2], &dlogits, t, d, v, &mut eg[gi + 2], None);

        // Final LayerNorm.
        let (dgf, dbf) = two_mut(eg, gi, gi + 1);
        let mut dx = layernorm_bwd(
            &dlnf_out,
            &caches.lnf_xhat,
            &caches.lnf_rstd,
            ps[gi],
            t,
            d,
            dgf,
            dbf,
        );

        for i in (0..self.cfg.n_layers).rev() {
            let base = self.block_base(i);
            let c = &caches.blocks[i];

            // MLP branch: x_out = x_mid + proj(gelu(fc(ln2(x_mid)))).
            let dfc_act = {
                let (dw, db) = two_mut(eg, base + W_PROJ, base + B_PROJ);
                linear_bwd(&c.fc_act, ps[base + W_PROJ], &dx, t, 4 * d, d, dw, Some(db))
            };
            let mut dfc_pre = dfc_act;
            for (g, &u) in dfc_pre.iter_mut().zip(&c.fc_pre) {
                *g *= gelu_grad(u);
            }
            let dln2_out = {
                let (dw, db) = two_mut(eg, base + W_FC, base + B_FC);
                linear_bwd(&c.ln2_out, ps[base + W_FC], &dfc_pre, t, d, 4 * d, dw, Some(db))
            };
            let dx_ln2 = {
                let (dg, db) = two_mut(eg, base + LN2_G, base + LN2_B);
                layernorm_bwd(&dln2_out, &c.ln2_xhat, &c.ln2_rstd, ps[base + LN2_G], t, d, dg, db)
            };
            for (a, b) in dx.iter_mut().zip(&dx_ln2) {
                *a += *b;
            }

            // Attention branch: x_mid = x_in + w_o(att(ln1(x_in))).
            let datt_out = {
                let (dw, db) = two_mut(eg, base + W_O, base + B_O);
                linear_bwd(&c.att_out, ps[base + W_O], &dx, t, d, d, dw, Some(db))
            };

            let mut dqkv = vec![0f32; t * 3 * d];
            for h in 0..heads {
                let q_off = h * hd;
                let k_off = d + h * hd;
                let v_off = 2 * d + h * hd;
                let ph = &c.att_p[h * t * t..(h + 1) * t * t];
                for ti in 0..t {
                    let dout_row = &datt_out[ti * d + q_off..ti * d + q_off + hd];
                    let mut dp = vec![0f32; ti + 1];
                    for s in 0..=ti {
                        let v_row = &c.qkv[s * 3 * d + v_off..s * 3 * d + v_off + hd];
                        dp[s] = dot(dout_row, v_row);
                        let pv = ph[ti * t + s];
                        for j in 0..hd {
                            dqkv[s * 3 * d + v_off + j] += pv * dout_row[j];
                        }
                    }
                    let dsum: f32 = (0..=ti).map(|s| dp[s] * ph[ti * t + s]).sum();
                    for s in 0..=ti {
                        let ds = ph[ti * t + s] * (dp[s] - dsum) * scale;
                        if ds == 0.0 {
                            continue;
                        }
                        for j in 0..hd {
                            dqkv[ti * 3 * d + q_off + j] += ds * c.qkv[s * 3 * d + k_off + j];
                            dqkv[s * 3 * d + k_off + j] += ds * c.qkv[ti * 3 * d + q_off + j];
                        }
                    }
                }
            }

            let dln1_out = {
                let (dw, db) = two_mut(eg, base + W_QKV, base + B_QKV);
                linear_bwd(&c.ln1_out, ps[base + W_QKV], &dqkv, t, d, 3 * d, dw, Some(db))
            };
            let dx_ln1 = {
                let (dg, db) = two_mut(eg, base + LN1_G, base + LN1_B);
                layernorm_bwd(&dln1_out, &c.ln1_xhat, &c.ln1_rstd, ps[base + LN1_G], t, d, dg, db)
            };
            for (a, b) in dx.iter_mut().zip(&dx_ln1) {
                *a += *b;
            }
        }

        // Embedding.
        for ti in 0..t {
            let id = ids[ti] as usize;
            for j in 0..d {
                eg[0][id * d + j] += dx[ti * d + j];
                eg[1][ti * d + j] += dx[ti * d + j];
            }
        }
    }

    fn check_batch(&self, batch: &Batch) -> Result<()> {
        ensure!(
            batch.seq_len == self.cfg.seq_len && batch.batch > 0,
            "batch shape ({}, {}) incompatible with model seq_len {}",
            batch.batch,
            batch.seq_len,
            self.cfg.seq_len
        );
        let n = batch.batch * batch.seq_len;
        ensure!(
            batch.inputs.len() == n && batch.targets.len() == n,
            "batch declares {} tokens but holds {} inputs / {} targets",
            n,
            batch.inputs.len(),
            batch.targets.len()
        );
        Ok(())
    }
}

/// Disjoint mutable borrows of two entries of a slice of Vecs.
fn two_mut(eg: &mut [Vec<f32>], a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
    assert!(a < b);
    let (lo, hi) = eg.split_at_mut(b);
    (&mut lo[a], &mut hi[0])
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn init(&self, seed: i32) -> Result<Vec<Buffer>> {
        let mut rng = Rng::seed_from_u64(seed as i64 as u64);
        let resid_scale = 1.0 / (2.0 * self.cfg.n_layers as f64).sqrt();
        let out = self
            .entry
            .params
            .iter()
            .map(|p| {
                let n = p.numel();
                let data: Vec<f32> = if p.shape.len() == 1 {
                    if p.name.ends_with(".g") {
                        vec![1.0; n]
                    } else {
                        vec![0.0; n]
                    }
                } else {
                    let std = if p.name.contains("w_o") || p.name.contains("w_proj") {
                        0.02 * resid_scale
                    } else {
                        0.02
                    };
                    (0..n).map(|_| (rng.normal() * std) as f32).collect()
                };
                Ok(Buffer::Host(Tensor::new(p.shape.clone(), data)?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(out)
    }

    fn grad_step(&self, params: &[Buffer], batch: &Batch) -> Result<GradOut> {
        self.check_batch(batch)?;
        let ps = self.host_params(params)?;
        let t = batch.seq_len;
        let bsz = batch.batch;
        let inv_b = 1.0 / bsz as f32;

        let mut acc: Vec<Vec<f32>> =
            self.entry.params.iter().map(|p| vec![0f32; p.numel()]).collect();
        let mut eg: Vec<Vec<f32>> =
            self.entry.params.iter().map(|p| vec![0f32; p.numel()]).collect();
        let mut stats = [0f64; N_TYPES];
        let mut loss_sum = 0f64;

        for b in 0..bsz {
            let ids = &batch.inputs[b * t..(b + 1) * t];
            let tgt = &batch.targets[b * t..(b + 1) * t];
            for g in eg.iter_mut() {
                g.fill(0.0);
            }
            let (loss, caches) = self.example_forward(&ps, ids, tgt)?;
            loss_sum += loss as f64;
            self.example_backward(&ps, ids, tgt, &caches, &mut eg);
            for (i, g) in eg.iter().enumerate() {
                let ti = self.ltype_idx[i];
                let mut sq = 0f64;
                let a = &mut acc[i];
                for (av, gv) in a.iter_mut().zip(g) {
                    let w = gv * inv_b; // w'_b = (1/B) dL_b/dw
                    *av += w;
                    sq += (w as f64) * (w as f64);
                }
                stats[ti] += sq;
            }
        }

        let grads = acc
            .into_iter()
            .zip(&self.entry.params)
            .map(|(data, p)| Ok(Buffer::Host(Tensor::new(p.shape.clone(), data)?)))
            .collect::<Result<Vec<_>>>()?;
        let mut stats32 = [0f32; N_TYPES];
        for (dst, src) in stats32.iter_mut().zip(stats) {
            *dst = src as f32;
        }
        Ok(GradOut { loss: (loss_sum / bsz as f64) as f32, grads, stats: stats32 })
    }

    fn accumulate(&self, acc: Vec<Buffer>, grads: &[Buffer]) -> Result<Vec<Buffer>> {
        ensure!(acc.len() == grads.len(), "accumulate arity mismatch");
        acc.into_iter()
            .zip(grads)
            .map(|(a, g)| {
                let mut t = a.into_host()?;
                let gt = g.as_host()?;
                ensure!(t.data.len() == gt.data.len(), "accumulate shape mismatch");
                for (x, y) in t.data.iter_mut().zip(&gt.data) {
                    *x += *y;
                }
                Ok(Buffer::Host(t))
            })
            .collect()
    }

    fn grad_sqnorms(&self, grads: &[Buffer]) -> Result<[f64; N_TYPES]> {
        ensure!(grads.len() == self.entry.params.len(), "grad_sqnorms arity mismatch");
        let mut out = [0f64; N_TYPES];
        for (i, g) in grads.iter().enumerate() {
            out[self.ltype_idx[i]] += g.as_host()?.sq_norm();
        }
        Ok(out)
    }

    fn adamw_update(
        &self,
        params: Vec<Buffer>,
        m: Vec<Buffer>,
        v: Vec<Buffer>,
        grads: &[Buffer],
        step: u64,
        lr: f64,
        grad_scale: f64,
    ) -> Result<(Vec<Buffer>, Vec<Buffer>, Vec<Buffer>)> {
        let n = self.entry.params.len();
        ensure!(
            params.len() == n && m.len() == n && v.len() == n && grads.len() == n,
            "adamw_update arity mismatch"
        );
        ensure!(step >= 1, "adamw_update needs a 1-based step");
        let h = &self.entry.adam;
        let bc1 = 1.0 - h.beta1.powi(step.min(i32::MAX as u64) as i32);
        let bc2 = 1.0 - h.beta2.powi(step.min(i32::MAX as u64) as i32);

        let mut new_p = Vec::with_capacity(n);
        let mut new_m = Vec::with_capacity(n);
        let mut new_v = Vec::with_capacity(n);
        for (i, ((pb, mb), vb)) in params.into_iter().zip(m).zip(v).enumerate() {
            let mut pt = pb.into_host()?;
            let mut mt = mb.into_host()?;
            let mut vt = vb.into_host()?;
            let gt = grads[i].as_host()?;
            ensure!(
                pt.data.len() == gt.data.len()
                    && mt.data.len() == gt.data.len()
                    && vt.data.len() == gt.data.len(),
                "adamw_update shape mismatch on {}",
                self.entry.params[i].name
            );
            let decay = self.entry.params[i].decay;
            for j in 0..pt.data.len() {
                let g = gt.data[j] as f64 * grad_scale;
                let m1 = h.beta1 * mt.data[j] as f64 + (1.0 - h.beta1) * g;
                let v1 = h.beta2 * vt.data[j] as f64 + (1.0 - h.beta2) * g * g;
                let mhat = m1 / bc1;
                let vhat = v1 / bc2;
                let mut upd = mhat / (vhat.sqrt() + h.eps);
                if decay {
                    upd += h.wd * pt.data[j] as f64;
                }
                pt.data[j] = (pt.data[j] as f64 - lr * upd) as f32;
                mt.data[j] = m1 as f32;
                vt.data[j] = v1 as f32;
            }
            new_p.push(Buffer::Host(pt));
            new_m.push(Buffer::Host(mt));
            new_v.push(Buffer::Host(vt));
        }
        Ok((new_p, new_m, new_v))
    }

    fn eval(&self, params: &[Buffer], batch: &Batch) -> Result<f32> {
        self.check_batch(batch)?;
        let ps = self.host_params(params)?;
        let t = batch.seq_len;
        let mut loss_sum = 0f64;
        for b in 0..batch.batch {
            let ids = &batch.inputs[b * t..(b + 1) * t];
            let tgt = &batch.targets[b * t..(b + 1) * t];
            let (loss, _) = self.example_forward(&ps, ids, tgt)?;
            loss_sum += loss as f64;
        }
        Ok((loss_sum / batch.batch as f64) as f32)
    }
}

/// Factory over the built-in [`PRESETS`].
pub struct ReferenceFactory;

impl BackendFactory for ReferenceFactory {
    fn create(&self, model: &str) -> Result<Box<dyn Backend>> {
        Ok(Box::new(ReferenceBackend::from_preset(model)?))
    }

    fn describe(&self, model: &str) -> Result<ModelEntry> {
        Ok(ReferenceBackend::from_preset(model)?.entry().clone())
    }

    fn models(&self) -> Vec<String> {
        PRESETS.iter().map(|(n, _)| n.to_string()).collect()
    }

    fn platform(&self) -> String {
        "reference-cpu".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(microbatch: usize) -> RefModelConfig {
        RefModelConfig {
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            seq_len: 6,
            vocab: 11,
            microbatch,
        }
    }

    fn tiny_batch(bsz: usize, t: usize, vocab: usize, seed: u64) -> Batch {
        let mut rng = Rng::seed_from_u64(seed);
        let n = bsz * t;
        Batch {
            batch: bsz,
            seq_len: t,
            inputs: (0..n).map(|_| rng.range(0, vocab) as i32).collect(),
            targets: (0..n).map(|_| rng.range(0, vocab) as i32).collect(),
        }
    }

    fn perturbed(params: &[Buffer], i: usize, j: usize, eps: f32) -> Vec<Buffer> {
        let mut out = params.to_vec();
        let mut t = out[i].to_tensor().unwrap();
        t.data[j] += eps;
        out[i] = Buffer::Host(t);
        out
    }

    #[test]
    fn presets_all_build() {
        for (name, _) in PRESETS {
            let be = ReferenceBackend::from_preset(name).unwrap();
            let e = be.entry();
            assert_eq!(e.params.len(), 2 + 12 * e.n_layers + 3, "{name}");
            let total: u64 = e.params.iter().map(|p| p.numel() as u64).sum();
            assert_eq!(total, e.n_params, "{name}");
        }
        assert!(ReferenceBackend::from_preset("gpt5").is_err());
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let a = be.init(3).unwrap();
        let b = be.init(3).unwrap();
        let c = be.init(4).unwrap();
        assert_eq!(a[0].as_host().unwrap(), b[0].as_host().unwrap());
        assert_ne!(a[0].as_host().unwrap(), c[0].as_host().unwrap());
        // ln gamma ones, biases zero
        let e = be.entry();
        for (i, p) in e.params.iter().enumerate() {
            let t = a[i].as_host().unwrap();
            if p.name.ends_with(".g") {
                assert!(t.data.iter().all(|&x| x == 1.0), "{}", p.name);
            } else if p.shape.len() == 1 {
                assert!(t.data.iter().all(|&x| x == 0.0), "{}", p.name);
            }
        }
    }

    #[test]
    fn grad_step_is_deterministic() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(0).unwrap();
        let batch = tiny_batch(2, 6, 11, 7);
        let a = be.grad_step(&params, &batch).unwrap();
        let b = be.grad_step(&params, &batch).unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.stats, b.stats);
        for (x, y) in a.grads.iter().zip(&b.grads) {
            assert_eq!(x.as_host().unwrap(), y.as_host().unwrap());
        }
    }

    #[test]
    fn grad_step_loss_matches_eval() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(1).unwrap();
        let batch = tiny_batch(2, 6, 11, 3);
        let g = be.grad_step(&params, &batch).unwrap();
        let e = be.eval(&params, &batch).unwrap();
        assert!((g.loss - e).abs() < 1e-6, "{} vs {e}", g.loss);
        // random-init loss near ln(vocab)
        assert!((e - (11f32).ln()).abs() < 1.0, "{e}");
    }

    /// The backward pass against central finite differences, per tensor.
    #[test]
    fn gradients_match_finite_differences() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(5).unwrap();
        let batch = tiny_batch(2, 6, 11, 9);
        let out = be.grad_step(&params, &batch).unwrap();
        let h = 1e-2f32;
        let mut checked = 0usize;
        for (i, g) in out.grads.iter().enumerate() {
            let gt = g.as_host().unwrap();
            // most-identifiable coordinate of this tensor
            let (j, &ana) = gt
                .data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .unwrap();
            if ana.abs() < 1e-3 {
                continue;
            }
            let lp = be.eval(&perturbed(&params, i, j, h), &batch).unwrap();
            let lm = be.eval(&perturbed(&params, i, j, -h), &batch).unwrap();
            let num = (lp - lm) / (2.0 * h);
            let tol = 0.1 * ana.abs().max(num.abs()) + 2e-3;
            assert!(
                (num - ana).abs() <= tol,
                "param {} ({}): numeric {num} vs analytic {ana}",
                be.entry().params[i].name,
                i
            );
            checked += 1;
        }
        assert!(checked >= 5, "only {checked} tensors had a testable coordinate");
    }

    /// `stats` and `grads` of a B=4 step against brute-force per-example
    /// gradients obtained from four B=1 steps (Goodfellow reference path).
    #[test]
    fn stats_match_bruteforce_per_example_gradients() {
        let be4 = ReferenceBackend::new(tiny_cfg(4)).unwrap();
        let be1 = ReferenceBackend::new(tiny_cfg(1)).unwrap();
        let params = be4.init(2).unwrap();
        let t = 6;
        let batch = tiny_batch(4, t, 11, 11);
        let out = be4.grad_step(&params, &batch).unwrap();

        let mut brute_stats = [0f64; N_TYPES];
        let mut brute_grads: Vec<Vec<f64>> =
            be4.entry().params.iter().map(|p| vec![0f64; p.numel()]).collect();
        for b in 0..4 {
            let one = Batch {
                batch: 1,
                seq_len: t,
                inputs: batch.inputs[b * t..(b + 1) * t].to_vec(),
                targets: batch.targets[b * t..(b + 1) * t].to_vec(),
            };
            // B=1: returned grads are exactly dL_b/dw.
            let ob = be1.grad_step(&params, &one).unwrap();
            for (i, g) in ob.grads.iter().enumerate() {
                let gt = g.as_host().unwrap();
                let ti = be1.ltype_idx[i];
                let mut sq = 0f64;
                for (acc, &gv) in brute_grads[i].iter_mut().zip(&gt.data) {
                    let w = gv as f64 / 4.0;
                    *acc += w;
                    sq += w * w;
                }
                brute_stats[ti] += sq;
            }
        }
        for (a, b) in out.stats.iter().zip(brute_stats) {
            assert!(
                ((*a as f64) - b).abs() <= 1e-4 * b.abs().max(1e-12),
                "stats {a} vs brute {b}"
            );
        }
        for (i, g) in out.grads.iter().enumerate() {
            let gt = g.as_host().unwrap();
            for (x, y) in gt.data.iter().zip(&brute_grads[i]) {
                assert!(
                    ((*x as f64) - y).abs() <= 1e-5 * y.abs().max(1e-6),
                    "grad[{i}] {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn accumulate_and_sqnorms_are_consistent() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(0).unwrap();
        let g1 = be.grad_step(&params, &tiny_batch(2, 6, 11, 1)).unwrap().grads;
        let g2 = be.grad_step(&params, &tiny_batch(2, 6, 11, 2)).unwrap().grads;
        let acc = be.accumulate(be.zero_grads().unwrap(), &g1).unwrap();
        let acc = be.accumulate(acc, &g2).unwrap();
        let sq = be.grad_sqnorms(&acc).unwrap();
        let mut host = [0f64; N_TYPES];
        for (i, (a, b)) in g1.iter().zip(&g2).enumerate() {
            let ta = a.as_host().unwrap();
            let tb = b.as_host().unwrap();
            let s: f64 = ta
                .data
                .iter()
                .zip(&tb.data)
                .map(|(x, y)| ((x + y) as f64) * ((x + y) as f64))
                .sum();
            host[be.ltype_idx[i]] += s;
        }
        for (d, h) in sq.iter().zip(host) {
            assert!((d - h).abs() <= 1e-6 * h.max(1e-12), "{d} vs {h}");
        }
    }

    #[test]
    fn adamw_overfits_one_batch() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let mut params = be.init(4).unwrap();
        let mut m = be.zero_grads().unwrap();
        let mut v = be.zero_grads().unwrap();
        let batch = tiny_batch(2, 6, 11, 5);
        let before = be.eval(&params, &batch).unwrap();
        for step in 1..=8u64 {
            let out = be.grad_step(&params, &batch).unwrap();
            let (p2, m2, v2) = be.adamw_update(params, m, v, &out.grads, step, 3e-3, 1.0).unwrap();
            params = p2;
            m = m2;
            v = v2;
        }
        let after = be.eval(&params, &batch).unwrap();
        assert!(after < before, "{after} !< {before}");
    }
}
