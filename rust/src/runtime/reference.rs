//! Pure-Rust reference backend: a hermetic CPU transformer.
//!
//! Implements [`Backend`] with a hand-written forward/backward for a small
//! decoder-only transformer (embedding → N× {LayerNorm, causal attention,
//! MLP} → LayerNorm → lm_head), so the whole coordinator — trainer, DDP
//! estimator, GNS tracking, schedules, figures — runs end-to-end with zero
//! native dependencies.
//!
//! Per-example gradient statistics use the paper's *simultaneous* method
//! (Gray et al. §3): one batched backward over the flattened `[B·T, ...]
//! ` tensors computes the parameter gradients, while the per-layer-type
//! `sum_b ||w'_b||^2` stats vector is emitted from the same contractions —
//! Goodfellow's Gram-matrix trick for linear weights
//! (`runtime::kernels::gram`), a fused LayerNorm backward for the
//! normalization layers (`runtime::kernels::layernorm`), and column-sum
//! reuse for biases. No per-example weight gradient is ever materialized.
//! The naive one-example-at-a-time backward (Goodfellow's *reference
//! formula*, arXiv:1510.01799) is retained as
//! [`ReferenceBackend::grad_step_per_example`], the correctness oracle the
//! fused path — like the Pallas kernels in `python/compile/kernels/` — is
//! validated against, and the "before" baseline in the train_step bench.
//!
//! The hot path is data-parallel over examples and output rows via a
//! persistent [`WorkerPool`] owned by the backend (`NANOGNS_THREADS`
//! overrides the worker count): threads are spawned once at construction
//! and parked between parallel regions, so steady-state training creates
//! zero threads (`kernels::threads::total_threads_spawned`) and the
//! dispatch itself allocates nothing. Inner loops dispatch through
//! `kernels::simd` (AVX2/FMA, NEON, or the scalar oracle under
//! `NANOGNS_FORCE_SCALAR=1`); every reduction has a fixed order, so
//! results are bitwise identical for any worker count within a dispatch
//! tier. Activation workspaces are pre-allocated once and reused across
//! steps — workers write disjoint row blocks of the same pinned buffers;
//! [`workspace_bytes`] estimates their size and construction fails with
//! a clear error when it would exceed the configurable cap
//! (`NANOGNS_WS_CAP_MB`, default 1 GiB) instead of OOMing mid-run.
//!
//! Conventions match the PJRT artifacts (see DESIGN.md §3):
//! * `grad_step` returns gradients of the **mean-microbatch** loss, i.e.
//!   `sum_b w'_b` with `w'_b = (1/B) dL_b/dw`;
//! * `stats[t] = sum_b ||w'_b||^2` restricted to layer type `t`;
//! * losses are mean cross-entropy per token, in nats.

// Backward-pass helpers thread several gradient slices explicitly; the
// many-argument form is the readable one here.
#![allow(clippy::too_many_arguments)]

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, ensure, Result};

use crate::data::Batch;
use crate::norms::{NormKind, NormPlacement};
use crate::runtime::backend::{Backend, BackendFactory, Buffer, GradOut};
use crate::runtime::kernels::matmul::dot as vdot;
use crate::runtime::kernels::{
    bias_sqnorms_acc, default_workers, ln_bwd_fused, ln_fwd, matmul_at_b_acc, matmul_xw_t,
    matmul_xwt, par_row_blocks, par_row_blocks2, rms_bwd_fused, rms_fwd, transpose, transpose_par,
    weight_sqnorms, WorkerPool,
};
use crate::runtime::manifest::{AdamHypers, ModelEntry, ParamSpec};
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;
use crate::{N_TYPES, STATS_ORDER};

const LN_EPS: f32 = 1e-5;

/// Shape of a reference-backend model, plus its cell of the
/// normalization matrix ([`NormKind`] × [`NormPlacement`]). The default
/// cell (LayerNorm + Pre-LN) reproduces the paper's architecture and the
/// historical parameter layout bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct RefModelConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub microbatch: usize,
    pub norm: NormKind,
    pub placement: NormPlacement,
}

const fn preset(d: usize, l: usize, h: usize, t: usize) -> RefModelConfig {
    RefModelConfig {
        d_model: d,
        n_layers: l,
        n_heads: h,
        seq_len: t,
        vocab: 256,
        microbatch: 4,
        norm: NormKind::LayerNorm,
        placement: NormPlacement::PreLn,
    }
}

/// Built-in model configs, mirroring the artifact manifest's names.
pub const PRESETS: [(&str, RefModelConfig); 5] = [
    ("nano", preset(16, 2, 2, 32)),
    ("micro", preset(32, 2, 2, 48)),
    ("small", preset(48, 3, 4, 64)),
    ("sweep70", preset(24, 2, 2, 48)),
    ("sweep161", preset(48, 2, 4, 48)),
];

/// Look up a preset config by name (default matrix cell).
pub fn preset_cfg(name: &str) -> Result<RefModelConfig> {
    PRESETS.iter().find(|(n, _)| *n == name).map(|(_, c)| *c).ok_or_else(|| {
        anyhow!("unknown reference model {name:?} (have: {:?})", PRESETS.map(|(n, _)| n))
    })
}

// Per-block parameter offsets from the block base index
// (2 + per_block(cfg)*i). The first 12 slots are identical for every
// matrix cell; Peri-LN appends the two output norms at 12..16. Under
// RMSNorm the `.b` slots are kept as frozen zero dummies (never read or
// written by the kernels; init zeroes them and their gradients stay
// exactly zero) so the layout — and every offset below — is uniform
// across kinds. See `build_entry`.
const LN1_G: usize = 0;
const LN1_B: usize = 1;
const W_QKV: usize = 2;
const B_QKV: usize = 3;
const W_O: usize = 4;
const B_O: usize = 5;
const LN2_G: usize = 6;
const LN2_B: usize = 7;
const W_FC: usize = 8;
const B_FC: usize = 9;
const W_PROJ: usize = 10;
const B_PROJ: usize = 11;
// Peri-LN output norms (present only when placement == PeriLn).
const LNO1_G: usize = 12;
const LNO1_B: usize = 13;
const LNO2_G: usize = 14;
const LNO2_B: usize = 15;

/// Parameters per transformer block for a config's placement.
fn per_block(cfg: &RefModelConfig) -> usize {
    match cfg.placement {
        NormPlacement::PeriLn => 16,
        NormPlacement::PreLn | NormPlacement::PostLn => 12,
    }
}

fn spec(name: &str, shape: Vec<usize>, ltype: &str, decay: bool) -> ParamSpec {
    ParamSpec {
        name: name.to_string(),
        shape,
        dtype: "f32".to_string(),
        ltype: ltype.to_string(),
        decay,
    }
}

/// Parameter manifest for one matrix cell.
///
/// All norm sites keep a `.g`/`.b` pair regardless of [`NormKind`]:
/// under RMSNorm the `.b` tensors are frozen zero dummies (init zeroes
/// them, the RMS kernels never touch them, so their gradients — and
/// their per-example norm contribution — are exactly zero and AdamW
/// leaves them at zero). This keeps parameter indices, checkpoints and
/// the stats plumbing uniform across the whole matrix. Peri-LN appends
/// the learnable output norms `h{i}.lno1.*` / `h{i}.lno2.*`.
fn build_entry(cfg: &RefModelConfig) -> ModelEntry {
    let d = cfg.d_model;
    let mut params = vec![
        spec("wte", vec![cfg.vocab, d], "embedding", true),
        spec("wpe", vec![cfg.seq_len, d], "embedding", true),
    ];
    for i in 0..cfg.n_layers {
        params.push(spec(&format!("h{i}.ln1.g"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.ln1.b"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.attn.w_qkv"), vec![d, 3 * d], "attention", true));
        params.push(spec(&format!("h{i}.attn.b_qkv"), vec![3 * d], "attention", false));
        params.push(spec(&format!("h{i}.attn.w_o"), vec![d, d], "attention", true));
        params.push(spec(&format!("h{i}.attn.b_o"), vec![d], "attention", false));
        params.push(spec(&format!("h{i}.ln2.g"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.ln2.b"), vec![d], "layernorm", false));
        params.push(spec(&format!("h{i}.mlp.w_fc"), vec![d, 4 * d], "mlp", true));
        params.push(spec(&format!("h{i}.mlp.b_fc"), vec![4 * d], "mlp", false));
        params.push(spec(&format!("h{i}.mlp.w_proj"), vec![4 * d, d], "mlp", true));
        params.push(spec(&format!("h{i}.mlp.b_proj"), vec![d], "mlp", false));
        if cfg.placement == NormPlacement::PeriLn {
            params.push(spec(&format!("h{i}.lno1.g"), vec![d], "layernorm", false));
            params.push(spec(&format!("h{i}.lno1.b"), vec![d], "layernorm", false));
            params.push(spec(&format!("h{i}.lno2.g"), vec![d], "layernorm", false));
            params.push(spec(&format!("h{i}.lno2.b"), vec![d], "layernorm", false));
        }
    }
    params.push(spec("lnf.g", vec![d], "layernorm", false));
    params.push(spec("lnf.b", vec![d], "layernorm", false));
    params.push(spec("lm_head.w", vec![d, cfg.vocab], "lm_head", true));
    debug_assert_eq!(params.len(), 2 + per_block(cfg) * cfg.n_layers + 3);
    let n_params = params.iter().map(|p| p.numel() as u64).sum();
    ModelEntry {
        d_model: d,
        n_layers: cfg.n_layers,
        n_heads: cfg.n_heads,
        seq_len: cfg.seq_len,
        vocab: cfg.vocab,
        microbatch: cfg.microbatch,
        n_params,
        pallas_ln: false,
        adam: AdamHypers { beta1: 0.9, beta2: 0.95, eps: 1e-8, wd: 0.1 },
        params,
        artifacts: HashMap::new(),
    }
}

// ---------------------------------------------------------------------------
// Dense math helpers (row-major, f32)
// ---------------------------------------------------------------------------

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y = x @ w (+ b)` with `x: [t, k]`, `w: [k, n]`.
fn linear_fwd(x: &[f32], w: &[f32], b: Option<&[f32]>, t: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0f32; t * n];
    for ti in 0..t {
        let yrow = &mut y[ti * n..(ti + 1) * n];
        if let Some(b) = b {
            yrow.copy_from_slice(&b[..n]);
        }
        for kk in 0..k {
            let xv = x[ti * k + kk];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for j in 0..n {
                yrow[j] += xv * wrow[j];
            }
        }
    }
    y
}

/// Backward of [`linear_fwd`]: accumulates `dw += x^T dy`,
/// `db += colsum(dy)`, returns `dx = dy @ w^T`.
fn linear_bwd(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    t: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
    db: Option<&mut [f32]>,
) -> Vec<f32> {
    if let Some(db) = db {
        for ti in 0..t {
            let dyr = &dy[ti * n..(ti + 1) * n];
            for j in 0..n {
                db[j] += dyr[j];
            }
        }
    }
    for ti in 0..t {
        let dyr = &dy[ti * n..(ti + 1) * n];
        for kk in 0..k {
            let xv = x[ti * k + kk];
            if xv == 0.0 {
                continue;
            }
            let dwr = &mut dw[kk * n..(kk + 1) * n];
            for j in 0..n {
                dwr[j] += xv * dyr[j];
            }
        }
    }
    let mut dx = vec![0f32; t * k];
    for ti in 0..t {
        let dyr = &dy[ti * n..(ti + 1) * n];
        for kk in 0..k {
            dx[ti * k + kk] = dot(dyr, &w[kk * n..(kk + 1) * n]);
        }
    }
    dx
}

/// Per-row LayerNorm; returns (out, xhat, rstd).
fn layernorm_fwd(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    t: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut out = vec![0f32; t * d];
    let mut xhat = vec![0f32; t * d];
    let mut rstd = vec![0f32; t];
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let r = 1.0 / (var + LN_EPS).sqrt();
        rstd[ti] = r;
        for j in 0..d {
            let xh = (row[j] - mean) * r;
            xhat[ti * d + j] = xh;
            out[ti * d + j] = g[j] * xh + b[j];
        }
    }
    (out, xhat, rstd)
}

/// Backward of [`layernorm_fwd`]: accumulates `dg`, `db`, returns `dx`.
fn layernorm_bwd(
    dout: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    t: usize,
    d: usize,
    dg: &mut [f32],
    db: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0f32; t * d];
    for ti in 0..t {
        let mut m1 = 0f32; // mean(dxhat)
        let mut m2 = 0f32; // mean(dxhat * xhat)
        for j in 0..d {
            let dy = dout[ti * d + j];
            let xh = xhat[ti * d + j];
            dg[j] += dy * xh;
            db[j] += dy;
            let dxh = dy * g[j];
            m1 += dxh;
            m2 += dxh * xh;
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for j in 0..d {
            let dxh = dout[ti * d + j] * g[j];
            dx[ti * d + j] = rstd[ti] * (dxh - m1 - xhat[ti * d + j] * m2);
        }
    }
    dx
}

/// Per-row RMSNorm (serial oracle); returns (out, xhat, rstd). No mean
/// subtraction, no `β`: `y = γ ⊙ x·r`, `r = 1/√(mean(x²)+ε)`.
fn rmsnorm_fwd(x: &[f32], g: &[f32], t: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut out = vec![0f32; t * d];
    let mut xhat = vec![0f32; t * d];
    let mut rstd = vec![0f32; t];
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let ms = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + LN_EPS).sqrt();
        rstd[ti] = r;
        for j in 0..d {
            let xh = row[j] * r;
            xhat[ti * d + j] = xh;
            out[ti * d + j] = g[j] * xh;
        }
    }
    (out, xhat, rstd)
}

/// Backward of [`rmsnorm_fwd`] (the LayerNorm backward at `m1 = 0` with
/// no `β`): accumulates `dg`, returns `dx`.
fn rmsnorm_bwd(
    dout: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    t: usize,
    d: usize,
    dg: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0f32; t * d];
    for ti in 0..t {
        let mut m2 = 0f32; // mean(dxhat * xhat)
        for j in 0..d {
            let dy = dout[ti * d + j];
            let xh = xhat[ti * d + j];
            dg[j] += dy * xh;
            m2 += dy * g[j] * xh;
        }
        m2 /= d as f32;
        for j in 0..d {
            let dxh = dout[ti * d + j] * g[j];
            dx[ti * d + j] = rstd[ti] * (dxh - xhat[ti * d + j] * m2);
        }
    }
    dx
}

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_A: f32 = 0.044715;

fn gelu(v: f32) -> f32 {
    0.5 * v * (1.0 + (GELU_C * (v + GELU_A * v * v * v)).tanh())
}

fn gelu_grad(v: f32) -> f32 {
    let u = GELU_C * (v + GELU_A * v * v * v);
    let th = u.tanh();
    let sech2 = 1.0 - th * th;
    0.5 * (1.0 + th) + 0.5 * v * sech2 * GELU_C * (1.0 + 3.0 * GELU_A * v * v)
}

/// Serial causal multi-head attention forward for one example (the
/// oracle-path mirror of [`attention_forward`]); returns `(att_p,
/// att_out)`.
fn attn_fwd_serial(
    qkv: &[f32],
    t: usize,
    d: usize,
    heads: usize,
    scale: f32,
) -> (Vec<f32>, Vec<f32>) {
    let hd = d / heads;
    let mut att_p = vec![0f32; heads * t * t];
    let mut att_out = vec![0f32; t * d];
    for h in 0..heads {
        let q_off = h * hd;
        let k_off = d + h * hd;
        let v_off = 2 * d + h * hd;
        for ti in 0..t {
            let q_row = &qkv[ti * 3 * d + q_off..ti * 3 * d + q_off + hd];
            let mut row = vec![0f32; ti + 1];
            let mut maxv = f32::NEG_INFINITY;
            for s in 0..=ti {
                let k_row = &qkv[s * 3 * d + k_off..s * 3 * d + k_off + hd];
                let sc = scale * dot(q_row, k_row);
                row[s] = sc;
                maxv = maxv.max(sc);
            }
            let mut sum = 0f32;
            for r in row.iter_mut() {
                *r = (*r - maxv).exp();
                sum += *r;
            }
            for (s, r) in row.iter().enumerate() {
                let pv = r / sum;
                att_p[h * t * t + ti * t + s] = pv;
                let v_row = &qkv[s * 3 * d + v_off..s * 3 * d + v_off + hd];
                for j in 0..hd {
                    att_out[ti * d + q_off + j] += pv * v_row[j];
                }
            }
        }
    }
    (att_p, att_out)
}

/// Serial attention backward (scores + values) for one example (the
/// oracle-path mirror of [`attention_backward`]); returns `dqkv`.
fn attn_bwd_serial(
    qkv: &[f32],
    att_p: &[f32],
    datt_out: &[f32],
    t: usize,
    d: usize,
    heads: usize,
    scale: f32,
) -> Vec<f32> {
    let hd = d / heads;
    let mut dqkv = vec![0f32; t * 3 * d];
    for h in 0..heads {
        let q_off = h * hd;
        let k_off = d + h * hd;
        let v_off = 2 * d + h * hd;
        let ph = &att_p[h * t * t..(h + 1) * t * t];
        for ti in 0..t {
            let dout_row = &datt_out[ti * d + q_off..ti * d + q_off + hd];
            let mut dp = vec![0f32; ti + 1];
            for s in 0..=ti {
                let v_row = &qkv[s * 3 * d + v_off..s * 3 * d + v_off + hd];
                dp[s] = dot(dout_row, v_row);
                let pv = ph[ti * t + s];
                for j in 0..hd {
                    dqkv[s * 3 * d + v_off + j] += pv * dout_row[j];
                }
            }
            let dsum: f32 = (0..=ti).map(|s| dp[s] * ph[ti * t + s]).sum();
            for s in 0..=ti {
                let ds = ph[ti * t + s] * (dp[s] - dsum) * scale;
                if ds == 0.0 {
                    continue;
                }
                for j in 0..hd {
                    dqkv[ti * 3 * d + q_off + j] += ds * qkv[s * 3 * d + k_off + j];
                    dqkv[s * 3 * d + k_off + j] += ds * qkv[ti * 3 * d + q_off + j];
                }
            }
        }
    }
    dqkv
}

// ---------------------------------------------------------------------------
// Batched (fused) hot-path helpers
// ---------------------------------------------------------------------------

/// Default workspace cap in MiB; override via `NANOGNS_WS_CAP_MB` or
/// [`ReferenceBackend::with_workspace_cap`].
pub const DEFAULT_WS_CAP_MB: u64 = 1024;

fn env_ws_cap() -> u64 {
    std::env::var("NANOGNS_WS_CAP_MB")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(DEFAULT_WS_CAP_MB)
        .saturating_mul(1 << 20)
}

/// Approximate size in bytes of the fused-path activation workspace for a
/// config at batch size `bsz`. Saturating: absurd configs report
/// `u64::MAX` rather than wrapping.
pub fn workspace_bytes(cfg: &RefModelConfig, bsz: usize) -> u64 {
    let b = bsz as u64;
    let t = cfg.seq_len as u64;
    let d = cfg.d_model as u64;
    let v = cfg.vocab as u64;
    let h = cfg.n_heads as u64;
    let l = cfg.n_layers as u64;
    let m = b.saturating_mul(t);
    let md = m.saturating_mul(d);
    // per block: 5×[m,d] + [m,3d] + 2×[m,4d] activations, 2 rstd rows,
    // and the [b, h, t, t] attention weights
    let per_block = md
        .saturating_mul(16)
        .saturating_add(m.saturating_mul(2))
        .saturating_add(b.saturating_mul(h).saturating_mul(t).saturating_mul(t));
    // placement extras: Post-LN caches the block input ([m,d]); Peri-LN
    // caches the two output-norm xhat/rstd pairs (2×([m,d]+[m]))
    let per_block = match cfg.placement {
        NormPlacement::PreLn => per_block,
        NormPlacement::PostLn => per_block.saturating_add(md),
        NormPlacement::PeriLn => {
            per_block.saturating_add(md.saturating_add(m).saturating_mul(2))
        }
    };
    let f32s = md
        .saturating_mul(12) // x, dx, tmp1, tmp2, delta[m,4d], xt[4d,m]
        .saturating_add(d.saturating_mul(4).saturating_mul(d).max(d.saturating_mul(v))) // wt
        .saturating_add(m.saturating_mul(v)) // probs / dlogits
        .saturating_add(md.saturating_mul(2).saturating_add(m)) // lnf caches
        .saturating_add(b.saturating_mul(2).saturating_mul(d)) // LN per-example scratch
        .saturating_add(d.saturating_mul(4).saturating_add(b)) // bias scratch + losses
        .saturating_add(t.saturating_mul(d)) // embedding row groups
        .saturating_add(l.saturating_mul(per_block));
    f32s.saturating_mul(4)
        .saturating_add(b.saturating_mul(8)) // per-example f64 norms
        .saturating_add(v.saturating_mul(8)) // embedding slot map
}

/// Pre-allocated activations/scratch for the batched forward/backward.
/// Created once per backend (grown only if a larger batch arrives) so the
/// hot path performs no allocation.
struct BlockWs {
    ln1_xhat: Vec<f32>,
    ln1_rstd: Vec<f32>,
    ln1_out: Vec<f32>,
    qkv: Vec<f32>,
    att_p: Vec<f32>,
    att_out: Vec<f32>,
    ln2_xhat: Vec<f32>,
    ln2_rstd: Vec<f32>,
    ln2_out: Vec<f32>,
    fc_pre: Vec<f32>,
    fc_act: Vec<f32>,
    /// Block input, cached only under Post-LN (it feeds the QKV
    /// projection, whose backward needs it); empty otherwise.
    blk_in: Vec<f32>,
    /// Output-norm caches, allocated only under Peri-LN; empty otherwise.
    lno1_xhat: Vec<f32>,
    lno1_rstd: Vec<f32>,
    lno2_xhat: Vec<f32>,
    lno2_rstd: Vec<f32>,
}

struct Workspace {
    bsz: usize,
    x: Vec<f32>,
    dx: Vec<f32>,
    tmp1: Vec<f32>,
    tmp2: Vec<f32>,
    delta: Vec<f32>,
    wt: Vec<f32>,
    xt: Vec<f32>,
    probs: Vec<f32>,
    lnf_xhat: Vec<f32>,
    lnf_rstd: Vec<f32>,
    lnf_out: Vec<f32>,
    ex_scratch: Vec<f32>,
    bias_scratch: Vec<f32>,
    ex_losses: Vec<f32>,
    per_ex: Vec<f64>,
    emb_rows: Vec<f32>,
    emb_slot: Vec<usize>,
    blocks: Vec<BlockWs>,
}

impl Workspace {
    fn new(cfg: &RefModelConfig, bsz: usize) -> Self {
        let d = cfg.d_model;
        let t = cfg.seq_len;
        let v = cfg.vocab;
        let h = cfg.n_heads;
        let m = bsz * t;
        let postln = cfg.placement == NormPlacement::PostLn;
        let periln = cfg.placement == NormPlacement::PeriLn;
        let opt = |on: bool, n: usize| if on { vec![0.0; n] } else { Vec::new() };
        let blocks = (0..cfg.n_layers)
            .map(|_| BlockWs {
                ln1_xhat: vec![0.0; m * d],
                ln1_rstd: vec![0.0; m],
                ln1_out: vec![0.0; m * d],
                qkv: vec![0.0; m * 3 * d],
                att_p: vec![0.0; bsz * h * t * t],
                att_out: vec![0.0; m * d],
                ln2_xhat: vec![0.0; m * d],
                ln2_rstd: vec![0.0; m],
                ln2_out: vec![0.0; m * d],
                fc_pre: vec![0.0; m * 4 * d],
                fc_act: vec![0.0; m * 4 * d],
                blk_in: opt(postln, m * d),
                lno1_xhat: opt(periln, m * d),
                lno1_rstd: opt(periln, m),
                lno2_xhat: opt(periln, m * d),
                lno2_rstd: opt(periln, m),
            })
            .collect();
        let ws = Self {
            bsz,
            x: vec![0.0; m * d],
            dx: vec![0.0; m * d],
            tmp1: vec![0.0; m * d],
            tmp2: vec![0.0; m * d],
            delta: vec![0.0; m * 4 * d],
            wt: vec![0.0; (4 * d * d).max(d * v)],
            xt: vec![0.0; m * 4 * d],
            probs: vec![0.0; m * v],
            lnf_xhat: vec![0.0; m * d],
            lnf_rstd: vec![0.0; m],
            lnf_out: vec![0.0; m * d],
            ex_scratch: vec![0.0; bsz * 2 * d],
            bias_scratch: vec![0.0; 4 * d],
            ex_losses: vec![0.0; bsz],
            per_ex: vec![0.0; bsz],
            emb_rows: vec![0.0; t * d],
            emb_slot: vec![usize::MAX; v],
            blocks,
        };
        // The cap's estimate mirrors this constructor term-for-term; a
        // buffer added or resized on one side only is caught here before
        // it can make the OOM guard under-estimate.
        debug_assert_eq!(workspace_bytes(cfg, bsz), ws.bytes());
        ws
    }

    /// Bytes actually held by this workspace's buffers (the quantity
    /// [`workspace_bytes`] estimates; 8 bytes/slot assumed for the
    /// embedding map to match the estimate's 64-bit accounting).
    fn bytes(&self) -> u64 {
        let block_f32s: usize = self
            .blocks
            .iter()
            .map(|b| {
                b.ln1_xhat.len()
                    + b.ln1_rstd.len()
                    + b.ln1_out.len()
                    + b.qkv.len()
                    + b.att_p.len()
                    + b.att_out.len()
                    + b.ln2_xhat.len()
                    + b.ln2_rstd.len()
                    + b.ln2_out.len()
                    + b.fc_pre.len()
                    + b.fc_act.len()
                    + b.blk_in.len()
                    + b.lno1_xhat.len()
                    + b.lno1_rstd.len()
                    + b.lno2_xhat.len()
                    + b.lno2_rstd.len()
            })
            .sum();
        let f32s = self.x.len()
            + self.dx.len()
            + self.tmp1.len()
            + self.tmp2.len()
            + self.delta.len()
            + self.wt.len()
            + self.xt.len()
            + self.probs.len()
            + self.lnf_xhat.len()
            + self.lnf_rstd.len()
            + self.lnf_out.len()
            + self.ex_scratch.len()
            + self.bias_scratch.len()
            + self.ex_losses.len()
            + self.emb_rows.len()
            + block_f32s;
        (f32s as u64) * 4 + ((self.per_ex.len() + self.emb_slot.len()) as u64) * 8
    }
}

/// `dst += src`, element-wise.
fn add_into(dst: &mut [f32], src: &[f32]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a += *b;
    }
}

/// Fold per-example squared norms into a stats slot in fixed example
/// order (deterministic regardless of how `per_ex` was produced).
fn add_stats(stats: &mut [f64; N_TYPES], idx: usize, per_ex: &[f64], bsz: usize) {
    let mut s = 0f64;
    for &v in &per_ex[..bsz] {
        s += v;
    }
    stats[idx] += s;
}

fn sqnorm64(v: &[f32]) -> f64 {
    let mut s = 0f64;
    for &x in v {
        s += x as f64 * x as f64;
    }
    s
}

/// Elementwise GELU over `rows × row_len`, threaded over row blocks.
fn gelu_batched(pool: &WorkerPool, pre: &[f32], rows: usize, row_len: usize, act: &mut [f32]) {
    par_row_blocks(pool, rows, row_len, act, |r0, r1, ab| {
        let src = &pre[r0 * row_len..r1 * row_len];
        for (a, &u) in ab.iter_mut().zip(src) {
            *a = gelu(u);
        }
    });
}

/// In-place `dact *= gelu'(pre)`, threaded over row blocks.
fn gelu_bwd_batched(pool: &WorkerPool, pre: &[f32], rows: usize, row_len: usize, dact: &mut [f32]) {
    par_row_blocks(pool, rows, row_len, dact, |r0, r1, db| {
        let src = &pre[r0 * row_len..r1 * row_len];
        for (g, &u) in db.iter_mut().zip(src) {
            *g *= gelu_grad(u);
        }
    });
}

/// Batched causal multi-head attention forward, threaded over examples.
/// Writes softmax weights (`att_p`, lower triangle) and concatenated head
/// outputs (`att_out`).
fn attention_forward(
    pool: &WorkerPool,
    qkv: &[f32],
    bsz: usize,
    t: usize,
    d: usize,
    heads: usize,
    scale: f32,
    att_p: &mut [f32],
    att_out: &mut [f32],
) {
    let hd = d / heads;
    par_row_blocks2(pool, bsz, heads * t * t, att_p, t * d, att_out, |b0, b1, pch, och| {
        let mut srow = vec![0f32; t];
        for b in b0..b1 {
            let q = &qkv[b * t * 3 * d..(b + 1) * t * 3 * d];
            let pb = &mut pch[(b - b0) * heads * t * t..(b - b0 + 1) * heads * t * t];
            let ob = &mut och[(b - b0) * t * d..(b - b0 + 1) * t * d];
            ob.fill(0.0);
            for h in 0..heads {
                let q_off = h * hd;
                let k_off = d + h * hd;
                let v_off = 2 * d + h * hd;
                for ti in 0..t {
                    let q_row = &q[ti * 3 * d + q_off..ti * 3 * d + q_off + hd];
                    let mut maxv = f32::NEG_INFINITY;
                    for s in 0..=ti {
                        let k_row = &q[s * 3 * d + k_off..s * 3 * d + k_off + hd];
                        let sc = scale * vdot(q_row, k_row);
                        srow[s] = sc;
                        maxv = maxv.max(sc);
                    }
                    let mut sum = 0f32;
                    for r in srow.iter_mut().take(ti + 1) {
                        *r = (*r - maxv).exp();
                        sum += *r;
                    }
                    for s in 0..=ti {
                        let pv = srow[s] / sum;
                        pb[h * t * t + ti * t + s] = pv;
                        let v_row = &q[s * 3 * d + v_off..s * 3 * d + v_off + hd];
                        let orow = &mut ob[ti * d + q_off..ti * d + q_off + hd];
                        for j in 0..hd {
                            orow[j] += pv * v_row[j];
                        }
                    }
                }
            }
        }
    });
}

/// Batched attention backward (scores + values), threaded over examples.
/// Reads the cached `qkv`/`att_p` and the output-projection gradient
/// `datt_out`; writes `dqkv`.
fn attention_backward(
    pool: &WorkerPool,
    qkv: &[f32],
    att_p: &[f32],
    datt_out: &[f32],
    bsz: usize,
    t: usize,
    d: usize,
    heads: usize,
    scale: f32,
    dqkv: &mut [f32],
) {
    let hd = d / heads;
    par_row_blocks(pool, bsz, t * 3 * d, dqkv, |b0, b1, dqb| {
        let mut dp = vec![0f32; t];
        for b in b0..b1 {
            let q = &qkv[b * t * 3 * d..(b + 1) * t * 3 * d];
            let pb = &att_p[b * heads * t * t..(b + 1) * heads * t * t];
            let dob = &datt_out[b * t * d..(b + 1) * t * d];
            let dq = &mut dqb[(b - b0) * t * 3 * d..(b - b0 + 1) * t * 3 * d];
            dq.fill(0.0);
            for h in 0..heads {
                let q_off = h * hd;
                let k_off = d + h * hd;
                let v_off = 2 * d + h * hd;
                let ph = &pb[h * t * t..(h + 1) * t * t];
                for ti in 0..t {
                    let dout_row = &dob[ti * d + q_off..ti * d + q_off + hd];
                    for s in 0..=ti {
                        let v_row = &q[s * 3 * d + v_off..s * 3 * d + v_off + hd];
                        dp[s] = vdot(dout_row, v_row);
                        let pv = ph[ti * t + s];
                        let dvr = &mut dq[s * 3 * d + v_off..s * 3 * d + v_off + hd];
                        for j in 0..hd {
                            dvr[j] += pv * dout_row[j];
                        }
                    }
                    let mut dsum = 0f32;
                    for s in 0..=ti {
                        dsum += dp[s] * ph[ti * t + s];
                    }
                    for s in 0..=ti {
                        let ds = ph[ti * t + s] * (dp[s] - dsum) * scale;
                        if ds == 0.0 {
                            continue;
                        }
                        for j in 0..hd {
                            dq[ti * 3 * d + q_off + j] += ds * q[s * 3 * d + k_off + j];
                        }
                        for j in 0..hd {
                            dq[s * 3 * d + k_off + j] += ds * q[ti * 3 * d + q_off + j];
                        }
                    }
                }
            }
        }
    });
}

/// In-place softmax over `[bsz·t, v]` logits plus mean-token cross-entropy
/// per example, threaded over examples. Targets must be pre-validated.
fn softmax_ce(
    pool: &WorkerPool,
    targets: &[i32],
    bsz: usize,
    t: usize,
    v: usize,
    logits: &mut [f32],
    losses: &mut [f32],
) {
    par_row_blocks2(pool, bsz, t * v, logits, 1, losses, |b0, b1, lch, lossb| {
        for b in b0..b1 {
            let rows = &mut lch[(b - b0) * t * v..(b - b0 + 1) * t * v];
            let mut lsum = 0f64;
            for ti in 0..t {
                let row = &mut rows[ti * v..(ti + 1) * v];
                let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0f32;
                for p in row.iter_mut() {
                    *p = (*p - maxv).exp();
                    sum += *p;
                }
                for p in row.iter_mut() {
                    *p /= sum;
                }
                let y = targets[b * t + ti] as usize;
                lsum -= (row[y].max(1e-30) as f64).ln();
            }
            lossb[b - b0] = (lsum / t as f64) as f32;
        }
    });
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// Per-example activation caches from one forward pass.
struct BlockCache {
    ln1_xhat: Vec<f32>,
    ln1_rstd: Vec<f32>,
    ln1_out: Vec<f32>,
    /// `[t, 3d]` rows of `[q | k | v]` (post-bias).
    qkv: Vec<f32>,
    /// Softmax attention weights, `[heads, t, t]` (causal; upper zero).
    att_p: Vec<f32>,
    /// Concatenated head outputs before the output projection, `[t, d]`.
    att_out: Vec<f32>,
    ln2_xhat: Vec<f32>,
    ln2_rstd: Vec<f32>,
    ln2_out: Vec<f32>,
    fc_pre: Vec<f32>,
    fc_act: Vec<f32>,
    /// Block input (cached only under Post-LN); empty otherwise.
    blk_in: Vec<f32>,
    /// Output-norm caches (Peri-LN only); empty otherwise.
    lno1_xhat: Vec<f32>,
    lno1_rstd: Vec<f32>,
    lno2_xhat: Vec<f32>,
    lno2_rstd: Vec<f32>,
}

struct Caches {
    blocks: Vec<BlockCache>,
    lnf_xhat: Vec<f32>,
    lnf_rstd: Vec<f32>,
    lnf_out: Vec<f32>,
    /// Softmax over logits, `[t, vocab]`.
    probs: Vec<f32>,
}

/// Pure-Rust CPU implementation of [`Backend`].
pub struct ReferenceBackend {
    cfg: RefModelConfig,
    entry: ModelEntry,
    /// Per-parameter index into `STATS_ORDER`.
    ltype_idx: Vec<usize>,
    /// Persistent worker pool for the fused hot path: threads spawn once
    /// here and park between parallel regions (results are worker-count
    /// invariant; see `runtime::kernels::threads`).
    pool: WorkerPool,
    /// Workspace size cap in bytes (`None` = uncapped).
    ws_cap: Option<u64>,
    /// Lazily built, reused activation workspace.
    ws: Mutex<Option<Workspace>>,
}

impl ReferenceBackend {
    pub fn new(cfg: RefModelConfig) -> Result<Self> {
        Self::with_options(cfg, default_workers(), Some(env_ws_cap()))
    }

    /// Backend with an explicit worker-thread count (tests use 1 vs N to
    /// assert the determinism contract).
    pub fn with_threads(cfg: RefModelConfig, workers: usize) -> Result<Self> {
        Self::with_options(cfg, workers, Some(env_ws_cap()))
    }

    /// Backend with an explicit workspace cap in bytes (`None` disables
    /// the cap entirely).
    pub fn with_workspace_cap(cfg: RefModelConfig, cap: Option<u64>) -> Result<Self> {
        Self::with_options(cfg, default_workers(), cap)
    }

    pub fn with_options(
        cfg: RefModelConfig,
        workers: usize,
        ws_cap: Option<u64>,
    ) -> Result<Self> {
        ensure!(cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0, "d_model must divide by heads");
        ensure!(
            cfg.n_layers > 0 && cfg.seq_len > 0 && cfg.vocab > 1 && cfg.microbatch > 0,
            "degenerate reference model config {cfg:?}"
        );
        if let Some(cap) = ws_cap {
            let need = workspace_bytes(&cfg, cfg.microbatch);
            ensure!(
                need <= cap,
                "reference workspace for {cfg:?} needs ~{} MiB, over the {} MiB cap \
                 (shrink microbatch/seq_len, raise NANOGNS_WS_CAP_MB, or use \
                 ReferenceBackend::with_workspace_cap)",
                need >> 20,
                cap >> 20
            );
        }
        let entry = build_entry(&cfg);
        let ltype_idx = entry
            .params
            .iter()
            .map(|p| {
                STATS_ORDER
                    .iter()
                    .position(|t| *t == p.ltype)
                    .ok_or_else(|| anyhow!("unknown ltype {}", p.ltype))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            cfg,
            entry,
            ltype_idx,
            pool: WorkerPool::new(workers.max(1)),
            ws_cap,
            ws: Mutex::new(None),
        })
    }

    pub fn from_preset(name: &str) -> Result<Self> {
        Self::new(preset_cfg(name)?)
    }

    pub fn config(&self) -> &RefModelConfig {
        &self.cfg
    }

    fn block_base(&self, i: usize) -> usize {
        2 + per_block(&self.cfg) * i
    }

    fn lnf_g_idx(&self) -> usize {
        2 + per_block(&self.cfg) * self.cfg.n_layers
    }

    /// Forward through one norm site (γ at `ps[g]`, β — LayerNorm only —
    /// at `ps[g + 1]`), dispatching on the config's [`NormKind`].
    fn norm_fwd(
        &self,
        ps: &[&[f32]],
        g: usize,
        x: &[f32],
        rows: usize,
        out: &mut [f32],
        xhat: &mut [f32],
        rstd: &mut [f32],
    ) {
        let d = self.cfg.d_model;
        match self.cfg.norm {
            NormKind::LayerNorm => ln_fwd(x, ps[g], ps[g + 1], rows, d, LN_EPS, out, xhat, rstd),
            NormKind::RmsNorm => rms_fwd(x, ps[g], rows, d, LN_EPS, out, xhat, rstd),
        }
    }

    /// Fused backward through one norm site: writes `dx`, accumulates the
    /// site's parameter gradients into `grads`, and (with stats on) folds
    /// the per-example `||dγ_b||²(+||dβ_b||²)` norms into `stats` — the
    /// §3 simultaneous emission, for whichever kind this config runs.
    fn norm_bwd(
        &self,
        ps: &[&[f32]],
        g: usize,
        dout: &[f32],
        xhat: &[f32],
        rstd: &[f32],
        bsz: usize,
        t: usize,
        dx: &mut [f32],
        ex_scratch: &mut [f32],
        grads: &mut [Vec<f32>],
        per_ex: &mut [f64],
        stats: &mut [f64; N_TYPES],
        with_stats: bool,
    ) {
        let d = self.cfg.d_model;
        let nw = &self.pool;
        match self.cfg.norm {
            NormKind::LayerNorm => {
                let (dg, db) = two_mut(grads, g, g + 1);
                ln_bwd_fused(
                    nw,
                    dout,
                    xhat,
                    rstd,
                    ps[g],
                    bsz,
                    t,
                    d,
                    dx,
                    ex_scratch,
                    dg,
                    db,
                    if with_stats { Some(&mut per_ex[..]) } else { None },
                );
            }
            NormKind::RmsNorm => {
                rms_bwd_fused(
                    nw,
                    dout,
                    xhat,
                    rstd,
                    ps[g],
                    bsz,
                    t,
                    d,
                    dx,
                    ex_scratch,
                    &mut grads[g],
                    if with_stats { Some(&mut per_ex[..]) } else { None },
                );
            }
        }
        if with_stats {
            add_stats(stats, self.ltype_idx[g], per_ex, bsz);
        }
    }

    /// Serial (oracle-path) forward through one norm site.
    fn norm_fwd_serial(
        &self,
        ps: &[&[f32]],
        g: usize,
        x: &[f32],
        t: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let d = self.cfg.d_model;
        match self.cfg.norm {
            NormKind::LayerNorm => layernorm_fwd(x, ps[g], ps[g + 1], t, d),
            NormKind::RmsNorm => rmsnorm_fwd(x, ps[g], t, d),
        }
    }

    /// Serial (oracle-path) backward through one norm site; accumulates
    /// the site's gradients into `eg` and returns `dx`.
    fn norm_bwd_serial(
        &self,
        ps: &[&[f32]],
        g: usize,
        dout: &[f32],
        xhat: &[f32],
        rstd: &[f32],
        t: usize,
        eg: &mut [Vec<f32>],
    ) -> Vec<f32> {
        let d = self.cfg.d_model;
        match self.cfg.norm {
            NormKind::LayerNorm => {
                let (dg, db) = two_mut(eg, g, g + 1);
                layernorm_bwd(dout, xhat, rstd, ps[g], t, d, dg, db)
            }
            NormKind::RmsNorm => rmsnorm_bwd(dout, xhat, rstd, ps[g], t, d, &mut eg[g]),
        }
    }

    fn host_params<'a>(&self, params: &'a [Buffer]) -> Result<Vec<&'a [f32]>> {
        ensure!(
            params.len() == self.entry.params.len(),
            "got {} param tensors, model has {}",
            params.len(),
            self.entry.params.len()
        );
        params.iter().map(|b| Ok(b.as_host()?.data.as_slice())).collect()
    }

    /// Forward pass for one example; returns (mean token loss, caches).
    fn example_forward(
        &self,
        ps: &[&[f32]],
        ids: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Caches)> {
        let d = self.cfg.d_model;
        let t = ids.len();
        let v = self.cfg.vocab;
        let heads = self.cfg.n_heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();

        // Embedding: wte[id] + wpe[pos].
        let mut x = vec![0f32; t * d];
        for ti in 0..t {
            let id = ids[ti] as usize;
            ensure!(id < v, "token id {id} out of vocab {v}");
            for j in 0..d {
                x[ti * d + j] = ps[0][id * d + j] + ps[1][ti * d + j];
            }
        }

        let mut blocks = Vec::with_capacity(self.cfg.n_layers);
        for i in 0..self.cfg.n_layers {
            let base = self.block_base(i);
            let cache = match self.cfg.placement {
                // x += Attn(Norm1(x)); x += MLP(Norm2(x))
                NormPlacement::PreLn => {
                    let (ln1_out, ln1_xhat, ln1_rstd) =
                        self.norm_fwd_serial(ps, base + LN1_G, &x, t);
                    let qkv = linear_fwd(
                        &ln1_out,
                        ps[base + W_QKV],
                        Some(ps[base + B_QKV]),
                        t,
                        d,
                        3 * d,
                    );
                    let (att_p, att_out) = attn_fwd_serial(&qkv, t, d, heads, scale);
                    let o = linear_fwd(&att_out, ps[base + W_O], Some(ps[base + B_O]), t, d, d);
                    for (xv, ov) in x.iter_mut().zip(&o) {
                        *xv += *ov;
                    }
                    let (ln2_out, ln2_xhat, ln2_rstd) =
                        self.norm_fwd_serial(ps, base + LN2_G, &x, t);
                    let fc_pre =
                        linear_fwd(&ln2_out, ps[base + W_FC], Some(ps[base + B_FC]), t, d, 4 * d);
                    let fc_act: Vec<f32> = fc_pre.iter().map(|&u| gelu(u)).collect();
                    let p = linear_fwd(
                        &fc_act,
                        ps[base + W_PROJ],
                        Some(ps[base + B_PROJ]),
                        t,
                        4 * d,
                        d,
                    );
                    for (xv, pv) in x.iter_mut().zip(&p) {
                        *xv += *pv;
                    }
                    BlockCache {
                        ln1_xhat,
                        ln1_rstd,
                        ln1_out,
                        qkv,
                        att_p,
                        att_out,
                        ln2_xhat,
                        ln2_rstd,
                        ln2_out,
                        fc_pre,
                        fc_act,
                        blk_in: Vec::new(),
                        lno1_xhat: Vec::new(),
                        lno1_rstd: Vec::new(),
                        lno2_xhat: Vec::new(),
                        lno2_rstd: Vec::new(),
                    }
                }
                // x = Norm1(x + Attn(x)); x = Norm2(x + MLP(x))
                NormPlacement::PostLn => {
                    let blk_in = x.clone();
                    let qkv = linear_fwd(
                        &blk_in,
                        ps[base + W_QKV],
                        Some(ps[base + B_QKV]),
                        t,
                        d,
                        3 * d,
                    );
                    let (att_p, att_out) = attn_fwd_serial(&qkv, t, d, heads, scale);
                    let o = linear_fwd(&att_out, ps[base + W_O], Some(ps[base + B_O]), t, d, d);
                    for (xv, ov) in x.iter_mut().zip(&o) {
                        *xv += *ov;
                    }
                    // x = s1 → norm1 replaces the stream; ln1_out doubles
                    // as the MLP input x_mid.
                    let (ln1_out, ln1_xhat, ln1_rstd) =
                        self.norm_fwd_serial(ps, base + LN1_G, &x, t);
                    x.copy_from_slice(&ln1_out);
                    let fc_pre =
                        linear_fwd(&ln1_out, ps[base + W_FC], Some(ps[base + B_FC]), t, d, 4 * d);
                    let fc_act: Vec<f32> = fc_pre.iter().map(|&u| gelu(u)).collect();
                    let p = linear_fwd(
                        &fc_act,
                        ps[base + W_PROJ],
                        Some(ps[base + B_PROJ]),
                        t,
                        4 * d,
                        d,
                    );
                    for (xv, pv) in x.iter_mut().zip(&p) {
                        *xv += *pv;
                    }
                    // x = s2 → norm2 replaces the stream again.
                    let (ln2_out, ln2_xhat, ln2_rstd) =
                        self.norm_fwd_serial(ps, base + LN2_G, &x, t);
                    x.copy_from_slice(&ln2_out);
                    BlockCache {
                        ln1_xhat,
                        ln1_rstd,
                        ln1_out,
                        qkv,
                        att_p,
                        att_out,
                        ln2_xhat,
                        ln2_rstd,
                        ln2_out,
                        fc_pre,
                        fc_act,
                        blk_in,
                        lno1_xhat: Vec::new(),
                        lno1_rstd: Vec::new(),
                        lno2_xhat: Vec::new(),
                        lno2_rstd: Vec::new(),
                    }
                }
                // x += NormO1(Attn(Norm1(x))); x += NormO2(MLP(Norm2(x)))
                NormPlacement::PeriLn => {
                    let (ln1_out, ln1_xhat, ln1_rstd) =
                        self.norm_fwd_serial(ps, base + LN1_G, &x, t);
                    let qkv = linear_fwd(
                        &ln1_out,
                        ps[base + W_QKV],
                        Some(ps[base + B_QKV]),
                        t,
                        d,
                        3 * d,
                    );
                    let (att_p, att_out) = attn_fwd_serial(&qkv, t, d, heads, scale);
                    let o = linear_fwd(&att_out, ps[base + W_O], Some(ps[base + B_O]), t, d, d);
                    let (o_n, lno1_xhat, lno1_rstd) =
                        self.norm_fwd_serial(ps, base + LNO1_G, &o, t);
                    for (xv, ov) in x.iter_mut().zip(&o_n) {
                        *xv += *ov;
                    }
                    let (ln2_out, ln2_xhat, ln2_rstd) =
                        self.norm_fwd_serial(ps, base + LN2_G, &x, t);
                    let fc_pre =
                        linear_fwd(&ln2_out, ps[base + W_FC], Some(ps[base + B_FC]), t, d, 4 * d);
                    let fc_act: Vec<f32> = fc_pre.iter().map(|&u| gelu(u)).collect();
                    let p = linear_fwd(
                        &fc_act,
                        ps[base + W_PROJ],
                        Some(ps[base + B_PROJ]),
                        t,
                        4 * d,
                        d,
                    );
                    let (p_n, lno2_xhat, lno2_rstd) =
                        self.norm_fwd_serial(ps, base + LNO2_G, &p, t);
                    for (xv, pv) in x.iter_mut().zip(&p_n) {
                        *xv += *pv;
                    }
                    BlockCache {
                        ln1_xhat,
                        ln1_rstd,
                        ln1_out,
                        qkv,
                        att_p,
                        att_out,
                        ln2_xhat,
                        ln2_rstd,
                        ln2_out,
                        fc_pre,
                        fc_act,
                        blk_in: Vec::new(),
                        lno1_xhat,
                        lno1_rstd,
                        lno2_xhat,
                        lno2_rstd,
                    }
                }
            };
            blocks.push(cache);
        }

        let gi = self.lnf_g_idx();
        let (lnf_out, lnf_xhat, lnf_rstd) = self.norm_fwd_serial(ps, gi, &x, t);
        let logits = linear_fwd(&lnf_out, ps[gi + 2], None, t, d, v);

        // Softmax cross-entropy, mean over tokens.
        let mut probs = vec![0f32; t * v];
        let mut loss = 0f64;
        for ti in 0..t {
            let row = &logits[ti * v..(ti + 1) * v];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for j in 0..v {
                let e = (row[j] - maxv).exp();
                probs[ti * v + j] = e;
                sum += e;
            }
            for j in 0..v {
                probs[ti * v + j] /= sum;
            }
            let y = targets[ti] as usize;
            ensure!(y < v, "target id {y} out of vocab {v}");
            loss -= (probs[ti * v + y].max(1e-30) as f64).ln();
        }
        let loss = (loss / t as f64) as f32;

        Ok((loss, Caches { blocks, lnf_xhat, lnf_rstd, lnf_out, probs }))
    }

    /// Backward pass for one example; accumulates `dL_b/dw` into `eg`.
    fn example_backward(
        &self,
        ps: &[&[f32]],
        ids: &[i32],
        targets: &[i32],
        caches: &Caches,
        eg: &mut [Vec<f32>],
    ) {
        let d = self.cfg.d_model;
        let t = ids.len();
        let v = self.cfg.vocab;
        let heads = self.cfg.n_heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let gi = self.lnf_g_idx();

        // dlogits = (softmax - onehot) / t.
        let mut dlogits = vec![0f32; t * v];
        let inv_t = 1.0 / t as f32;
        for ti in 0..t {
            for j in 0..v {
                dlogits[ti * v + j] = caches.probs[ti * v + j] * inv_t;
            }
            dlogits[ti * v + targets[ti] as usize] -= inv_t;
        }

        // lm_head (no bias).
        let dlnf_out =
            linear_bwd(&caches.lnf_out, ps[gi + 2], &dlogits, t, d, v, &mut eg[gi + 2], None);

        // Final norm.
        let mut dx = self.norm_bwd_serial(
            ps,
            gi,
            &dlnf_out,
            &caches.lnf_xhat,
            &caches.lnf_rstd,
            t,
            eg,
        );

        for i in (0..self.cfg.n_layers).rev() {
            let base = self.block_base(i);
            let c = &caches.blocks[i];
            match self.cfg.placement {
                NormPlacement::PreLn => {
                    // MLP branch: x_out = x_mid + proj(gelu(fc(ln2(x_mid)))).
                    let dfc_act = {
                        let (dw, db) = two_mut(eg, base + W_PROJ, base + B_PROJ);
                        linear_bwd(&c.fc_act, ps[base + W_PROJ], &dx, t, 4 * d, d, dw, Some(db))
                    };
                    let mut dfc_pre = dfc_act;
                    for (g, &u) in dfc_pre.iter_mut().zip(&c.fc_pre) {
                        *g *= gelu_grad(u);
                    }
                    let dln2_out = {
                        let (dw, db) = two_mut(eg, base + W_FC, base + B_FC);
                        linear_bwd(&c.ln2_out, ps[base + W_FC], &dfc_pre, t, d, 4 * d, dw, Some(db))
                    };
                    let dx_ln2 = self.norm_bwd_serial(
                        ps,
                        base + LN2_G,
                        &dln2_out,
                        &c.ln2_xhat,
                        &c.ln2_rstd,
                        t,
                        eg,
                    );
                    for (a, b) in dx.iter_mut().zip(&dx_ln2) {
                        *a += *b;
                    }

                    // Attention branch: x_mid = x_in + w_o(att(ln1(x_in))).
                    let datt_out = {
                        let (dw, db) = two_mut(eg, base + W_O, base + B_O);
                        linear_bwd(&c.att_out, ps[base + W_O], &dx, t, d, d, dw, Some(db))
                    };
                    let dqkv = attn_bwd_serial(&c.qkv, &c.att_p, &datt_out, t, d, heads, scale);
                    let dln1_out = {
                        let (dw, db) = two_mut(eg, base + W_QKV, base + B_QKV);
                        linear_bwd(&c.ln1_out, ps[base + W_QKV], &dqkv, t, d, 3 * d, dw, Some(db))
                    };
                    let dx_ln1 = self.norm_bwd_serial(
                        ps,
                        base + LN1_G,
                        &dln1_out,
                        &c.ln1_xhat,
                        &c.ln1_rstd,
                        t,
                        eg,
                    );
                    for (a, b) in dx.iter_mut().zip(&dx_ln1) {
                        *a += *b;
                    }
                }
                NormPlacement::PostLn => {
                    // x_out = norm2(s2): the norm backward REPLACES the
                    // stream gradient (no residual passthrough here).
                    let ds2 = self.norm_bwd_serial(
                        ps,
                        base + LN2_G,
                        &dx,
                        &c.ln2_xhat,
                        &c.ln2_rstd,
                        t,
                        eg,
                    );
                    // s2 = x_mid + proj(gelu(fc(x_mid))), x_mid = ln1_out.
                    let dfc_act = {
                        let (dw, db) = two_mut(eg, base + W_PROJ, base + B_PROJ);
                        linear_bwd(&c.fc_act, ps[base + W_PROJ], &ds2, t, 4 * d, d, dw, Some(db))
                    };
                    let mut dfc_pre = dfc_act;
                    for (g, &u) in dfc_pre.iter_mut().zip(&c.fc_pre) {
                        *g *= gelu_grad(u);
                    }
                    let mut dx_mid = {
                        let (dw, db) = two_mut(eg, base + W_FC, base + B_FC);
                        linear_bwd(&c.ln1_out, ps[base + W_FC], &dfc_pre, t, d, 4 * d, dw, Some(db))
                    };
                    for (a, b) in dx_mid.iter_mut().zip(&ds2) {
                        *a += *b;
                    }
                    // x_mid = norm1(s1): replace again.
                    let ds1 = self.norm_bwd_serial(
                        ps,
                        base + LN1_G,
                        &dx_mid,
                        &c.ln1_xhat,
                        &c.ln1_rstd,
                        t,
                        eg,
                    );
                    // s1 = x_in + w_o(att(qkv(x_in))).
                    let datt_out = {
                        let (dw, db) = two_mut(eg, base + W_O, base + B_O);
                        linear_bwd(&c.att_out, ps[base + W_O], &ds1, t, d, d, dw, Some(db))
                    };
                    let dqkv = attn_bwd_serial(&c.qkv, &c.att_p, &datt_out, t, d, heads, scale);
                    let mut dx_in = {
                        let (dw, db) = two_mut(eg, base + W_QKV, base + B_QKV);
                        linear_bwd(&c.blk_in, ps[base + W_QKV], &dqkv, t, d, 3 * d, dw, Some(db))
                    };
                    for (a, b) in dx_in.iter_mut().zip(&ds1) {
                        *a += *b;
                    }
                    dx = dx_in;
                }
                NormPlacement::PeriLn => {
                    // x_out = x_mid + lno2(proj_out): residual carries dx.
                    let dproj = self.norm_bwd_serial(
                        ps,
                        base + LNO2_G,
                        &dx,
                        &c.lno2_xhat,
                        &c.lno2_rstd,
                        t,
                        eg,
                    );
                    let dfc_act = {
                        let (dw, db) = two_mut(eg, base + W_PROJ, base + B_PROJ);
                        linear_bwd(&c.fc_act, ps[base + W_PROJ], &dproj, t, 4 * d, d, dw, Some(db))
                    };
                    let mut dfc_pre = dfc_act;
                    for (g, &u) in dfc_pre.iter_mut().zip(&c.fc_pre) {
                        *g *= gelu_grad(u);
                    }
                    let dln2_out = {
                        let (dw, db) = two_mut(eg, base + W_FC, base + B_FC);
                        linear_bwd(&c.ln2_out, ps[base + W_FC], &dfc_pre, t, d, 4 * d, dw, Some(db))
                    };
                    let dx_ln2 = self.norm_bwd_serial(
                        ps,
                        base + LN2_G,
                        &dln2_out,
                        &c.ln2_xhat,
                        &c.ln2_rstd,
                        t,
                        eg,
                    );
                    for (a, b) in dx.iter_mut().zip(&dx_ln2) {
                        *a += *b;
                    }

                    // x_mid = x_in + lno1(w_o(att(qkv(ln1(x_in))))).
                    let do_out = self.norm_bwd_serial(
                        ps,
                        base + LNO1_G,
                        &dx,
                        &c.lno1_xhat,
                        &c.lno1_rstd,
                        t,
                        eg,
                    );
                    let datt_out = {
                        let (dw, db) = two_mut(eg, base + W_O, base + B_O);
                        linear_bwd(&c.att_out, ps[base + W_O], &do_out, t, d, d, dw, Some(db))
                    };
                    let dqkv = attn_bwd_serial(&c.qkv, &c.att_p, &datt_out, t, d, heads, scale);
                    let dln1_out = {
                        let (dw, db) = two_mut(eg, base + W_QKV, base + B_QKV);
                        linear_bwd(&c.ln1_out, ps[base + W_QKV], &dqkv, t, d, 3 * d, dw, Some(db))
                    };
                    let dx_ln1 = self.norm_bwd_serial(
                        ps,
                        base + LN1_G,
                        &dln1_out,
                        &c.ln1_xhat,
                        &c.ln1_rstd,
                        t,
                        eg,
                    );
                    for (a, b) in dx.iter_mut().zip(&dx_ln1) {
                        *a += *b;
                    }
                }
            }
        }

        // Embedding.
        for ti in 0..t {
            let id = ids[ti] as usize;
            for j in 0..d {
                eg[0][id * d + j] += dx[ti * d + j];
                eg[1][ti * d + j] += dx[ti * d + j];
            }
        }
    }

    fn check_batch(&self, batch: &Batch) -> Result<()> {
        ensure!(
            batch.seq_len == self.cfg.seq_len && batch.batch > 0,
            "batch shape ({}, {}) incompatible with model seq_len {}",
            batch.batch,
            batch.seq_len,
            self.cfg.seq_len
        );
        let n = batch.batch * batch.seq_len;
        ensure!(
            batch.inputs.len() == n && batch.targets.len() == n,
            "batch declares {} tokens but holds {} inputs / {} targets",
            n,
            batch.inputs.len(),
            batch.targets.len()
        );
        let v = self.cfg.vocab;
        for (&id, &y) in batch.inputs.iter().zip(&batch.targets) {
            ensure!((id as usize) < v, "token id {id} out of vocab {v}");
            ensure!((y as usize) < v, "target id {y} out of vocab {v}");
        }
        Ok(())
    }

    /// Reuse (or grow) the pre-allocated workspace for a batch size,
    /// enforcing the memory cap with a clear error instead of OOMing.
    fn ensure_workspace<'a>(
        &self,
        slot: &'a mut Option<Workspace>,
        bsz: usize,
    ) -> Result<&'a mut Workspace> {
        let rebuild = match slot.as_ref() {
            Some(w) => w.bsz < bsz,
            None => true,
        };
        if rebuild {
            let alloc_bsz = bsz.max(self.cfg.microbatch);
            if let Some(cap) = self.ws_cap {
                let need = workspace_bytes(&self.cfg, alloc_bsz);
                ensure!(
                    need <= cap,
                    "reference workspace for batch {alloc_bsz} needs ~{} MiB, over the {} MiB \
                     cap (raise NANOGNS_WS_CAP_MB or use ReferenceBackend::with_workspace_cap)",
                    need >> 20,
                    cap >> 20
                );
            }
            *slot = Some(Workspace::new(&self.cfg, alloc_bsz));
        }
        Ok(slot.as_mut().unwrap())
    }

    /// Batched forward over the whole microbatch; fills the workspace
    /// caches (for the backward) and returns the mean loss.
    fn batched_forward(&self, ps: &[&[f32]], batch: &Batch, ws: &mut Workspace) -> Result<f32> {
        let d = self.cfg.d_model;
        let t = self.cfg.seq_len;
        let v = self.cfg.vocab;
        let heads = self.cfg.n_heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let bsz = batch.batch;
        let m = bsz * t;
        let nw = &self.pool;
        let gi = self.lnf_g_idx();

        let Workspace {
            x,
            tmp1,
            delta,
            wt,
            probs,
            lnf_xhat,
            lnf_rstd,
            lnf_out,
            ex_losses,
            blocks,
            ..
        } = ws;

        // Embedding: wte[id] + wpe[pos], flattened to [B·T, d].
        for r in 0..m {
            let id = batch.inputs[r] as usize;
            let ti = r % t;
            let row = &mut x[r * d..(r + 1) * d];
            let wte = &ps[0][id * d..(id + 1) * d];
            let wpe = &ps[1][ti * d..(ti + 1) * d];
            for j in 0..d {
                row[j] = wte[j] + wpe[j];
            }
        }

        for (i, blk) in blocks.iter_mut().enumerate() {
            let base = self.block_base(i);
            match self.cfg.placement {
                // x += Attn(Norm1(x)); x += MLP(Norm2(x))
                NormPlacement::PreLn => {
                    self.norm_fwd(
                        ps,
                        base + LN1_G,
                        x,
                        m,
                        &mut blk.ln1_out,
                        &mut blk.ln1_xhat,
                        &mut blk.ln1_rstd,
                    );
                    transpose(ps[base + W_QKV], d, 3 * d, wt);
                    matmul_xwt(
                        nw,
                        &blk.ln1_out,
                        wt,
                        Some(ps[base + B_QKV]),
                        m,
                        d,
                        3 * d,
                        &mut blk.qkv,
                    );
                    attention_forward(
                        nw,
                        &blk.qkv,
                        bsz,
                        t,
                        d,
                        heads,
                        scale,
                        &mut blk.att_p,
                        &mut blk.att_out,
                    );
                    transpose(ps[base + W_O], d, d, wt);
                    matmul_xwt(nw, &blk.att_out, wt, Some(ps[base + B_O]), m, d, d, delta);
                    add_into(&mut x[..m * d], &delta[..m * d]);

                    self.norm_fwd(
                        ps,
                        base + LN2_G,
                        x,
                        m,
                        &mut blk.ln2_out,
                        &mut blk.ln2_xhat,
                        &mut blk.ln2_rstd,
                    );
                    transpose(ps[base + W_FC], d, 4 * d, wt);
                    matmul_xwt(
                        nw,
                        &blk.ln2_out,
                        wt,
                        Some(ps[base + B_FC]),
                        m,
                        d,
                        4 * d,
                        &mut blk.fc_pre,
                    );
                    gelu_batched(nw, &blk.fc_pre, m, 4 * d, &mut blk.fc_act);
                    transpose(ps[base + W_PROJ], 4 * d, d, wt);
                    matmul_xwt(nw, &blk.fc_act, wt, Some(ps[base + B_PROJ]), m, 4 * d, d, delta);
                    add_into(&mut x[..m * d], &delta[..m * d]);
                }
                // x = Norm1(x + Attn(x)); x = Norm2(x + MLP(x))
                NormPlacement::PostLn => {
                    blk.blk_in[..m * d].copy_from_slice(&x[..m * d]);
                    transpose(ps[base + W_QKV], d, 3 * d, wt);
                    matmul_xwt(
                        nw,
                        &blk.blk_in,
                        wt,
                        Some(ps[base + B_QKV]),
                        m,
                        d,
                        3 * d,
                        &mut blk.qkv,
                    );
                    attention_forward(
                        nw,
                        &blk.qkv,
                        bsz,
                        t,
                        d,
                        heads,
                        scale,
                        &mut blk.att_p,
                        &mut blk.att_out,
                    );
                    transpose(ps[base + W_O], d, d, wt);
                    matmul_xwt(nw, &blk.att_out, wt, Some(ps[base + B_O]), m, d, d, delta);
                    add_into(&mut x[..m * d], &delta[..m * d]);
                    // x = s1 → norm1 replaces the stream (ln1_out doubles
                    // as the MLP input x_mid).
                    self.norm_fwd(
                        ps,
                        base + LN1_G,
                        x,
                        m,
                        &mut blk.ln1_out,
                        &mut blk.ln1_xhat,
                        &mut blk.ln1_rstd,
                    );
                    x[..m * d].copy_from_slice(&blk.ln1_out[..m * d]);

                    transpose(ps[base + W_FC], d, 4 * d, wt);
                    matmul_xwt(
                        nw,
                        &blk.ln1_out,
                        wt,
                        Some(ps[base + B_FC]),
                        m,
                        d,
                        4 * d,
                        &mut blk.fc_pre,
                    );
                    gelu_batched(nw, &blk.fc_pre, m, 4 * d, &mut blk.fc_act);
                    transpose(ps[base + W_PROJ], 4 * d, d, wt);
                    matmul_xwt(nw, &blk.fc_act, wt, Some(ps[base + B_PROJ]), m, 4 * d, d, delta);
                    add_into(&mut x[..m * d], &delta[..m * d]);
                    // x = s2 → norm2 replaces the stream again.
                    self.norm_fwd(
                        ps,
                        base + LN2_G,
                        x,
                        m,
                        &mut blk.ln2_out,
                        &mut blk.ln2_xhat,
                        &mut blk.ln2_rstd,
                    );
                    x[..m * d].copy_from_slice(&blk.ln2_out[..m * d]);
                }
                // x += NormO1(Attn(Norm1(x))); x += NormO2(MLP(Norm2(x)))
                NormPlacement::PeriLn => {
                    self.norm_fwd(
                        ps,
                        base + LN1_G,
                        x,
                        m,
                        &mut blk.ln1_out,
                        &mut blk.ln1_xhat,
                        &mut blk.ln1_rstd,
                    );
                    transpose(ps[base + W_QKV], d, 3 * d, wt);
                    matmul_xwt(
                        nw,
                        &blk.ln1_out,
                        wt,
                        Some(ps[base + B_QKV]),
                        m,
                        d,
                        3 * d,
                        &mut blk.qkv,
                    );
                    attention_forward(
                        nw,
                        &blk.qkv,
                        bsz,
                        t,
                        d,
                        heads,
                        scale,
                        &mut blk.att_p,
                        &mut blk.att_out,
                    );
                    transpose(ps[base + W_O], d, d, wt);
                    matmul_xwt(nw, &blk.att_out, wt, Some(ps[base + B_O]), m, d, d, delta);
                    // delta = pre-norm attention output o → lno1 → tmp1.
                    self.norm_fwd(
                        ps,
                        base + LNO1_G,
                        delta,
                        m,
                        tmp1,
                        &mut blk.lno1_xhat,
                        &mut blk.lno1_rstd,
                    );
                    add_into(&mut x[..m * d], &tmp1[..m * d]);

                    self.norm_fwd(
                        ps,
                        base + LN2_G,
                        x,
                        m,
                        &mut blk.ln2_out,
                        &mut blk.ln2_xhat,
                        &mut blk.ln2_rstd,
                    );
                    transpose(ps[base + W_FC], d, 4 * d, wt);
                    matmul_xwt(
                        nw,
                        &blk.ln2_out,
                        wt,
                        Some(ps[base + B_FC]),
                        m,
                        d,
                        4 * d,
                        &mut blk.fc_pre,
                    );
                    gelu_batched(nw, &blk.fc_pre, m, 4 * d, &mut blk.fc_act);
                    transpose(ps[base + W_PROJ], 4 * d, d, wt);
                    matmul_xwt(nw, &blk.fc_act, wt, Some(ps[base + B_PROJ]), m, 4 * d, d, delta);
                    // delta = pre-norm MLP output p → lno2 → tmp1.
                    self.norm_fwd(
                        ps,
                        base + LNO2_G,
                        delta,
                        m,
                        tmp1,
                        &mut blk.lno2_xhat,
                        &mut blk.lno2_rstd,
                    );
                    add_into(&mut x[..m * d], &tmp1[..m * d]);
                }
            }
        }

        self.norm_fwd(ps, gi, x, m, lnf_out, lnf_xhat, lnf_rstd);
        transpose(ps[gi + 2], d, v, wt);
        matmul_xwt(nw, lnf_out, wt, None, m, d, v, probs);
        softmax_ce(nw, &batch.targets, bsz, t, v, probs, ex_losses);

        let mut loss = 0f64;
        for &l in &ex_losses[..bsz] {
            loss += l as f64;
        }
        Ok((loss / bsz as f64) as f32)
    }

    /// Batched backward with fused per-example norm emission (the paper's
    /// "simultaneous" method). Consumes the forward caches in `ws`;
    /// accumulates gradients of the mean-microbatch loss into `grads` and
    /// `sum_b ||w'_b||²` into `stats` per layer type. With
    /// `with_stats = false` every norm contraction and stats reduction is
    /// skipped while the gradient accumulation order stays bitwise
    /// identical — the norms-off backward that measures the paper's
    /// near-zero-overhead claim.
    fn batched_backward(
        &self,
        ps: &[&[f32]],
        batch: &Batch,
        ws: &mut Workspace,
        grads: &mut [Vec<f32>],
        stats: &mut [f64; N_TYPES],
        with_stats: bool,
    ) {
        let d = self.cfg.d_model;
        let t = self.cfg.seq_len;
        let v = self.cfg.vocab;
        let heads = self.cfg.n_heads;
        let hd = d / heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let bsz = batch.batch;
        let m = bsz * t;
        let nw = &self.pool;
        let gi = self.lnf_g_idx();

        let Workspace {
            dx,
            tmp1,
            tmp2,
            delta,
            xt,
            probs,
            lnf_xhat,
            lnf_rstd,
            lnf_out,
            ex_scratch,
            bias_scratch,
            per_ex,
            emb_rows,
            emb_slot,
            blocks,
            ..
        } = ws;

        // dlogits = (softmax - onehot) / (T · B), in place over `probs`.
        // The 1/B folds the per-example → mean-microbatch scaling into the
        // whole backward, so per-example contributions are w'_b directly.
        let inv = 1.0 / (bsz as f32 * t as f32);
        for r in 0..m {
            let row = &mut probs[r * v..(r + 1) * v];
            for p in row.iter_mut() {
                *p *= inv;
            }
            row[batch.targets[r] as usize] -= inv;
        }

        // lm_head (no bias): Gram norms + batched dw + dx.
        if with_stats {
            weight_sqnorms(nw, lnf_out, probs, bsz, t, d, v, per_ex);
            add_stats(stats, self.ltype_idx[gi + 2], per_ex, bsz);
        }
        transpose_par(nw, lnf_out, m, d, xt);
        matmul_at_b_acc(nw, xt, probs, m, d, v, &mut grads[gi + 2]);
        matmul_xw_t(nw, probs, ps[gi + 2], m, d, v, tmp1);

        // Final norm: fused backward emits the per-example norms.
        self.norm_bwd(
            ps, gi, tmp1, lnf_xhat, lnf_rstd, bsz, t, dx, ex_scratch, grads, per_ex, stats,
            with_stats,
        );

        for i in (0..self.cfg.n_layers).rev() {
            let base = self.block_base(i);
            let blk = &blocks[i];
            match self.cfg.placement {
                NormPlacement::PreLn => {
                    // MLP branch: x_out = x_mid + proj(gelu(fc(ln2(x_mid)))).
                    if with_stats {
                        weight_sqnorms(nw, &blk.fc_act, dx, bsz, t, 4 * d, d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_PROJ], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        dx,
                        bsz,
                        t,
                        d,
                        &mut grads[base + B_PROJ],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_PROJ], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.fc_act, m, 4 * d, xt);
                    matmul_at_b_acc(nw, xt, dx, m, 4 * d, d, &mut grads[base + W_PROJ]);
                    matmul_xw_t(nw, dx, ps[base + W_PROJ], m, 4 * d, d, delta);
                    gelu_bwd_batched(nw, &blk.fc_pre, m, 4 * d, delta);

                    if with_stats {
                        weight_sqnorms(nw, &blk.ln2_out, delta, bsz, t, d, 4 * d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_FC], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        delta,
                        bsz,
                        t,
                        4 * d,
                        &mut grads[base + B_FC],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_FC], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.ln2_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, delta, m, d, 4 * d, &mut grads[base + W_FC]);
                    matmul_xw_t(nw, delta, ps[base + W_FC], m, d, 4 * d, tmp1);

                    self.norm_bwd(
                        ps,
                        base + LN2_G,
                        tmp1,
                        &blk.ln2_xhat,
                        &blk.ln2_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );
                    add_into(&mut dx[..m * d], &tmp2[..m * d]);

                    // Attention branch: x_mid = x_in + w_o(att(ln1(x_in))).
                    if with_stats {
                        weight_sqnorms(nw, &blk.att_out, dx, bsz, t, d, d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_O], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        dx,
                        bsz,
                        t,
                        d,
                        &mut grads[base + B_O],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_O], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.att_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, dx, m, d, d, &mut grads[base + W_O]);
                    matmul_xw_t(nw, dx, ps[base + W_O], m, d, d, tmp1);

                    attention_backward(
                        nw, &blk.qkv, &blk.att_p, tmp1, bsz, t, d, heads, scale, delta,
                    );

                    if with_stats {
                        weight_sqnorms(nw, &blk.ln1_out, delta, bsz, t, d, 3 * d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_QKV], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        delta,
                        bsz,
                        t,
                        3 * d,
                        &mut grads[base + B_QKV],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_QKV], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.ln1_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, delta, m, d, 3 * d, &mut grads[base + W_QKV]);
                    matmul_xw_t(nw, delta, ps[base + W_QKV], m, d, 3 * d, tmp1);

                    self.norm_bwd(
                        ps,
                        base + LN1_G,
                        tmp1,
                        &blk.ln1_xhat,
                        &blk.ln1_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );
                    add_into(&mut dx[..m * d], &tmp2[..m * d]);
                }
                NormPlacement::PostLn => {
                    // x_out = norm2(s2): the norm backward REPLACES the
                    // stream gradient — no residual passes around a
                    // Post-LN norm.
                    self.norm_bwd(
                        ps,
                        base + LN2_G,
                        dx,
                        &blk.ln2_xhat,
                        &blk.ln2_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );
                    dx[..m * d].copy_from_slice(&tmp2[..m * d]);

                    // s2 = x_mid + proj(gelu(fc(x_mid))), x_mid = ln1_out.
                    if with_stats {
                        weight_sqnorms(nw, &blk.fc_act, dx, bsz, t, 4 * d, d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_PROJ], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        dx,
                        bsz,
                        t,
                        d,
                        &mut grads[base + B_PROJ],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_PROJ], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.fc_act, m, 4 * d, xt);
                    matmul_at_b_acc(nw, xt, dx, m, 4 * d, d, &mut grads[base + W_PROJ]);
                    matmul_xw_t(nw, dx, ps[base + W_PROJ], m, 4 * d, d, delta);
                    gelu_bwd_batched(nw, &blk.fc_pre, m, 4 * d, delta);

                    if with_stats {
                        weight_sqnorms(nw, &blk.ln1_out, delta, bsz, t, d, 4 * d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_FC], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        delta,
                        bsz,
                        t,
                        4 * d,
                        &mut grads[base + B_FC],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_FC], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.ln1_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, delta, m, d, 4 * d, &mut grads[base + W_FC]);
                    matmul_xw_t(nw, delta, ps[base + W_FC], m, d, 4 * d, tmp1);
                    // d(x_mid) = residual ds2 + MLP path.
                    add_into(&mut dx[..m * d], &tmp1[..m * d]);

                    // x_mid = norm1(s1): replace again.
                    self.norm_bwd(
                        ps,
                        base + LN1_G,
                        dx,
                        &blk.ln1_xhat,
                        &blk.ln1_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );
                    dx[..m * d].copy_from_slice(&tmp2[..m * d]);

                    // s1 = x_in + w_o(att(qkv(x_in))).
                    if with_stats {
                        weight_sqnorms(nw, &blk.att_out, dx, bsz, t, d, d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_O], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        dx,
                        bsz,
                        t,
                        d,
                        &mut grads[base + B_O],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_O], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.att_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, dx, m, d, d, &mut grads[base + W_O]);
                    matmul_xw_t(nw, dx, ps[base + W_O], m, d, d, tmp1);

                    attention_backward(
                        nw, &blk.qkv, &blk.att_p, tmp1, bsz, t, d, heads, scale, delta,
                    );

                    if with_stats {
                        weight_sqnorms(nw, &blk.blk_in, delta, bsz, t, d, 3 * d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_QKV], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        delta,
                        bsz,
                        t,
                        3 * d,
                        &mut grads[base + B_QKV],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_QKV], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.blk_in, m, d, xt);
                    matmul_at_b_acc(nw, xt, delta, m, d, 3 * d, &mut grads[base + W_QKV]);
                    matmul_xw_t(nw, delta, ps[base + W_QKV], m, d, 3 * d, tmp1);
                    // d(x_in) = residual ds1 + attention path.
                    add_into(&mut dx[..m * d], &tmp1[..m * d]);
                }
                NormPlacement::PeriLn => {
                    // x_out = x_mid + lno2(p): residual carries dx
                    // through; tmp2 = d(p), the pre-norm MLP output grad.
                    self.norm_bwd(
                        ps,
                        base + LNO2_G,
                        dx,
                        &blk.lno2_xhat,
                        &blk.lno2_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );

                    // p = proj(gelu(fc(ln2(x_mid)))).
                    if with_stats {
                        weight_sqnorms(nw, &blk.fc_act, tmp2, bsz, t, 4 * d, d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_PROJ], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        tmp2,
                        bsz,
                        t,
                        d,
                        &mut grads[base + B_PROJ],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_PROJ], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.fc_act, m, 4 * d, xt);
                    matmul_at_b_acc(nw, xt, tmp2, m, 4 * d, d, &mut grads[base + W_PROJ]);
                    matmul_xw_t(nw, tmp2, ps[base + W_PROJ], m, 4 * d, d, delta);
                    gelu_bwd_batched(nw, &blk.fc_pre, m, 4 * d, delta);

                    if with_stats {
                        weight_sqnorms(nw, &blk.ln2_out, delta, bsz, t, d, 4 * d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_FC], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        delta,
                        bsz,
                        t,
                        4 * d,
                        &mut grads[base + B_FC],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_FC], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.ln2_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, delta, m, d, 4 * d, &mut grads[base + W_FC]);
                    matmul_xw_t(nw, delta, ps[base + W_FC], m, d, 4 * d, tmp1);

                    self.norm_bwd(
                        ps,
                        base + LN2_G,
                        tmp1,
                        &blk.ln2_xhat,
                        &blk.ln2_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );
                    add_into(&mut dx[..m * d], &tmp2[..m * d]);

                    // x_mid = x_in + lno1(o): tmp2 = d(o), the pre-norm
                    // attention output grad.
                    self.norm_bwd(
                        ps,
                        base + LNO1_G,
                        dx,
                        &blk.lno1_xhat,
                        &blk.lno1_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );

                    if with_stats {
                        weight_sqnorms(nw, &blk.att_out, tmp2, bsz, t, d, d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_O], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        tmp2,
                        bsz,
                        t,
                        d,
                        &mut grads[base + B_O],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_O], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.att_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, tmp2, m, d, d, &mut grads[base + W_O]);
                    matmul_xw_t(nw, tmp2, ps[base + W_O], m, d, d, tmp1);

                    attention_backward(
                        nw, &blk.qkv, &blk.att_p, tmp1, bsz, t, d, heads, scale, delta,
                    );

                    if with_stats {
                        weight_sqnorms(nw, &blk.ln1_out, delta, bsz, t, d, 3 * d, per_ex);
                        add_stats(stats, self.ltype_idx[base + W_QKV], per_ex, bsz);
                    }
                    bias_sqnorms_acc(
                        delta,
                        bsz,
                        t,
                        3 * d,
                        &mut grads[base + B_QKV],
                        bias_scratch,
                        if with_stats { Some(per_ex.as_mut_slice()) } else { None },
                    );
                    if with_stats {
                        add_stats(stats, self.ltype_idx[base + B_QKV], per_ex, bsz);
                    }
                    transpose_par(nw, &blk.ln1_out, m, d, xt);
                    matmul_at_b_acc(nw, xt, delta, m, d, 3 * d, &mut grads[base + W_QKV]);
                    matmul_xw_t(nw, delta, ps[base + W_QKV], m, d, 3 * d, tmp1);

                    self.norm_bwd(
                        ps,
                        base + LN1_G,
                        tmp1,
                        &blk.ln1_xhat,
                        &blk.ln1_rstd,
                        bsz,
                        t,
                        tmp2,
                        ex_scratch,
                        grads,
                        per_ex,
                        stats,
                        with_stats,
                    );
                    add_into(&mut dx[..m * d], &tmp2[..m * d]);
                }
            }
        }

        // Embedding: per-example norms need token-id grouping for wte
        // (rows hitting the same id sum before the norm); wpe rows are hit
        // once per example, so its per-example norm is just Σ_t ||dx_t||².
        if with_stats {
            let emb_idx = self.ltype_idx[0];
            for b in 0..bsz {
                let mut nslots = 0usize;
                for ti in 0..t {
                    let r = b * t + ti;
                    let id = batch.inputs[r] as usize;
                    let src = &dx[r * d..(r + 1) * d];
                    let slot = emb_slot[id];
                    if slot == usize::MAX {
                        emb_slot[id] = nslots;
                        emb_rows[nslots * d..(nslots + 1) * d].copy_from_slice(src);
                        nslots += 1;
                    } else {
                        let dst = &mut emb_rows[slot * d..(slot + 1) * d];
                        for j in 0..d {
                            dst[j] += src[j];
                        }
                    }
                }
                let mut sq = 0f64;
                for s in 0..nslots {
                    sq += sqnorm64(&emb_rows[s * d..(s + 1) * d]);
                }
                for ti in 0..t {
                    let r = b * t + ti;
                    emb_slot[batch.inputs[r] as usize] = usize::MAX;
                    sq += sqnorm64(&dx[r * d..(r + 1) * d]); // wpe
                }
                stats[emb_idx] += sq;
            }
        }
        for r in 0..m {
            let id = batch.inputs[r] as usize;
            let ti = r % t;
            let src = &dx[r * d..(r + 1) * d];
            let g0 = &mut grads[0][id * d..(id + 1) * d];
            for j in 0..d {
                g0[j] += src[j];
            }
            let g1 = &mut grads[1][ti * d..(ti + 1) * d];
            for j in 0..d {
                g1[j] += src[j];
            }
        }
    }
}

/// Disjoint mutable borrows of two entries of a slice of Vecs.
fn two_mut(eg: &mut [Vec<f32>], a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
    assert!(a < b);
    let (lo, hi) = eg.split_at_mut(b);
    (&mut lo[a], &mut hi[0])
}

impl ReferenceBackend {
    fn grad_step_impl(
        &self,
        params: &[Buffer],
        batch: &Batch,
        with_stats: bool,
    ) -> Result<GradOut> {
        self.check_batch(batch)?;
        let ps = self.host_params(params)?;
        let mut guard =
            self.ws.lock().map_err(|_| anyhow!("reference workspace mutex poisoned"))?;
        let ws = self.ensure_workspace(&mut *guard, batch.batch)?;

        let mut acc: Vec<Vec<f32>> =
            self.entry.params.iter().map(|p| vec![0f32; p.numel()]).collect();
        let mut stats = [0f64; N_TYPES];
        let loss = self.batched_forward(&ps, batch, ws)?;
        self.batched_backward(&ps, batch, ws, &mut acc, &mut stats, with_stats);
        drop(guard);

        let grads = acc
            .into_iter()
            .zip(&self.entry.params)
            .map(|(data, p)| Ok(Buffer::Host(Tensor::new(p.shape.clone(), data)?)))
            .collect::<Result<Vec<_>>>()?;
        let mut stats32 = [0f32; N_TYPES];
        for (dst, src) in stats32.iter_mut().zip(stats) {
            *dst = src as f32;
        }
        Ok(GradOut { loss, grads, stats: stats32 })
    }

    /// [`Backend::grad_step`] with every per-example norm contraction
    /// skipped (`stats` comes back all zero); gradients and loss are
    /// bitwise identical to the full step. This is the norms-off baseline
    /// the benches use to measure the paper's overhead claim (§3:
    /// per-example norms at near-zero extra cost).
    pub fn grad_step_no_stats(&self, params: &[Buffer], batch: &Batch) -> Result<GradOut> {
        self.grad_step_impl(params, batch, false)
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn init(&self, seed: i32) -> Result<Vec<Buffer>> {
        let mut rng = Rng::seed_from_u64(seed as i64 as u64);
        let resid_scale = 1.0 / (2.0 * self.cfg.n_layers as f64).sqrt();
        let out = self
            .entry
            .params
            .iter()
            .map(|p| {
                let n = p.numel();
                let data: Vec<f32> = if p.shape.len() == 1 {
                    if p.name.ends_with(".g") {
                        vec![1.0; n]
                    } else {
                        vec![0.0; n]
                    }
                } else {
                    let std = if p.name.contains("w_o") || p.name.contains("w_proj") {
                        0.02 * resid_scale
                    } else {
                        0.02
                    };
                    (0..n).map(|_| (rng.normal() * std) as f32).collect()
                };
                Ok(Buffer::Host(Tensor::new(p.shape.clone(), data)?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(out)
    }

    /// Fused batched forward/backward: gradients and the per-example
    /// stats vector come out of one pass over `[B·T, ...]` tensors
    /// (the paper's §3 "simultaneous" method; see `runtime::kernels`).
    fn grad_step(&self, params: &[Buffer], batch: &Batch) -> Result<GradOut> {
        self.grad_step_impl(params, batch, true)
    }

    fn accumulate(&self, acc: Vec<Buffer>, grads: &[Buffer]) -> Result<Vec<Buffer>> {
        ensure!(acc.len() == grads.len(), "accumulate arity mismatch");
        acc.into_iter()
            .zip(grads)
            .map(|(a, g)| {
                let mut t = a.into_host()?;
                let gt = g.as_host()?;
                ensure!(t.data.len() == gt.data.len(), "accumulate shape mismatch");
                for (x, y) in t.data.iter_mut().zip(&gt.data) {
                    *x += *y;
                }
                Ok(Buffer::Host(t))
            })
            .collect()
    }

    fn grad_sqnorms(&self, grads: &[Buffer]) -> Result<[f64; N_TYPES]> {
        ensure!(grads.len() == self.entry.params.len(), "grad_sqnorms arity mismatch");
        let mut out = [0f64; N_TYPES];
        for (i, g) in grads.iter().enumerate() {
            out[self.ltype_idx[i]] += g.as_host()?.sq_norm();
        }
        Ok(out)
    }

    fn adamw_update(
        &self,
        params: Vec<Buffer>,
        m: Vec<Buffer>,
        v: Vec<Buffer>,
        grads: &[Buffer],
        step: u64,
        lr: f64,
        grad_scale: f64,
    ) -> Result<(Vec<Buffer>, Vec<Buffer>, Vec<Buffer>)> {
        let n = self.entry.params.len();
        ensure!(
            params.len() == n && m.len() == n && v.len() == n && grads.len() == n,
            "adamw_update arity mismatch"
        );
        ensure!(step >= 1, "adamw_update needs a 1-based step");
        let h = &self.entry.adam;
        let bc1 = 1.0 - h.beta1.powi(step.min(i32::MAX as u64) as i32);
        let bc2 = 1.0 - h.beta2.powi(step.min(i32::MAX as u64) as i32);

        let mut new_p = Vec::with_capacity(n);
        let mut new_m = Vec::with_capacity(n);
        let mut new_v = Vec::with_capacity(n);
        for (i, ((pb, mb), vb)) in params.into_iter().zip(m).zip(v).enumerate() {
            let mut pt = pb.into_host()?;
            let mut mt = mb.into_host()?;
            let mut vt = vb.into_host()?;
            let gt = grads[i].as_host()?;
            ensure!(
                pt.data.len() == gt.data.len()
                    && mt.data.len() == gt.data.len()
                    && vt.data.len() == gt.data.len(),
                "adamw_update shape mismatch on {}",
                self.entry.params[i].name
            );
            let decay = self.entry.params[i].decay;
            for j in 0..pt.data.len() {
                let g = gt.data[j] as f64 * grad_scale;
                let m1 = h.beta1 * mt.data[j] as f64 + (1.0 - h.beta1) * g;
                let v1 = h.beta2 * vt.data[j] as f64 + (1.0 - h.beta2) * g * g;
                let mhat = m1 / bc1;
                let vhat = v1 / bc2;
                let mut upd = mhat / (vhat.sqrt() + h.eps);
                if decay {
                    upd += h.wd * pt.data[j] as f64;
                }
                pt.data[j] = (pt.data[j] as f64 - lr * upd) as f32;
                mt.data[j] = m1 as f32;
                vt.data[j] = v1 as f32;
            }
            new_p.push(Buffer::Host(pt));
            new_m.push(Buffer::Host(mt));
            new_v.push(Buffer::Host(vt));
        }
        Ok((new_p, new_m, new_v))
    }

    fn eval(&self, params: &[Buffer], batch: &Batch) -> Result<f32> {
        self.check_batch(batch)?;
        let ps = self.host_params(params)?;
        let mut guard =
            self.ws.lock().map_err(|_| anyhow!("reference workspace mutex poisoned"))?;
        let ws = self.ensure_workspace(&mut *guard, batch.batch)?;
        self.batched_forward(&ps, batch, ws)
    }
}

impl ReferenceBackend {
    /// The retained per-example oracle: the naive one-example-at-a-time
    /// backward (Goodfellow's *reference formula*), computing `sum_b
    /// ||w'_b||²` from definitionally-correct per-example gradients.
    /// Semantically identical to [`Backend::grad_step`] but ~an order of
    /// magnitude slower; tests validate the fused path against it and the
    /// train_step bench uses it as the "before" baseline.
    pub fn grad_step_per_example(&self, params: &[Buffer], batch: &Batch) -> Result<GradOut> {
        self.check_batch(batch)?;
        let ps = self.host_params(params)?;
        let t = batch.seq_len;
        let bsz = batch.batch;
        let inv_b = 1.0 / bsz as f32;

        let mut acc: Vec<Vec<f32>> =
            self.entry.params.iter().map(|p| vec![0f32; p.numel()]).collect();
        let mut eg: Vec<Vec<f32>> =
            self.entry.params.iter().map(|p| vec![0f32; p.numel()]).collect();
        let mut stats = [0f64; N_TYPES];
        let mut loss_sum = 0f64;

        for b in 0..bsz {
            let ids = &batch.inputs[b * t..(b + 1) * t];
            let tgt = &batch.targets[b * t..(b + 1) * t];
            for g in eg.iter_mut() {
                g.fill(0.0);
            }
            let (loss, caches) = self.example_forward(&ps, ids, tgt)?;
            loss_sum += loss as f64;
            self.example_backward(&ps, ids, tgt, &caches, &mut eg);
            for (i, g) in eg.iter().enumerate() {
                let ti = self.ltype_idx[i];
                let mut sq = 0f64;
                let a = &mut acc[i];
                for (av, gv) in a.iter_mut().zip(g) {
                    let w = gv * inv_b; // w'_b = (1/B) dL_b/dw
                    *av += w;
                    sq += (w as f64) * (w as f64);
                }
                stats[ti] += sq;
            }
        }

        let grads = acc
            .into_iter()
            .zip(&self.entry.params)
            .map(|(data, p)| Ok(Buffer::Host(Tensor::new(p.shape.clone(), data)?)))
            .collect::<Result<Vec<_>>>()?;
        let mut stats32 = [0f32; N_TYPES];
        for (dst, src) in stats32.iter_mut().zip(stats) {
            *dst = src as f32;
        }
        Ok(GradOut { loss: (loss_sum / bsz as f64) as f32, grads, stats: stats32 })
    }
}

/// Factory over the built-in [`PRESETS`].
pub struct ReferenceFactory;

impl BackendFactory for ReferenceFactory {
    fn create(&self, model: &str) -> Result<Box<dyn Backend>> {
        Ok(Box::new(ReferenceBackend::from_preset(model)?))
    }

    fn describe(&self, model: &str) -> Result<ModelEntry> {
        Ok(ReferenceBackend::from_preset(model)?.entry().clone())
    }

    fn models(&self) -> Vec<String> {
        PRESETS.iter().map(|(n, _)| n.to_string()).collect()
    }

    fn platform(&self) -> String {
        "reference-cpu".to_string()
    }
}

/// Factory over the built-in [`PRESETS`] with an explicit normalization
/// matrix cell applied to every model it creates. `default()` is the
/// LayerNorm + Pre-LN cell, i.e. exactly [`ReferenceFactory`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReferenceVariantFactory {
    pub norm: NormKind,
    pub placement: NormPlacement,
}

impl ReferenceVariantFactory {
    pub fn new(norm: NormKind, placement: NormPlacement) -> Self {
        Self { norm, placement }
    }

    fn cfg(&self, model: &str) -> Result<RefModelConfig> {
        let mut cfg = preset_cfg(model)?;
        cfg.norm = self.norm;
        cfg.placement = self.placement;
        Ok(cfg)
    }
}

impl BackendFactory for ReferenceVariantFactory {
    fn create(&self, model: &str) -> Result<Box<dyn Backend>> {
        Ok(Box::new(ReferenceBackend::new(self.cfg(model)?)?))
    }

    fn describe(&self, model: &str) -> Result<ModelEntry> {
        Ok(ReferenceBackend::new(self.cfg(model)?)?.entry().clone())
    }

    fn models(&self) -> Vec<String> {
        PRESETS.iter().map(|(n, _)| n.to_string()).collect()
    }

    fn platform(&self) -> String {
        "reference-cpu".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(microbatch: usize) -> RefModelConfig {
        RefModelConfig {
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            seq_len: 6,
            vocab: 11,
            microbatch,
            norm: NormKind::LayerNorm,
            placement: NormPlacement::PreLn,
        }
    }

    /// All six cells of the normalization matrix at the tiny shape.
    fn matrix_cells(microbatch: usize) -> Vec<RefModelConfig> {
        let mut out = Vec::new();
        for norm in NormKind::ALL {
            for placement in NormPlacement::ALL {
                out.push(RefModelConfig { norm, placement, ..tiny_cfg(microbatch) });
            }
        }
        out
    }

    fn tiny_batch(bsz: usize, t: usize, vocab: usize, seed: u64) -> Batch {
        let mut rng = Rng::seed_from_u64(seed);
        let n = bsz * t;
        Batch {
            batch: bsz,
            seq_len: t,
            inputs: (0..n).map(|_| rng.range(0, vocab) as i32).collect(),
            targets: (0..n).map(|_| rng.range(0, vocab) as i32).collect(),
        }
    }

    fn perturbed(params: &[Buffer], i: usize, j: usize, eps: f32) -> Vec<Buffer> {
        let mut out = params.to_vec();
        let mut t = out[i].to_tensor().unwrap();
        t.data[j] += eps;
        out[i] = Buffer::Host(t);
        out
    }

    #[test]
    fn presets_all_build() {
        for (name, _) in PRESETS {
            let be = ReferenceBackend::from_preset(name).unwrap();
            let e = be.entry();
            assert_eq!(e.params.len(), 2 + 12 * e.n_layers + 3, "{name}");
            let total: u64 = e.params.iter().map(|p| p.numel() as u64).sum();
            assert_eq!(total, e.n_params, "{name}");
        }
        assert!(ReferenceBackend::from_preset("gpt5").is_err());
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let a = be.init(3).unwrap();
        let b = be.init(3).unwrap();
        let c = be.init(4).unwrap();
        assert_eq!(a[0].as_host().unwrap(), b[0].as_host().unwrap());
        assert_ne!(a[0].as_host().unwrap(), c[0].as_host().unwrap());
        // ln gamma ones, biases zero
        let e = be.entry();
        for (i, p) in e.params.iter().enumerate() {
            let t = a[i].as_host().unwrap();
            if p.name.ends_with(".g") {
                assert!(t.data.iter().all(|&x| x == 1.0), "{}", p.name);
            } else if p.shape.len() == 1 {
                assert!(t.data.iter().all(|&x| x == 0.0), "{}", p.name);
            }
        }
    }

    #[test]
    fn grad_step_is_deterministic() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(0).unwrap();
        let batch = tiny_batch(2, 6, 11, 7);
        let a = be.grad_step(&params, &batch).unwrap();
        let b = be.grad_step(&params, &batch).unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.stats, b.stats);
        for (x, y) in a.grads.iter().zip(&b.grads) {
            assert_eq!(x.as_host().unwrap(), y.as_host().unwrap());
        }
    }

    #[test]
    fn grad_step_loss_matches_eval() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(1).unwrap();
        let batch = tiny_batch(2, 6, 11, 3);
        let g = be.grad_step(&params, &batch).unwrap();
        let e = be.eval(&params, &batch).unwrap();
        assert!((g.loss - e).abs() < 1e-6, "{} vs {e}", g.loss);
        // random-init loss near ln(vocab)
        assert!((e - (11f32).ln()).abs() < 1.0, "{e}");
    }

    /// The backward pass against central finite differences, per tensor.
    #[test]
    fn gradients_match_finite_differences() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(5).unwrap();
        let batch = tiny_batch(2, 6, 11, 9);
        let out = be.grad_step(&params, &batch).unwrap();
        let h = 1e-2f32;
        let mut checked = 0usize;
        for (i, g) in out.grads.iter().enumerate() {
            let gt = g.as_host().unwrap();
            // most-identifiable coordinate of this tensor
            let (j, &ana) = gt
                .data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .unwrap();
            if ana.abs() < 1e-3 {
                continue;
            }
            let lp = be.eval(&perturbed(&params, i, j, h), &batch).unwrap();
            let lm = be.eval(&perturbed(&params, i, j, -h), &batch).unwrap();
            let num = (lp - lm) / (2.0 * h);
            let tol = 0.1 * ana.abs().max(num.abs()) + 2e-3;
            assert!(
                (num - ana).abs() <= tol,
                "param {} ({}): numeric {num} vs analytic {ana}",
                be.entry().params[i].name,
                i
            );
            checked += 1;
        }
        assert!(checked >= 5, "only {checked} tensors had a testable coordinate");
    }

    /// The fused B=4 step against brute-force per-example gradients
    /// obtained from four B=1 oracle steps (Goodfellow reference path),
    /// and the retained oracle at B=4 against the same brute force.
    #[test]
    fn stats_match_bruteforce_per_example_gradients() {
        let be4 = ReferenceBackend::new(tiny_cfg(4)).unwrap();
        let be1 = ReferenceBackend::new(tiny_cfg(1)).unwrap();
        let params = be4.init(2).unwrap();
        let t = 6;
        let batch = tiny_batch(4, t, 11, 11);
        let fused = be4.grad_step(&params, &batch).unwrap();
        let oracle = be4.grad_step_per_example(&params, &batch).unwrap();

        let mut brute_stats = [0f64; N_TYPES];
        let mut brute_grads: Vec<Vec<f64>> =
            be4.entry().params.iter().map(|p| vec![0f64; p.numel()]).collect();
        for b in 0..4 {
            let one = Batch {
                batch: 1,
                seq_len: t,
                inputs: batch.inputs[b * t..(b + 1) * t].to_vec(),
                targets: batch.targets[b * t..(b + 1) * t].to_vec(),
            };
            // B=1 oracle: returned grads are exactly dL_b/dw.
            let ob = be1.grad_step_per_example(&params, &one).unwrap();
            for (i, g) in ob.grads.iter().enumerate() {
                let gt = g.as_host().unwrap();
                let ti = be1.ltype_idx[i];
                let mut sq = 0f64;
                for (acc, &gv) in brute_grads[i].iter_mut().zip(&gt.data) {
                    let w = gv as f64 / 4.0;
                    *acc += w;
                    sq += w * w;
                }
                brute_stats[ti] += sq;
            }
        }
        // Oracle at B=4 is bit-for-bit the old per-example path: tight.
        for (i, g) in oracle.grads.iter().enumerate() {
            let gt = g.as_host().unwrap();
            for (x, y) in gt.data.iter().zip(&brute_grads[i]) {
                assert!(
                    ((*x as f64) - y).abs() <= 1e-5 * y.abs().max(1e-6),
                    "oracle grad[{i}] {x} vs {y}"
                );
            }
        }
        // Fused path: same math, different f32 association — per-element
        // tolerance floors at a small fraction of the tensor's scale.
        for (a, b) in fused.stats.iter().zip(brute_stats) {
            assert!(
                ((*a as f64) - b).abs() <= 1e-4 * b.abs().max(1e-12),
                "fused stats {a} vs brute {b}"
            );
        }
        for (i, g) in fused.grads.iter().enumerate() {
            let gt = g.as_host().unwrap();
            let scale = brute_grads[i].iter().fold(0f64, |m, v| m.max(v.abs()));
            for (x, y) in gt.data.iter().zip(&brute_grads[i]) {
                assert!(
                    ((*x as f64) - y).abs() <= 1e-5 * y.abs() + 1e-5 * scale + 1e-12,
                    "fused grad[{i}] {x} vs {y} (scale {scale})"
                );
            }
        }
        assert!((fused.loss - oracle.loss).abs() <= 1e-5 * oracle.loss.abs().max(1e-6));
    }

    /// Property test (satellite): the fused Gram-matrix / fused-LN norm
    /// path matches the retained per-example oracle to 1e-4 relative on
    /// random shapes, including the T=1 and B=1 edges.
    #[test]
    fn fused_stats_match_oracle_on_random_shapes() {
        use crate::util::prop::forall;
        forall(
            2024,
            12,
            |r| {
                let heads = 1 + r.range(0, 2); // 1..=2
                let hd = 2 + r.range(0, 3); // 2..=4
                let d = heads * hd;
                let cfg = RefModelConfig {
                    d_model: d,
                    n_layers: 1 + r.range(0, 2),
                    n_heads: heads,
                    seq_len: [1, 2, 5, 9][r.range(0, 4)],
                    vocab: 5 + r.range(0, 13),
                    microbatch: 1 + r.range(0, 3),
                    norm: NormKind::ALL[r.range(0, NormKind::ALL.len())],
                    placement: NormPlacement::ALL[r.range(0, NormPlacement::ALL.len())],
                };
                let seed = r.next_u64();
                (cfg, seed)
            },
            |&(cfg, seed)| {
                let be = ReferenceBackend::new(cfg).map_err(|e| e.to_string())?;
                let params = be.init((seed % 1000) as i32).map_err(|e| e.to_string())?;
                let batch = tiny_batch(cfg.microbatch, cfg.seq_len, cfg.vocab, seed);
                let fused = be.grad_step(&params, &batch).map_err(|e| e.to_string())?;
                let oracle =
                    be.grad_step_per_example(&params, &batch).map_err(|e| e.to_string())?;
                for (ty, (a, b)) in
                    STATS_ORDER.iter().zip(fused.stats.iter().zip(oracle.stats))
                {
                    crate::prop_check!(
                        ((*a as f64) - b as f64).abs() <= 1e-4 * (b as f64).abs().max(1e-10),
                        "stats[{ty}]: fused {a} vs oracle {b} ({cfg:?})"
                    );
                }
                crate::prop_check!(
                    (fused.loss - oracle.loss).abs() <= 1e-5 * oracle.loss.abs().max(1e-6),
                    "loss {} vs {}",
                    fused.loss,
                    oracle.loss
                );
                Ok(())
            },
        );
    }

    /// Determinism contract (satellite): the threaded fused path has a
    /// fixed reduction order, so results are bitwise identical for any
    /// worker count.
    #[test]
    fn threaded_path_is_deterministic_across_worker_counts() {
        let cfg = tiny_cfg(3);
        let base = ReferenceBackend::with_threads(cfg, 1).unwrap();
        let params = base.init(8).unwrap();
        let batch = tiny_batch(3, 6, 11, 13);
        let a = base.grad_step(&params, &batch).unwrap();
        for w in [2, 3, 5] {
            let be = ReferenceBackend::with_threads(cfg, w).unwrap();
            let b = be.grad_step(&params, &batch).unwrap();
            assert_eq!(a.loss, b.loss, "workers={w}");
            assert_eq!(a.stats, b.stats, "workers={w}");
            for (x, y) in a.grads.iter().zip(&b.grads) {
                assert_eq!(x.as_host().unwrap(), y.as_host().unwrap(), "workers={w}");
            }
            assert_eq!(
                base.eval(&params, &batch).unwrap(),
                be.eval(&params, &batch).unwrap(),
                "workers={w}"
            );
        }
    }

    /// The norms-off backward (`grad_step_no_stats`, the overhead-bench
    /// baseline) must return bitwise-identical loss and gradients — only
    /// the stats vector goes to zero.
    #[test]
    fn no_stats_step_keeps_gradients_bitwise_invariant() {
        let be = ReferenceBackend::new(tiny_cfg(3)).unwrap();
        let params = be.init(21).unwrap();
        let batch = tiny_batch(3, 6, 11, 17);
        let full = be.grad_step(&params, &batch).unwrap();
        let bare = be.grad_step_no_stats(&params, &batch).unwrap();
        assert_eq!(full.loss, bare.loss);
        assert!(full.stats.iter().any(|&s| s > 0.0));
        assert!(bare.stats.iter().all(|&s| s == 0.0));
        for (x, y) in full.grads.iter().zip(&bare.grads) {
            assert_eq!(x.as_host().unwrap(), y.as_host().unwrap());
        }
    }

    /// Satellite: oversized microbatch/seq-len combos are rejected at
    /// construction with a clear error instead of OOMing mid-bench.
    #[test]
    fn workspace_cap_rejects_oversized_configs() {
        let cfg = tiny_cfg(2);
        let err = ReferenceBackend::with_workspace_cap(cfg, Some(1 << 10)).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        // uncapped always constructs
        ReferenceBackend::with_workspace_cap(cfg, None).unwrap();
        // an absurd config trips the default 1 GiB cap
        let huge = RefModelConfig {
            d_model: 1024,
            n_layers: 48,
            n_heads: 16,
            seq_len: 4096,
            vocab: 50304,
            microbatch: 64,
            norm: NormKind::LayerNorm,
            placement: NormPlacement::PreLn,
        };
        assert!(ReferenceBackend::new(huge).is_err());
        assert!(workspace_bytes(&huge, 64) > workspace_bytes(&cfg, 2));
    }

    #[test]
    fn accumulate_and_sqnorms_are_consistent() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let params = be.init(0).unwrap();
        let g1 = be.grad_step(&params, &tiny_batch(2, 6, 11, 1)).unwrap().grads;
        let g2 = be.grad_step(&params, &tiny_batch(2, 6, 11, 2)).unwrap().grads;
        let acc = be.accumulate(be.zero_grads().unwrap(), &g1).unwrap();
        let acc = be.accumulate(acc, &g2).unwrap();
        let sq = be.grad_sqnorms(&acc).unwrap();
        let mut host = [0f64; N_TYPES];
        for (i, (a, b)) in g1.iter().zip(&g2).enumerate() {
            let ta = a.as_host().unwrap();
            let tb = b.as_host().unwrap();
            let s: f64 = ta
                .data
                .iter()
                .zip(&tb.data)
                .map(|(x, y)| ((x + y) as f64) * ((x + y) as f64))
                .sum();
            host[be.ltype_idx[i]] += s;
        }
        for (d, h) in sq.iter().zip(host) {
            assert!((d - h).abs() <= 1e-6 * h.max(1e-12), "{d} vs {h}");
        }
    }

    #[test]
    fn adamw_overfits_one_batch() {
        let be = ReferenceBackend::new(tiny_cfg(2)).unwrap();
        let mut params = be.init(4).unwrap();
        let mut m = be.zero_grads().unwrap();
        let mut v = be.zero_grads().unwrap();
        let batch = tiny_batch(2, 6, 11, 5);
        let before = be.eval(&params, &batch).unwrap();
        for step in 1..=8u64 {
            let out = be.grad_step(&params, &batch).unwrap();
            let (p2, m2, v2) = be.adamw_update(params, m, v, &out.grads, step, 3e-3, 1.0).unwrap();
            params = p2;
            m = m2;
            v = v2;
        }
        let after = be.eval(&params, &batch).unwrap();
        assert!(after < before, "{after} !< {before}");
    }

    /// Tentpole: the parameter layout per matrix cell. Peri-LN appends
    /// the two output norms per block; RMSNorm keeps the `.b` slots as
    /// frozen dummies so offsets stay uniform across kinds.
    #[test]
    fn matrix_cell_layouts_are_consistent() {
        for cfg in matrix_cells(2) {
            let be = ReferenceBackend::new(cfg).unwrap();
            let e = be.entry();
            assert_eq!(
                e.params.len(),
                2 + per_block(&cfg) * cfg.n_layers + 3,
                "{}/{}",
                cfg.norm,
                cfg.placement
            );
            let has_lno = e.params.iter().any(|p| p.name.contains(".lno1."));
            assert_eq!(has_lno, cfg.placement == NormPlacement::PeriLn, "{}", cfg.placement);
            let total: u64 = e.params.iter().map(|p| p.numel() as u64).sum();
            assert_eq!(total, e.n_params, "{}/{}", cfg.norm, cfg.placement);
        }
    }

    /// Tentpole: analytic gradients against central finite differences in
    /// EVERY cell of the normalization matrix. The fused batched path and
    /// the per-example oracle share no code with `eval`'s loss beyond the
    /// forward, so this pins the placement-specific backward dataflow.
    #[test]
    fn matrix_cells_match_finite_differences() {
        for cfg in matrix_cells(2) {
            let tag = format!("{}/{}", cfg.norm, cfg.placement);
            let be = ReferenceBackend::new(cfg).unwrap();
            let params = be.init(5).unwrap();
            let batch = tiny_batch(2, 6, 11, 9);
            let out = be.grad_step(&params, &batch).unwrap();
            let h = 1e-2f32;
            let mut checked = 0usize;
            for (i, g) in out.grads.iter().enumerate() {
                let gt = g.as_host().unwrap();
                let (j, &ana) = gt
                    .data
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                let name = &be.entry().params[i].name;
                if cfg.norm == NormKind::RmsNorm && name.ends_with(".b") && name.contains("ln") {
                    // dummy β: gradient must stay exactly zero
                    assert!(gt.data.iter().all(|&x| x == 0.0), "{tag}: {name}");
                    continue;
                }
                if ana.abs() < 1e-3 {
                    continue;
                }
                let lp = be.eval(&perturbed(&params, i, j, h), &batch).unwrap();
                let lm = be.eval(&perturbed(&params, i, j, -h), &batch).unwrap();
                let num = (lp - lm) / (2.0 * h);
                let tol = 0.1 * ana.abs().max(num.abs()) + 2e-3;
                assert!(
                    (num - ana).abs() <= tol,
                    "{tag}: param {name} ({i}): numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
            assert!(checked >= 5, "{tag}: only {checked} tensors had a testable coordinate");
        }
    }

    /// Tentpole + satellite: every matrix cell is bitwise invariant to
    /// the worker count, and its fused stats match the retained
    /// per-example oracle.
    #[test]
    fn matrix_cells_are_worker_invariant_and_match_oracle() {
        for cfg in matrix_cells(3) {
            let tag = format!("{}/{}", cfg.norm, cfg.placement);
            let base = ReferenceBackend::with_threads(cfg, 1).unwrap();
            let params = base.init(8).unwrap();
            let batch = tiny_batch(3, 6, 11, 13);
            let a = base.grad_step(&params, &batch).unwrap();
            for w in [2, 5] {
                let be = ReferenceBackend::with_threads(cfg, w).unwrap();
                let b = be.grad_step(&params, &batch).unwrap();
                assert_eq!(a.loss, b.loss, "{tag} workers={w}");
                assert_eq!(a.stats, b.stats, "{tag} workers={w}");
                for (x, y) in a.grads.iter().zip(&b.grads) {
                    assert_eq!(x.as_host().unwrap(), y.as_host().unwrap(), "{tag} workers={w}");
                }
            }
            let oracle = base.grad_step_per_example(&params, &batch).unwrap();
            for (ty, (f, o)) in STATS_ORDER.iter().zip(a.stats.iter().zip(oracle.stats)) {
                assert!(
                    ((*f as f64) - o as f64).abs() <= 1e-4 * (o as f64).abs().max(1e-10),
                    "{tag} stats[{ty}]: fused {f} vs oracle {o}"
                );
            }
            assert!((a.loss - oracle.loss).abs() <= 1e-5 * oracle.loss.abs().max(1e-6), "{tag}");
        }
    }

    /// The variant factory applies its cell to every preset; the default
    /// cell describes the same entry as the plain factory.
    #[test]
    fn variant_factory_applies_cell() {
        let f = ReferenceVariantFactory::new(NormKind::RmsNorm, NormPlacement::PeriLn);
        let e = f.describe("nano").unwrap();
        assert!(e.params.iter().any(|p| p.name.contains(".lno1.")));
        let default = ReferenceVariantFactory::default().describe("nano").unwrap();
        let plain = ReferenceFactory.describe("nano").unwrap();
        assert_eq!(default.params.len(), plain.params.len());
        assert_eq!(default.n_params, plain.n_params);
        assert_eq!(f.platform(), "reference-cpu");
        assert!(f.models().contains(&"nano".to_string()));
    }
}
