//! Fused CPU kernels for the reference backend's batched hot path.
//!
//! The paper's central claim (§3) is that per-example gradient norms can
//! be computed *simultaneously* with the batched parameter-gradient
//! contraction at near-zero extra FLOPs. This module is that method in
//! pure Rust, replacing the naive one-example-at-a-time backward:
//!
//! * [`matmul`] — blocked, transposed-B batched matmuls (`[B·T, K] ×
//!   [K, N]`) shared by every linear layer, with eight-lane vectorizable
//!   dot products;
//! * [`gram`] — Goodfellow's trick: per-example squared weight-gradient
//!   norms from activation/delta Gram matrices, never materializing a
//!   per-example weight gradient (Eqs. 4–5 inputs);
//! * [`layernorm`] — the §3 fused LayerNorm backward that emits
//!   per-example `||dγ_b||² + ||dβ_b||²` inside the same reduction pass;
//! * [`threads`] — `std::thread::scope` data parallelism whose outputs
//!   are always disjoint row blocks, making every kernel bitwise
//!   deterministic for any worker count.
//!
//! DESIGN.md §2 "Kernels" maps each kernel to the paper equation it
//! implements.

// Kernels thread shapes and several output slices explicitly; the
// many-argument form is the readable one here (as in runtime::reference).
#![allow(clippy::too_many_arguments)]

pub mod gram;
pub mod layernorm;
pub mod matmul;
pub mod threads;

pub use gram::{bias_sqnorms_acc, weight_sqnorms};
pub use layernorm::{ln_bwd_fused, ln_fwd};
pub use matmul::{dot, matmul_at_b_acc, matmul_xw_t, matmul_xwt, transpose, transpose_par};
pub use threads::{default_workers, par_row_blocks, par_row_blocks2};
