//! Fused CPU kernels for the reference backend's batched hot path.
//!
//! The paper's central claim (§3) is that per-example gradient norms can
//! be computed *simultaneously* with the batched parameter-gradient
//! contraction at near-zero extra FLOPs. This module is that method in
//! pure Rust, replacing the naive one-example-at-a-time backward:
//!
//! * [`simd`] — runtime-dispatched AVX2/FMA and NEON inner loops with
//!   the original scalar code as the always-compiled oracle
//!   (`NANOGNS_FORCE_SCALAR=1` pins it; see the tier table in
//!   DESIGN.md §2);
//! * [`matmul`] — blocked, transposed-B batched matmuls (`[B·T, K] ×
//!   [K, N]`) shared by every linear layer, register-blocked four output
//!   columns at a time and tiled so the packed weight slice stays
//!   cache-resident;
//! * [`gram`] — Goodfellow's trick: per-example squared weight-gradient
//!   norms from activation/delta Gram matrices, never materializing a
//!   per-example weight gradient (Eqs. 4–5 inputs);
//! * [`layernorm`] — the §3 fused LayerNorm backward that emits
//!   per-example `||dγ_b||² + ||dβ_b||²` inside the same reduction pass;
//! * [`rmsnorm`] — the RMSNorm member of the same kernel family: the
//!   LayerNorm backward at `m1 = 0` with no `β`, emitting per-example
//!   `||dγ_b||²` from the same fused pass (normalization-matrix cells
//!   with `NormKind::RmsNorm`);
//! * [`threads`] — the persistent [`WorkerPool`]: parked workers, one
//!   spawn per pool lifetime (counted by [`total_threads_spawned`]),
//!   allocation-free dispatch, and outputs that are always disjoint row
//!   blocks, making every kernel bitwise deterministic for any worker
//!   count within a dispatch tier.
//!
//! DESIGN.md §2 "Kernels" maps each kernel to the paper equation it
//! implements.

// Kernels thread shapes and several output slices explicitly; the
// many-argument form is the readable one here (as in runtime::reference).
#![allow(clippy::too_many_arguments)]

pub mod gram;
pub mod layernorm;
pub mod matmul;
pub mod rmsnorm;
pub mod simd;
pub mod threads;

pub use gram::{bias_sqnorms_acc, weight_sqnorms};
pub use layernorm::{ln_bwd_fused, ln_fwd};
pub use rmsnorm::{rms_bwd_fused, rms_fwd};
pub use matmul::{dot, matmul_at_b_acc, matmul_xw_t, matmul_xwt, transpose, transpose_par};
pub use simd::{tier, Tier};
pub use threads::{
    default_workers, par_row_blocks, par_row_blocks2, total_threads_spawned, WorkerPool,
};
