//! Scoped-thread data parallelism with a deterministic reduction contract.
//!
//! All parallel loops in the fused kernels split their *output* into
//! contiguous, disjoint row blocks — one per worker — so no two threads
//! ever write the same element, and every floating-point reduction runs
//! either entirely inside one row (fixed index order) or on the calling
//! thread after the join (fixed example order). Results are therefore
//! bitwise identical for any worker count, which is the thread-determinism
//! contract stated in DESIGN.md §2.
//!
//! Workers are plain `std::thread::scope` threads (no pool, no deps); the
//! calling thread runs the first block itself, so `workers = n` spawns
//! only `n - 1` OS threads per parallel region.

/// Cap on the machine-derived default: each parallel region spawns fresh
/// scoped threads (no persistent pool), and one fused grad_step issues
/// dozens of regions, so beyond a handful of workers the per-region
/// spawn/join cost (~10–20 µs each) outweighs extra cores at these model
/// sizes. An explicit `NANOGNS_THREADS` bypasses the cap.
const DEFAULT_MAX_WORKERS: usize = 8;

/// Worker count from the environment (`NANOGNS_THREADS`, uncapped) or
/// the machine (capped at [`DEFAULT_MAX_WORKERS`]).
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("NANOGNS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(DEFAULT_MAX_WORKERS)
}

/// Split `rows` into at most `workers` contiguous chunks.
/// Returns the chunk length in rows (>= 1 when rows > 0).
fn chunk_rows(rows: usize, workers: usize) -> usize {
    let w = workers.clamp(1, rows.max(1));
    rows.div_ceil(w.max(1)).max(1)
}

/// Run `f(row0, row1, out_block)` over disjoint row blocks of `out`
/// (`rows` rows of `row_len` elements), one block per worker. The first
/// block runs on the calling thread. Deterministic: block boundaries
/// depend only on `(rows, workers)` and blocks never overlap.
pub fn par_row_blocks<T, F>(workers: usize, rows: usize, row_len: usize, out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert!(out.len() >= rows * row_len, "output too small: {} < {}", out.len(), rows * row_len);
    if rows == 0 {
        return;
    }
    let per = chunk_rows(rows, workers);
    if per >= rows {
        f(0, rows, &mut out[..rows * row_len]);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = &mut out[..rows * row_len];
        // Spawn blocks after the first; run the first block here.
        let (first, tail) = std::mem::take(&mut rest).split_at_mut(per * row_len);
        rest = tail;
        let mut start = per;
        while start < rows {
            let end = (start + per).min(rows);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut((end - start) * row_len);
            rest = tail;
            s.spawn(move || f(start, end, head));
            start = end;
        }
        f(0, per, first);
    });
}

/// Two-output variant of [`par_row_blocks`]: both buffers are split by the
/// same row boundaries (with independent row lengths) and handed to
/// `f(row0, row1, a_block, b_block)`.
pub fn par_row_blocks2<T, U, F>(
    workers: usize,
    rows: usize,
    a_row_len: usize,
    a: &mut [T],
    b_row_len: usize,
    b: &mut [U],
    f: F,
) where
    T: Send,
    U: Send,
    F: Fn(usize, usize, &mut [T], &mut [U]) + Sync,
{
    assert!(a.len() >= rows * a_row_len, "output A too small");
    assert!(b.len() >= rows * b_row_len, "output B too small");
    if rows == 0 {
        return;
    }
    let per = chunk_rows(rows, workers);
    if per >= rows {
        f(0, rows, &mut a[..rows * a_row_len], &mut b[..rows * b_row_len]);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest_a = &mut a[..rows * a_row_len];
        let mut rest_b = &mut b[..rows * b_row_len];
        let (first_a, tail_a) = std::mem::take(&mut rest_a).split_at_mut(per * a_row_len);
        let (first_b, tail_b) = std::mem::take(&mut rest_b).split_at_mut(per * b_row_len);
        rest_a = tail_a;
        rest_b = tail_b;
        let mut start = per;
        while start < rows {
            let end = (start + per).min(rows);
            let n = end - start;
            let (ha, ta) = std::mem::take(&mut rest_a).split_at_mut(n * a_row_len);
            let (hb, tb) = std::mem::take(&mut rest_b).split_at_mut(n * b_row_len);
            rest_a = ta;
            rest_b = tb;
            s.spawn(move || f(start, end, ha, hb));
            start = end;
        }
        f(0, per, first_a, first_b);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_row_exactly_once() {
        for workers in [1, 2, 3, 5, 16] {
            for rows in [0usize, 1, 2, 7, 16] {
                let mut out = vec![0u32; rows * 3];
                par_row_blocks(workers, rows, 3, &mut out, |r0, r1, block| {
                    assert_eq!(block.len(), (r1 - r0) * 3);
                    for v in block.iter_mut() {
                        *v += 1;
                    }
                });
                assert!(out.iter().all(|&v| v == 1), "workers={workers} rows={rows}");
            }
        }
    }

    #[test]
    fn block_indices_match_slices() {
        let rows = 11;
        let mut out = vec![0usize; rows * 2];
        par_row_blocks(3, rows, 2, &mut out, |r0, r1, block| {
            for (i, chunk) in block.chunks_mut(2).enumerate() {
                chunk[0] = r0 + i;
                chunk[1] = r1;
            }
        });
        for r in 0..rows {
            assert_eq!(out[r * 2], r);
            assert!(out[r * 2 + 1] > r);
        }
    }

    #[test]
    fn two_output_variant_splits_consistently() {
        let rows = 9;
        let mut a = vec![0f32; rows * 4];
        let mut b = vec![0f64; rows];
        par_row_blocks2(4, rows, 4, &mut a, 1, &mut b, |r0, r1, ab, bb| {
            assert_eq!(ab.len(), (r1 - r0) * 4);
            assert_eq!(bb.len(), r1 - r0);
            for v in ab.iter_mut() {
                *v = r0 as f32;
            }
            for v in bb.iter_mut() {
                *v = r1 as f64;
            }
        });
        assert!(a.iter().all(|&v| v >= 0.0));
        assert!(b.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }
}
