//! Persistent worker pool with a deterministic reduction contract.
//!
//! All parallel loops in the fused kernels split their *output* into
//! contiguous, disjoint row blocks — one task per block — so no two
//! threads ever write the same element, and every floating-point
//! reduction runs either entirely inside one row (fixed index order) or
//! on the calling thread after the join (fixed example order). Block
//! boundaries depend only on `(rows, pool.workers())`, so results are
//! bitwise identical for any worker count *within a dispatch tier*
//! (see `kernels::simd`), which is the thread-determinism contract
//! stated in DESIGN.md §2.
//!
//! Workers are spawned once per [`WorkerPool`] (owned by
//! `ReferenceBackend`) and parked on a condvar between parallel regions.
//! A fused grad_step issues dozens of regions; with scoped threads each
//! one paid ~10–20 µs of spawn/join, which is why the old module capped
//! workers at 8. The pool retires both the per-region spawns and the
//! cap: dispatching a region is one mutex/condvar round-trip and zero
//! heap allocations, and [`total_threads_spawned`] lets tests assert
//! that steady state creates no threads at all.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Monotonic count of OS threads ever spawned by [`WorkerPool`]s in this
/// process. Steady-state training must not move it: after the pools are
/// built, the delta across any number of grad steps is zero.
static THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Process-wide total of pool threads spawned so far (see
/// [`THREADS_SPAWNED`]). Tests diff this across a window of steps to
/// assert zero steady-state thread creation.
pub fn total_threads_spawned() -> u64 {
    THREADS_SPAWNED.load(Ordering::SeqCst)
}

/// Worker count from the environment (`NANOGNS_THREADS`) or the machine
/// (`available_parallelism`, uncapped). The historical cap of 8 existed
/// only to amortize per-region scoped-thread spawns; the persistent pool
/// made it obsolete.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("NANOGNS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split `rows` into at most `workers` contiguous chunks.
/// Returns the chunk length in rows (>= 1 when rows > 0).
fn chunk_rows(rows: usize, workers: usize) -> usize {
    let w = workers.clamp(1, rows.max(1));
    rows.div_ceil(w.max(1)).max(1)
}

/// One published parallel region: a borrow-erased pointer to the task
/// closure plus the task count. Workers copy the fields out under the
/// state lock, so the pointer is only dereferenced between publish and
/// the final ack — both inside the same [`WorkerPool::run`] call that
/// owns the borrow.
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    n_tasks: usize,
}
// SAFETY: the raw pointer is produced from a `&(dyn Fn + Sync)` that the
// publishing `run` call keeps alive until every worker acked the epoch.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per published region; workers track the last epoch
    /// they executed, so a parked worker can never run a region twice.
    epoch: u64,
    job: Option<Job>,
    /// Workers that have not yet acked the current epoch.
    remaining: usize,
    shutdown: bool,
    /// Set by a worker whose task panicked; re-raised by `run`.
    panicked: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here waiting for a new epoch (or shutdown).
    work_cv: Condvar,
    /// The caller parks here waiting for `remaining == 0`.
    done_cv: Condvar,
}

fn worker_loop(shared: &Shared, index: usize, stride: usize) {
    let mut seen = 0u64;
    loop {
        let (task_ptr, n_tasks) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    let job = st.job.as_ref().expect("published epoch carries a job");
                    break (job.task, job.n_tasks);
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: `run` keeps the closure borrow alive until this worker
        // (and every other) acks the epoch below.
        let task: &(dyn Fn(usize) + Sync) = unsafe { &*task_ptr };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // Fixed task assignment: worker `index` runs tasks
            // index+1, index+1+stride, ... (the caller strides from 0).
            let mut ti = index + 1;
            while ti < n_tasks {
                task(ti);
                ti += stride;
            }
        }));
        let mut st = shared.state.lock().unwrap();
        if result.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// A fixed-size pool of parked worker threads. `workers` counts the
/// calling thread too: `WorkerPool::new(n)` spawns `n - 1` OS threads,
/// exactly once, and `run` re-uses them for every region until drop.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes concurrent `run` calls on one pool (the job slot holds
    /// a single region). Uncontended in practice: a backend issues its
    /// regions from one thread.
    run_guard: Mutex<()>,
    workers: usize,
}

impl WorkerPool {
    /// Build a pool of `workers.max(1)` logical workers (spawning
    /// `workers - 1` OS threads). This is the only place threads are
    /// created — see [`total_threads_spawned`].
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                remaining: 0,
                shutdown: false,
                panicked: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers - 1);
        for i in 0..workers - 1 {
            let sh = Arc::clone(&shared);
            THREADS_SPAWNED.fetch_add(1, Ordering::SeqCst);
            let h = std::thread::Builder::new()
                .name(format!("nanogns-worker-{i}"))
                .spawn(move || worker_loop(&sh, i, workers))
                .expect("spawn pool worker");
            handles.push(h);
        }
        WorkerPool {
            shared,
            handles,
            run_guard: Mutex::new(()),
            workers,
        }
    }

    /// Pool built from [`default_workers`].
    pub fn with_default_workers() -> Self {
        Self::new(default_workers())
    }

    /// Logical worker count (calling thread included).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute `task(0..n_tasks)` across the pool and the calling thread,
    /// returning after every task finished. Task `ti` runs on a thread
    /// determined only by `ti % workers`, and the dispatch allocates
    /// nothing on the heap. Panics inside tasks are captured and
    /// re-raised here after all workers parked again.
    pub fn run(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if self.handles.is_empty() || n_tasks == 1 {
            for ti in 0..n_tasks {
                task(ti);
            }
            return;
        }
        let _guard = self.run_guard.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(Job {
                task: task as *const (dyn Fn(usize) + Sync),
                n_tasks,
            });
            st.remaining = self.handles.len();
            self.shared.work_cv.notify_all();
        }
        // The caller takes the stride starting at task 0. Its panic (if
        // any) is deferred until every worker acked, so the closure
        // borrow published above is never outlived.
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut ti = 0;
            while ti < n_tasks {
                task(ti);
                ti += self.workers;
            }
        }));
        let worker_panicked = {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining != 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            std::mem::replace(&mut st.panicked, false)
        };
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        assert!(!worker_panicked, "pool worker panicked during parallel region");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer wrapper that lets disjoint sub-slices of one `&mut [T]`
/// be re-materialized inside pool tasks. Sound because every task owns a
/// non-overlapping row range and the pool joins before `run` returns.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Run `f(row0, row1, out_block)` over disjoint row blocks of `out`
/// (`rows` rows of `row_len` elements), one block per logical worker.
/// Deterministic: block boundaries depend only on
/// `(rows, pool.workers())` and blocks never overlap.
pub fn par_row_blocks<T, F>(pool: &WorkerPool, rows: usize, row_len: usize, out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert!(out.len() >= rows * row_len, "output too small: {} < {}", out.len(), rows * row_len);
    if rows == 0 {
        return;
    }
    let per = chunk_rows(rows, pool.workers());
    if per >= rows {
        f(0, rows, &mut out[..rows * row_len]);
        return;
    }
    let n_tasks = rows.div_ceil(per);
    let base = SendPtr(out.as_mut_ptr());
    pool.run(n_tasks, &|ti| {
        let r0 = ti * per;
        let r1 = (r0 + per).min(rows);
        // SAFETY: tasks cover disjoint `[r0, r1)` row ranges and the
        // pool joins every task before `run` returns, so each block is
        // an exclusive, live sub-slice of `out`.
        let block = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(r0 * row_len), (r1 - r0) * row_len)
        };
        f(r0, r1, block);
    });
}

/// Two-output variant of [`par_row_blocks`]: both buffers are split by
/// the same row boundaries (with independent row lengths) and handed to
/// `f(row0, row1, a_block, b_block)`.
pub fn par_row_blocks2<T, U, F>(
    pool: &WorkerPool,
    rows: usize,
    a_row_len: usize,
    a: &mut [T],
    b_row_len: usize,
    b: &mut [U],
    f: F,
) where
    T: Send,
    U: Send,
    F: Fn(usize, usize, &mut [T], &mut [U]) + Sync,
{
    assert!(a.len() >= rows * a_row_len, "output A too small");
    assert!(b.len() >= rows * b_row_len, "output B too small");
    if rows == 0 {
        return;
    }
    let per = chunk_rows(rows, pool.workers());
    if per >= rows {
        f(0, rows, &mut a[..rows * a_row_len], &mut b[..rows * b_row_len]);
        return;
    }
    let n_tasks = rows.div_ceil(per);
    let base_a = SendPtr(a.as_mut_ptr());
    let base_b = SendPtr(b.as_mut_ptr());
    pool.run(n_tasks, &|ti| {
        let r0 = ti * per;
        let r1 = (r0 + per).min(rows);
        // SAFETY: as in `par_row_blocks` — disjoint row ranges, joined
        // before `run` returns, for both buffers.
        let (ba, bb) = unsafe {
            (
                std::slice::from_raw_parts_mut(base_a.0.add(r0 * a_row_len), (r1 - r0) * a_row_len),
                std::slice::from_raw_parts_mut(base_b.0.add(r0 * b_row_len), (r1 - r0) * b_row_len),
            )
        };
        f(r0, r1, ba, bb);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_row_exactly_once() {
        for workers in [1, 2, 3, 5, 16] {
            let pool = WorkerPool::new(workers);
            for rows in [0usize, 1, 2, 7, 16] {
                let mut out = vec![0u32; rows * 3];
                par_row_blocks(&pool, rows, 3, &mut out, |r0, r1, block| {
                    assert_eq!(block.len(), (r1 - r0) * 3);
                    for v in block.iter_mut() {
                        *v += 1;
                    }
                });
                assert!(out.iter().all(|&v| v == 1), "workers={workers} rows={rows}");
            }
        }
    }

    #[test]
    fn block_indices_match_slices() {
        let pool = WorkerPool::new(3);
        let rows = 11;
        let mut out = vec![0usize; rows * 2];
        par_row_blocks(&pool, rows, 2, &mut out, |r0, r1, block| {
            for (i, chunk) in block.chunks_mut(2).enumerate() {
                chunk[0] = r0 + i;
                chunk[1] = r1;
            }
        });
        for r in 0..rows {
            assert_eq!(out[r * 2], r);
            assert!(out[r * 2 + 1] > r);
        }
    }

    #[test]
    fn two_output_variant_splits_consistently() {
        let pool = WorkerPool::new(4);
        let rows = 9;
        let mut a = vec![0f32; rows * 4];
        let mut b = vec![0f64; rows];
        par_row_blocks2(&pool, rows, 4, &mut a, 1, &mut b, |r0, r1, ab, bb| {
            assert_eq!(ab.len(), (r1 - r0) * 4);
            assert_eq!(bb.len(), r1 - r0);
            for v in ab.iter_mut() {
                *v = r0 as f32;
            }
            for v in bb.iter_mut() {
                *v = r1 as f64;
            }
        });
        assert!(a.iter().all(|&v| v >= 0.0));
        assert!(b.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn pool_runs_every_task_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let pool = WorkerPool::new(4);
        for n_tasks in [0usize, 1, 2, 3, 4, 7, 9] {
            let hits: Vec<AtomicUsize> = (0..n_tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n_tasks, &|ti| {
                hits[ti].fetch_add(1, Ordering::SeqCst);
            });
            for (ti, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "n_tasks={n_tasks} ti={ti}");
            }
        }
    }

    #[test]
    fn pool_spawns_threads_only_at_construction() {
        let pool = WorkerPool::new(3);
        let after_new = total_threads_spawned();
        for _ in 0..50 {
            let mut out = vec![0u8; 64];
            par_row_blocks(&pool, 16, 4, &mut out, |_, _, block| {
                for v in block.iter_mut() {
                    *v = 1;
                }
            });
        }
        // The global counter may move if *other* tests build pools
        // concurrently, so assert through this pool only: it holds the
        // same worker handles it was born with, and a second pool (made
        // serially here) is what bumps the counter again.
        assert_eq!(pool.handles.len(), 2);
        let second = WorkerPool::new(2);
        assert!(total_threads_spawned() >= after_new + 1);
        drop(second);
    }

    #[test]
    fn pool_survives_and_reports_task_panic() {
        let pool = WorkerPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(3, &|ti| {
                if ti == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic inside a task must propagate");
        // The pool stays usable after a captured panic.
        let mut out = vec![0u32; 8];
        par_row_blocks(&pool, 8, 1, &mut out, |_, _, block| {
            for v in block.iter_mut() {
                *v = 7;
            }
        });
        assert!(out.iter().all(|&v| v == 7));
    }
}
