//! Blocked batched matmul kernels for the fused reference backend.
//!
//! All linear layers flatten the microbatch to a single `[B·T, K] × [K, N]`
//! contraction. Three shapes cover forward and backward:
//!
//! * [`matmul_xwt`] — `y = x @ w (+ b)` with the weight packed transposed
//!   (`wt: [N, K]`), so every output element is one contiguous dot product;
//! * [`matmul_xw_t`] — `dx = dy @ w^T` using `w` in its natural `[K, N]`
//!   layout (rows of `w` are already the contiguous operand);
//! * [`matmul_at_b_acc`] — `dw += x^T @ dy` from a pre-transposed
//!   `xt: [K, B·T]`, threaded over disjoint rows of `dw`.
//!
//! Inner loops dispatch through [`super::simd`]: explicit AVX2/FMA or
//! NEON dot/axpy kernels, with the original 8-lane scalar code as the
//! always-compiled oracle (`NANOGNS_FORCE_SCALAR=1`). The two dot-product
//! matmuls are register-blocked four output columns at a time
//! ([`super::simd::dots4`] shares each `x` load across four accumulator
//! chains) and tiled over output columns so the active slice of the
//! packed weight stays cache-resident while it is reused by every row of
//! the block ([`tile_cols`]).
//!
//! Determinism: each output element's reduction association depends only
//! on the operand length and the dispatch tier — never on worker count
//! or tile boundaries — so results are bitwise identical for any worker
//! count within a tier (see `threads`).

use super::simd::{self, Tier};
use super::threads::{par_row_blocks, WorkerPool};

pub use super::simd::dot;

/// Output-column tile width for the dot-product matmuls: the widest
/// multiple of four whose packed-weight slice (`cols × k` f32) fits in
/// ~256 KiB — roughly half a typical per-core L2, leaving room for the
/// streamed activation rows. Tiling changes only the *visit order* of
/// `(row, col)` pairs, never a reduction, so it cannot affect values.
fn tile_cols(k: usize) -> usize {
    const TILE_BYTES: usize = 256 * 1024;
    let per_col = 4 * k.max(1);
    let jt = (TILE_BYTES / per_col).max(8);
    (jt / 4) * 4
}

/// `dst = src^T`: `src` is `[rows, cols]` row-major, `dst` becomes
/// `[cols, rows]`. Used to pack weights (forward) and activations
/// (backward) into the layout the dot-product kernels stream.
pub fn transpose(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    assert!(src.len() >= rows * cols && dst.len() >= rows * cols);
    for r in 0..rows {
        let srow = &src[r * cols..(r + 1) * cols];
        for c in 0..cols {
            dst[c * rows + r] = srow[c];
        }
    }
}

/// Threaded [`transpose`] for large activation buffers: workers own
/// disjoint destination-row blocks (a pure scatter, no reductions), so
/// the result is bitwise identical to the serial version for any worker
/// count. Weight packs stay on the serial path — they are tiny.
pub fn transpose_par(pool: &WorkerPool, src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    assert!(src.len() >= rows * cols && dst.len() >= rows * cols);
    par_row_blocks(pool, cols, rows, dst, |c0, c1, db| {
        for c in c0..c1 {
            let drow = &mut db[(c - c0) * rows..(c - c0 + 1) * rows];
            for r in 0..rows {
                drow[r] = src[r * cols + c];
            }
        }
    });
}

/// Shared inner loop of the two dot-product matmuls: fill `yrow[j0..j1]`
/// with `xrow · op_rows[j]` (+ optional bias), register-blocked four
/// columns at a time. `op` is the packed operand whose row `j` has
/// length `k`.
#[inline]
fn dot_row_block(
    t: Tier,
    xrow: &[f32],
    op: &[f32],
    k: usize,
    bias: Option<&[f32]>,
    j0: usize,
    j1: usize,
    yrow: &mut [f32],
) {
    let mut j = j0;
    while j + 4 <= j1 {
        let mut o = [0f32; 4];
        simd::dots4(
            t,
            xrow,
            &op[j * k..(j + 1) * k],
            &op[(j + 1) * k..(j + 2) * k],
            &op[(j + 2) * k..(j + 3) * k],
            &op[(j + 3) * k..(j + 4) * k],
            &mut o,
        );
        if let Some(b) = bias {
            for c in 0..4 {
                o[c] += b[j + c];
            }
        }
        yrow[j..j + 4].copy_from_slice(&o);
        j += 4;
    }
    while j < j1 {
        let mut v = simd::dot_tier(t, xrow, &op[j * k..(j + 1) * k]);
        if let Some(b) = bias {
            v += b[j];
        }
        yrow[j] = v;
        j += 1;
    }
}

/// `y = x @ w (+ bias)` with `x: [m, k]`, `wt = w^T: [n, k]`, `y: [m, n]`.
/// Threaded over row blocks of `y`; each element is one contiguous dot.
/// Column-tiled so the `[jt, k]` slice of `wt` stays in cache across the
/// whole row block.
pub fn matmul_xwt(
    pool: &WorkerPool,
    x: &[f32],
    wt: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    assert!(x.len() >= m * k && wt.len() >= n * k && y.len() >= m * n);
    let t = simd::tier();
    let jt = tile_cols(k);
    par_row_blocks(pool, m, n, y, |r0, r1, yb| {
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + jt).min(n);
            for r in r0..r1 {
                let xrow = &x[r * k..(r + 1) * k];
                let yrow = &mut yb[(r - r0) * n..(r - r0 + 1) * n];
                dot_row_block(t, xrow, wt, k, bias, j0, j1, yrow);
            }
            j0 = j1;
        }
    });
}

/// `dx = dy @ w^T` with `dy: [m, n]`, `w: [k, n]` (natural layout),
/// `dx: [m, k]`. Threaded over row blocks of `dx`, tiled over the `k`
/// output columns (rows of `w`).
pub fn matmul_xw_t(
    pool: &WorkerPool,
    dy: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dx: &mut [f32],
) {
    assert!(dy.len() >= m * n && w.len() >= k * n && dx.len() >= m * k);
    let t = simd::tier();
    let kt = tile_cols(n);
    par_row_blocks(pool, m, k, dx, |r0, r1, db| {
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + kt).min(k);
            for r in r0..r1 {
                let dyr = &dy[r * n..(r + 1) * n];
                let drow = &mut db[(r - r0) * k..(r - r0 + 1) * k];
                dot_row_block(t, dyr, w, n, None, k0, k1, drow);
            }
            k0 = k1;
        }
    });
}

/// `dw += x^T @ dy` with `xt = x^T: [k, m]`, `dy: [m, n]`, `dw: [k, n]`.
/// Threaded over disjoint row blocks of `dw`; within each row the
/// reduction over the `m` batch rows runs in fixed order (deterministic).
/// Rows are processed four at a time so each streamed `dy` row updates
/// four output rows via SIMD axpy.
pub fn matmul_at_b_acc(
    pool: &WorkerPool,
    xt: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
) {
    assert!(xt.len() >= k * m && dy.len() >= m * n && dw.len() >= k * n);
    let t = simd::tier();
    par_row_blocks(pool, k, n, dw, |k0, k1, dwb| {
        let mut kk = k0;
        while kk < k1 {
            let kb = (k1 - kk).min(4);
            for r in 0..m {
                let dyr = &dy[r * n..(r + 1) * n];
                for kr in 0..kb {
                    let xv = xt[(kk + kr) * m + r];
                    if xv != 0.0 {
                        let dwr = &mut dwb[(kk + kr - k0) * n..(kk + kr - k0 + 1) * n];
                        simd::axpy(t, xv, dyr, dwr);
                    }
                }
            }
            kk += kb;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_mm(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0f64; m * n];
        for r in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    y[r * n + j] += x[r * k + kk] as f64 * w[kk * n + j] as f64;
                }
            }
        }
        y.into_iter().map(|v| v as f32).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * y.abs().max(1.0), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        let mut rng = Rng::seed_from_u64(1);
        for n in [0usize, 1, 7, 8, 9, 16, 31, 64] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            assert!(
                (dot(&a, &b) as f64 - naive).abs() <= 1e-4 * naive.abs().max(1.0),
                "n={n}"
            );
        }
    }

    #[test]
    fn forward_matches_naive_and_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(2);
        let pool1 = WorkerPool::new(1);
        let pool3 = WorkerPool::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (16, 8, 12), (33, 17, 9)] {
            let x = randv(&mut rng, m * k);
            let w = randv(&mut rng, k * n);
            let bias = randv(&mut rng, n);
            let mut wt = vec![0f32; k * n];
            transpose(&w, k, n, &mut wt);
            let mut want = naive_mm(&x, &w, m, k, n);
            for (v, b) in want.iter_mut().zip(bias.iter().cycle()) {
                *v += b;
            }
            let mut y1 = vec![0f32; m * n];
            matmul_xwt(&pool1, &x, &wt, Some(&bias), m, k, n, &mut y1);
            assert_close(&y1, &want, 1e-4);
            let mut y3 = vec![0f32; m * n];
            matmul_xwt(&pool3, &x, &wt, Some(&bias), m, k, n, &mut y3);
            assert_eq!(y1, y3, "worker count changed the result");
        }
    }

    #[test]
    fn backward_dx_matches_naive() {
        let mut rng = Rng::seed_from_u64(3);
        let pool = WorkerPool::new(2);
        let (m, k, n) = (9, 6, 11);
        let dy = randv(&mut rng, m * n);
        let w = randv(&mut rng, k * n);
        // dx = dy @ w^T  ==  naive_mm(dy, w^T)
        let mut wt = vec![0f32; k * n];
        transpose(&w, k, n, &mut wt);
        let want = naive_mm(&dy, &wt, m, n, k);
        let mut dx = vec![0f32; m * k];
        matmul_xw_t(&pool, &dy, &w, m, k, n, &mut dx);
        assert_close(&dx, &want, 1e-4);
    }

    #[test]
    fn backward_dw_accumulates_and_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(4);
        let pool1 = WorkerPool::new(1);
        let pool3 = WorkerPool::new(3);
        let (m, k, n) = (13, 10, 7);
        let x = randv(&mut rng, m * k);
        let dy = randv(&mut rng, m * n);
        let mut xt = vec![0f32; m * k];
        transpose(&x, m, k, &mut xt);
        // want = x^T @ dy == naive_mm(xt, dy) with xt as [k, m]
        let want = naive_mm(&xt, &dy, k, m, n);
        let mut dw1 = vec![1f32; k * n]; // pre-seeded: kernel must accumulate
        matmul_at_b_acc(&pool1, &xt, &dy, m, k, n, &mut dw1);
        let mut dw3 = vec![1f32; k * n];
        matmul_at_b_acc(&pool3, &xt, &dy, m, k, n, &mut dw3);
        assert_eq!(dw1, dw3);
        let shifted: Vec<f32> = want.iter().map(|v| v + 1.0).collect();
        assert_close(&dw1, &shifted, 1e-4);
    }

    #[test]
    fn column_tiling_never_changes_values() {
        // Shapes straddling the quad boundary and (via tiny k) multiple
        // tiles; compare against an untiled per-element dot_tier oracle.
        let mut rng = Rng::seed_from_u64(40);
        let pool = WorkerPool::new(2);
        let t = simd::tier();
        for (m, k, n) in [(3, 2, 130), (5, 7, 66), (2, 1, 9), (1, 16, 4)] {
            let x = randv(&mut rng, m * k);
            let wt = randv(&mut rng, n * k);
            let mut y = vec![0f32; m * n];
            matmul_xwt(&pool, &x, &wt, None, m, k, n, &mut y);
            for r in 0..m {
                for j in 0..n {
                    let mut o = [0f32; 4];
                    let q = j / 4 * 4;
                    let want = if q + 4 <= n {
                        simd::dots4(
                            t,
                            &x[r * k..(r + 1) * k],
                            &wt[q * k..(q + 1) * k],
                            &wt[(q + 1) * k..(q + 2) * k],
                            &wt[(q + 2) * k..(q + 3) * k],
                            &wt[(q + 3) * k..(q + 4) * k],
                            &mut o,
                        );
                        o[j - q]
                    } else {
                        simd::dot_tier(t, &x[r * k..(r + 1) * k], &wt[j * k..(j + 1) * k])
                    };
                    assert_eq!(y[r * n + j].to_bits(), want.to_bits(), "r={r} j={j}");
                }
            }
        }
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::seed_from_u64(5);
        let (r, c) = (5, 8);
        let src = randv(&mut rng, r * c);
        let mut t = vec![0f32; r * c];
        transpose(&src, r, c, &mut t);
        let mut back = vec![0f32; r * c];
        transpose(&t, c, r, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn threaded_transpose_matches_serial() {
        let mut rng = Rng::seed_from_u64(6);
        for (r, c) in [(1, 1), (7, 3), (16, 9), (33, 12)] {
            let src = randv(&mut rng, r * c);
            let mut serial = vec![0f32; r * c];
            transpose(&src, r, c, &mut serial);
            for workers in [1, 2, 5] {
                let pool = WorkerPool::new(workers);
                let mut par = vec![0f32; r * c];
                transpose_par(&pool, &src, r, c, &mut par);
                assert_eq!(serial, par, "r={r} c={c} workers={workers}");
            }
        }
    }
}
