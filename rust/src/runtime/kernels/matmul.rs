//! Blocked batched matmul kernels for the fused reference backend.
//!
//! All linear layers flatten the microbatch to a single `[B·T, K] × [K, N]`
//! contraction. Three shapes cover forward and backward:
//!
//! * [`matmul_xwt`] — `y = x @ w (+ b)` with the weight packed transposed
//!   (`wt: [N, K]`), so every output element is one contiguous dot product;
//! * [`matmul_xw_t`] — `dx = dy @ w^T` using `w` in its natural `[K, N]`
//!   layout (rows of `w` are already the contiguous operand);
//! * [`matmul_at_b_acc`] — `dw += x^T @ dy` from a pre-transposed
//!   `xt: [K, B·T]`, threaded over disjoint rows of `dw`.
//!
//! Dot products run over eight independent accumulator lanes ([`dot`]) so
//! LLVM can vectorize the f32 reduction (a naive `sum` is a serial
//! dependency chain the compiler must not reorder). Lane order is fixed,
//! so results are bitwise deterministic for any worker count — each
//! parallel region writes disjoint output rows and reduces inside a row
//! sequentially (see `threads`).

use super::threads::par_row_blocks;

/// Eight-lane blocked dot product. Deterministic (fixed association) and
/// autovectorizable: the eight partial sums have no cross-iteration
/// dependency, unlike a single running f32 sum.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot operand length mismatch");
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let ao = &a[c * 8..c * 8 + 8];
        let bo = &b[c * 8..c * 8 + 8];
        for l in 0..8 {
            acc[l] += ao[l] * bo[l];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// `dst = src^T`: `src` is `[rows, cols]` row-major, `dst` becomes
/// `[cols, rows]`. Used to pack weights (forward) and activations
/// (backward) into the layout the dot-product kernels stream.
pub fn transpose(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    assert!(src.len() >= rows * cols && dst.len() >= rows * cols);
    for r in 0..rows {
        let srow = &src[r * cols..(r + 1) * cols];
        for c in 0..cols {
            dst[c * rows + r] = srow[c];
        }
    }
}

/// Threaded [`transpose`] for large activation buffers: workers own
/// disjoint destination-row blocks (a pure scatter, no reductions), so
/// the result is bitwise identical to the serial version for any worker
/// count. Weight packs stay on the serial path — they are tiny.
pub fn transpose_par(workers: usize, src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    assert!(src.len() >= rows * cols && dst.len() >= rows * cols);
    par_row_blocks(workers, cols, rows, dst, |c0, c1, db| {
        for c in c0..c1 {
            let drow = &mut db[(c - c0) * rows..(c - c0 + 1) * rows];
            for r in 0..rows {
                drow[r] = src[r * cols + c];
            }
        }
    });
}

/// `y = x @ w (+ bias)` with `x: [m, k]`, `wt = w^T: [n, k]`, `y: [m, n]`.
/// Threaded over row blocks of `y`; each element is one contiguous dot.
pub fn matmul_xwt(
    workers: usize,
    x: &[f32],
    wt: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    assert!(x.len() >= m * k && wt.len() >= n * k && y.len() >= m * n);
    par_row_blocks(workers, m, n, y, |r0, r1, yb| {
        for r in r0..r1 {
            let xrow = &x[r * k..(r + 1) * k];
            let yrow = &mut yb[(r - r0) * n..(r - r0 + 1) * n];
            for j in 0..n {
                let mut v = dot(xrow, &wt[j * k..(j + 1) * k]);
                if let Some(b) = bias {
                    v += b[j];
                }
                yrow[j] = v;
            }
        }
    });
}

/// `dx = dy @ w^T` with `dy: [m, n]`, `w: [k, n]` (natural layout),
/// `dx: [m, k]`. Threaded over row blocks of `dx`.
pub fn matmul_xw_t(
    workers: usize,
    dy: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dx: &mut [f32],
) {
    assert!(dy.len() >= m * n && w.len() >= k * n && dx.len() >= m * k);
    par_row_blocks(workers, m, k, dx, |r0, r1, db| {
        for r in r0..r1 {
            let dyr = &dy[r * n..(r + 1) * n];
            let drow = &mut db[(r - r0) * k..(r - r0 + 1) * k];
            for kk in 0..k {
                drow[kk] = dot(dyr, &w[kk * n..(kk + 1) * n]);
            }
        }
    });
}

/// `dw += x^T @ dy` with `xt = x^T: [k, m]`, `dy: [m, n]`, `dw: [k, n]`.
/// Threaded over disjoint row blocks of `dw`; within each row the
/// reduction over the `m` batch rows runs in fixed order (deterministic).
/// Rows are processed four at a time so each streamed `dy` row updates
/// four output rows.
pub fn matmul_at_b_acc(
    workers: usize,
    xt: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
) {
    assert!(xt.len() >= k * m && dy.len() >= m * n && dw.len() >= k * n);
    par_row_blocks(workers, k, n, dw, |k0, k1, dwb| {
        let mut kk = k0;
        while kk < k1 {
            let kb = (k1 - kk).min(4);
            for r in 0..m {
                let dyr = &dy[r * n..(r + 1) * n];
                for kr in 0..kb {
                    let xv = xt[(kk + kr) * m + r];
                    if xv != 0.0 {
                        let dwr = &mut dwb[(kk + kr - k0) * n..(kk + kr - k0 + 1) * n];
                        for j in 0..n {
                            dwr[j] += xv * dyr[j];
                        }
                    }
                }
            }
            kk += kb;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_mm(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0f64; m * n];
        for r in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    y[r * n + j] += x[r * k + kk] as f64 * w[kk * n + j] as f64;
                }
            }
        }
        y.into_iter().map(|v| v as f32).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * y.abs().max(1.0), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        let mut rng = Rng::seed_from_u64(1);
        for n in [0usize, 1, 7, 8, 9, 16, 31, 64] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            assert!(
                (dot(&a, &b) as f64 - naive).abs() <= 1e-4 * naive.abs().max(1.0),
                "n={n}"
            );
        }
    }

    #[test]
    fn forward_matches_naive_and_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(2);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (16, 8, 12), (33, 17, 9)] {
            let x = randv(&mut rng, m * k);
            let w = randv(&mut rng, k * n);
            let bias = randv(&mut rng, n);
            let mut wt = vec![0f32; k * n];
            transpose(&w, k, n, &mut wt);
            let mut want = naive_mm(&x, &w, m, k, n);
            for (v, b) in want.iter_mut().zip(bias.iter().cycle()) {
                *v += b;
            }
            let mut y1 = vec![0f32; m * n];
            matmul_xwt(1, &x, &wt, Some(&bias), m, k, n, &mut y1);
            assert_close(&y1, &want, 1e-4);
            let mut y3 = vec![0f32; m * n];
            matmul_xwt(3, &x, &wt, Some(&bias), m, k, n, &mut y3);
            assert_eq!(y1, y3, "worker count changed the result");
        }
    }

    #[test]
    fn backward_dx_matches_naive() {
        let mut rng = Rng::seed_from_u64(3);
        let (m, k, n) = (9, 6, 11);
        let dy = randv(&mut rng, m * n);
        let w = randv(&mut rng, k * n);
        // dx = dy @ w^T  ==  naive_mm(dy, w^T)
        let mut wt = vec![0f32; k * n];
        transpose(&w, k, n, &mut wt);
        let want = naive_mm(&dy, &wt, m, n, k);
        let mut dx = vec![0f32; m * k];
        matmul_xw_t(2, &dy, &w, m, k, n, &mut dx);
        assert_close(&dx, &want, 1e-4);
    }

    #[test]
    fn backward_dw_accumulates_and_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(4);
        let (m, k, n) = (13, 10, 7);
        let x = randv(&mut rng, m * k);
        let dy = randv(&mut rng, m * n);
        let mut xt = vec![0f32; m * k];
        transpose(&x, m, k, &mut xt);
        // want = x^T @ dy == naive_mm(xt, dy) with xt as [k, m]
        let want = naive_mm(&xt, &dy, k, m, n);
        let mut dw1 = vec![1f32; k * n]; // pre-seeded: kernel must accumulate
        matmul_at_b_acc(1, &xt, &dy, m, k, n, &mut dw1);
        let mut dw3 = vec![1f32; k * n];
        matmul_at_b_acc(3, &xt, &dy, m, k, n, &mut dw3);
        assert_eq!(dw1, dw3);
        let shifted: Vec<f32> = want.iter().map(|v| v + 1.0).collect();
        assert_close(&dw1, &shifted, 1e-4);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::seed_from_u64(5);
        let (r, c) = (5, 8);
        let src = randv(&mut rng, r * c);
        let mut t = vec![0f32; r * c];
        transpose(&src, r, c, &mut t);
        let mut back = vec![0f32; r * c];
        transpose(&t, c, r, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn threaded_transpose_matches_serial() {
        let mut rng = Rng::seed_from_u64(6);
        for (r, c) in [(1, 1), (7, 3), (16, 9), (33, 12)] {
            let src = randv(&mut rng, r * c);
            let mut serial = vec![0f32; r * c];
            transpose(&src, r, c, &mut serial);
            for workers in [1, 2, 5] {
                let mut par = vec![0f32; r * c];
                transpose_par(workers, &src, r, c, &mut par);
                assert_eq!(serial, par, "r={r} c={c} workers={workers}");
            }
        }
    }
}
