//! Runtime-dispatched SIMD primitives with an always-compiled scalar
//! oracle.
//!
//! Every hot inner loop in the kernels (dot products, axpy updates, the
//! LayerNorm row passes) funnels through this module. A dispatch *tier*
//! is picked once per process:
//!
//! | tier      | arch      | gate                                      |
//! |-----------|-----------|-------------------------------------------|
//! | `Avx2Fma` | x86_64    | `is_x86_feature_detected!("avx2"+"fma")`  |
//! | `Neon`    | aarch64   | baseline (NEON is mandatory on aarch64)   |
//! | `Scalar`  | any       | fallback, or `NANOGNS_FORCE_SCALAR=1`     |
//!
//! The scalar functions are byte-for-byte the pre-SIMD kernels (the
//! 8-lane blocked dot, the serial LayerNorm row loops), kept compiled on
//! every arch as the oracle: property tests assert each SIMD tier agrees
//! with the scalar tier to tight relative error, and
//! `NANOGNS_FORCE_SCALAR=1` runs the entire suite through the oracle.
//!
//! Determinism: within one tier every function uses a fixed reduction
//! association for a given input length, so kernel results remain
//! bitwise worker-count invariant *per tier*. Across tiers results may
//! differ by rounding (FMA contracts the multiply-add), which is why the
//! CI determinism matrix pins the tier via `NANOGNS_FORCE_SCALAR`.

use std::sync::OnceLock;

/// Instruction-set tier the kernels dispatch to. All variants exist on
/// every arch (so tables/logs can name them); `detect` only ever returns
/// a tier the current CPU can execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Always-compiled oracle: the pre-SIMD autovectorizable loops.
    Scalar,
    /// x86_64 with AVX2 + FMA (256-bit, 8 × f32 lanes).
    Avx2Fma,
    /// aarch64 NEON (128-bit, 4 × f32 lanes).
    Neon,
}

impl Tier {
    /// Stable lowercase name for logs and bench reports.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2Fma => "avx2+fma",
            Tier::Neon => "neon",
        }
    }
}

static TIER: OnceLock<Tier> = OnceLock::new();

/// The process-wide dispatch tier: detected once, honoring
/// `NANOGNS_FORCE_SCALAR` (set to `1`/`true` to pin the scalar oracle).
/// Cached — changing the environment after the first call has no effect.
pub fn tier() -> Tier {
    *TIER.get_or_init(detect)
}

/// The best tier this CPU can execute, ignoring `NANOGNS_FORCE_SCALAR`.
/// `None` when only the scalar oracle is available. Tests use this to
/// exercise the native tier even inside a force-scalar run.
pub fn native_tier() -> Option<Tier> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            Some(Tier::Avx2Fma)
        } else {
            None
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Some(Tier::Neon)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        None
    }
}

fn detect() -> Tier {
    if let Ok(v) = std::env::var("NANOGNS_FORCE_SCALAR") {
        let v = v.trim();
        if v == "1" || v.eq_ignore_ascii_case("true") {
            return Tier::Scalar;
        }
    }
    native_tier().unwrap_or(Tier::Scalar)
}

// ---------------------------------------------------------------------------
// Scalar oracle (the pre-SIMD kernels, unchanged bit-for-bit)
// ---------------------------------------------------------------------------

/// Eight-lane blocked dot product. Deterministic (fixed association) and
/// autovectorizable: the eight partial sums have no cross-iteration
/// dependency, unlike a single running f32 sum.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot operand length mismatch");
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let ao = &a[c * 8..c * 8 + 8];
        let bo = &b[c * 8..c * 8 + 8];
        for l in 0..8 {
            acc[l] += ao[l] * bo[l];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

#[inline]
fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    for j in 0..n {
        y[j] += a * x[j];
    }
}

#[inline]
fn sum_scalar(a: &[f32]) -> f32 {
    let mut s = 0f32;
    for &v in a {
        s += v;
    }
    s
}

#[inline]
fn sq_dev_sum_scalar(a: &[f32], mean: f32) -> f32 {
    let mut s = 0f32;
    for &v in a {
        s += (v - mean) * (v - mean);
    }
    s
}

#[inline]
fn ln_fwd_row_scalar(
    row: &[f32],
    gamma: &[f32],
    beta: &[f32],
    mean: f32,
    rs: f32,
    xhat: &mut [f32],
    out: &mut [f32],
) {
    let d = row.len();
    for j in 0..d {
        let xh = (row[j] - mean) * rs;
        xhat[j] = xh;
        out[j] = gamma[j] * xh + beta[j];
    }
}

/// Accumulates `slg[j] += dy·xh`, `slb[j] += dy` and returns the raw
/// sums `(Σ dy·γ, Σ (dy·γ)·xh)` — the caller divides by `d`.
#[inline]
fn ln_bwd_row_acc_scalar(
    dy: &[f32],
    xh: &[f32],
    gamma: &[f32],
    slg: &mut [f32],
    slb: &mut [f32],
) -> (f32, f32) {
    let d = dy.len();
    let mut m1 = 0f32;
    let mut m2 = 0f32;
    for j in 0..d {
        let dyj = dy[j];
        let xhj = xh[j];
        slg[j] += dyj * xhj;
        slb[j] += dyj;
        let dxh = dyj * gamma[j];
        m1 += dxh;
        m2 += dxh * xhj;
    }
    (m1, m2)
}

#[inline]
fn ln_dx_row_scalar(
    dy: &[f32],
    xh: &[f32],
    gamma: &[f32],
    rs: f32,
    m1: f32,
    m2: f32,
    dx: &mut [f32],
) {
    let d = dy.len();
    for j in 0..d {
        let dxh = dy[j] * gamma[j];
        dx[j] = rs * (dxh - m1 - xh[j] * m2);
    }
}

#[inline]
fn rms_fwd_row_scalar(row: &[f32], gamma: &[f32], r: f32, xhat: &mut [f32], out: &mut [f32]) {
    let d = row.len();
    for j in 0..d {
        let xh = row[j] * r;
        xhat[j] = xh;
        out[j] = gamma[j] * xh;
    }
}

/// Accumulates `slg[j] += dy·xh` and returns the raw `Σ (dy·γ)·xh` — the
/// caller divides by `d`. RMSNorm has no `β` and no mean term, so this is
/// [`ln_bwd_row_acc_scalar`] minus the `slb`/`m1` work.
#[inline]
fn rms_bwd_row_acc_scalar(dy: &[f32], xh: &[f32], gamma: &[f32], slg: &mut [f32]) -> f32 {
    let d = dy.len();
    let mut m2 = 0f32;
    for j in 0..d {
        let dyj = dy[j];
        let xhj = xh[j];
        slg[j] += dyj * xhj;
        m2 += (dyj * gamma[j]) * xhj;
    }
    m2
}

// ---------------------------------------------------------------------------
// AVX2 + FMA (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Horizontal sum of the 8 lanes in a fixed tree order.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn hsum8(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_movehdup_ps(s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 16)),
                _mm256_loadu_ps(bp.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 24)),
                _mm256_loadu_ps(bp.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
        let mut s = hsum8(acc);
        while i < n {
            s = (*ap.add(i)).mul_add(*bp.add(i), s);
            i += 1;
        }
        s
    }

    /// Four dot products against one shared `x` row: each `x` load feeds
    /// four FMA chains, quadrupling arithmetic intensity per load.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dots4(
        x: &[f32],
        w0: &[f32],
        w1: &[f32],
        w2: &[f32],
        w3: &[f32],
        out: &mut [f32; 4],
    ) {
        let k = x.len();
        let xp = x.as_ptr();
        let (p0, p1, p2, p3) = (w0.as_ptr(), w1.as_ptr(), w2.as_ptr(), w3.as_ptr());
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= k {
            let xv = _mm256_loadu_ps(xp.add(i));
            a0 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p0.add(i)), a0);
            a1 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p1.add(i)), a1);
            a2 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p2.add(i)), a2);
            a3 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p3.add(i)), a3);
            i += 8;
        }
        let mut s0 = hsum8(a0);
        let mut s1 = hsum8(a1);
        let mut s2 = hsum8(a2);
        let mut s3 = hsum8(a3);
        while i < k {
            let xv = *xp.add(i);
            s0 = xv.mul_add(*p0.add(i), s0);
            s1 = xv.mul_add(*p1.add(i), s1);
            s2 = xv.mul_add(*p2.add(i), s2);
            s3 = xv.mul_add(*p3.add(i), s3);
            i += 1;
        }
        *out = [s0, s1, s2, s3];
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let va = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let yv = _mm256_fmadd_ps(va, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(yp.add(i), yv);
            i += 8;
        }
        while i < n {
            *yp.add(i) = a.mul_add(*xp.add(i), *yp.add(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sum(a: &[f32]) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(ap.add(i)));
            i += 8;
        }
        let mut s = hsum8(acc);
        while i < n {
            s += *ap.add(i);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sq_dev_sum(a: &[f32], mean: f32) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let vm = _mm256_set1_ps(mean);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(ap.add(i)), vm);
            acc = _mm256_fmadd_ps(d, d, acc);
            i += 8;
        }
        let mut s = hsum8(acc);
        while i < n {
            let d = *ap.add(i) - mean;
            s = d.mul_add(d, s);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn ln_fwd_row(
        row: &[f32],
        gamma: &[f32],
        beta: &[f32],
        mean: f32,
        rs: f32,
        xhat: &mut [f32],
        out: &mut [f32],
    ) {
        let d = row.len();
        let rp = row.as_ptr();
        let gp = gamma.as_ptr();
        let bp = beta.as_ptr();
        let xhp = xhat.as_mut_ptr();
        let op = out.as_mut_ptr();
        let vm = _mm256_set1_ps(mean);
        let vrs = _mm256_set1_ps(rs);
        let mut i = 0usize;
        while i + 8 <= d {
            let xh = _mm256_mul_ps(_mm256_sub_ps(_mm256_loadu_ps(rp.add(i)), vm), vrs);
            _mm256_storeu_ps(xhp.add(i), xh);
            let o = _mm256_fmadd_ps(_mm256_loadu_ps(gp.add(i)), xh, _mm256_loadu_ps(bp.add(i)));
            _mm256_storeu_ps(op.add(i), o);
            i += 8;
        }
        while i < d {
            let xh = (*rp.add(i) - mean) * rs;
            *xhp.add(i) = xh;
            *op.add(i) = (*gp.add(i)).mul_add(xh, *bp.add(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn ln_bwd_row_acc(
        dy: &[f32],
        xh: &[f32],
        gamma: &[f32],
        slg: &mut [f32],
        slb: &mut [f32],
    ) -> (f32, f32) {
        let d = dy.len();
        let dp = dy.as_ptr();
        let xp = xh.as_ptr();
        let gp = gamma.as_ptr();
        let sgp = slg.as_mut_ptr();
        let sbp = slb.as_mut_ptr();
        let mut m1 = _mm256_setzero_ps();
        let mut m2 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= d {
            let vdy = _mm256_loadu_ps(dp.add(i));
            let vxh = _mm256_loadu_ps(xp.add(i));
            _mm256_storeu_ps(sgp.add(i), _mm256_fmadd_ps(vdy, vxh, _mm256_loadu_ps(sgp.add(i))));
            _mm256_storeu_ps(sbp.add(i), _mm256_add_ps(vdy, _mm256_loadu_ps(sbp.add(i))));
            let dxh = _mm256_mul_ps(vdy, _mm256_loadu_ps(gp.add(i)));
            m1 = _mm256_add_ps(m1, dxh);
            m2 = _mm256_fmadd_ps(dxh, vxh, m2);
            i += 8;
        }
        let mut s1 = hsum8(m1);
        let mut s2 = hsum8(m2);
        while i < d {
            let dyj = *dp.add(i);
            let xhj = *xp.add(i);
            *sgp.add(i) = dyj.mul_add(xhj, *sgp.add(i));
            *sbp.add(i) += dyj;
            let dxh = dyj * *gp.add(i);
            s1 += dxh;
            s2 = dxh.mul_add(xhj, s2);
            i += 1;
        }
        (s1, s2)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn ln_dx_row(
        dy: &[f32],
        xh: &[f32],
        gamma: &[f32],
        rs: f32,
        m1: f32,
        m2: f32,
        dx: &mut [f32],
    ) {
        let d = dy.len();
        let dp = dy.as_ptr();
        let xp = xh.as_ptr();
        let gp = gamma.as_ptr();
        let op = dx.as_mut_ptr();
        let vm1 = _mm256_set1_ps(m1);
        let vm2 = _mm256_set1_ps(m2);
        let vrs = _mm256_set1_ps(rs);
        let mut i = 0usize;
        while i + 8 <= d {
            let dxh = _mm256_mul_ps(_mm256_loadu_ps(dp.add(i)), _mm256_loadu_ps(gp.add(i)));
            let t = _mm256_sub_ps(
                _mm256_sub_ps(dxh, vm1),
                _mm256_mul_ps(_mm256_loadu_ps(xp.add(i)), vm2),
            );
            _mm256_storeu_ps(op.add(i), _mm256_mul_ps(vrs, t));
            i += 8;
        }
        while i < d {
            let dxh = *dp.add(i) * *gp.add(i);
            *op.add(i) = rs * (dxh - m1 - *xp.add(i) * m2);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn rms_fwd_row(
        row: &[f32],
        gamma: &[f32],
        r: f32,
        xhat: &mut [f32],
        out: &mut [f32],
    ) {
        let d = row.len();
        let rp = row.as_ptr();
        let gp = gamma.as_ptr();
        let xhp = xhat.as_mut_ptr();
        let op = out.as_mut_ptr();
        let vr = _mm256_set1_ps(r);
        let mut i = 0usize;
        while i + 8 <= d {
            let xh = _mm256_mul_ps(_mm256_loadu_ps(rp.add(i)), vr);
            _mm256_storeu_ps(xhp.add(i), xh);
            _mm256_storeu_ps(op.add(i), _mm256_mul_ps(_mm256_loadu_ps(gp.add(i)), xh));
            i += 8;
        }
        while i < d {
            let xh = *rp.add(i) * r;
            *xhp.add(i) = xh;
            *op.add(i) = *gp.add(i) * xh;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn rms_bwd_row_acc(dy: &[f32], xh: &[f32], gamma: &[f32], slg: &mut [f32]) -> f32 {
        let d = dy.len();
        let dp = dy.as_ptr();
        let xp = xh.as_ptr();
        let gp = gamma.as_ptr();
        let sgp = slg.as_mut_ptr();
        let mut m2 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= d {
            let vdy = _mm256_loadu_ps(dp.add(i));
            let vxh = _mm256_loadu_ps(xp.add(i));
            _mm256_storeu_ps(sgp.add(i), _mm256_fmadd_ps(vdy, vxh, _mm256_loadu_ps(sgp.add(i))));
            let dxh = _mm256_mul_ps(vdy, _mm256_loadu_ps(gp.add(i)));
            m2 = _mm256_fmadd_ps(dxh, vxh, m2);
            i += 8;
        }
        let mut s2 = hsum8(m2);
        while i < d {
            let dyj = *dp.add(i);
            let xhj = *xp.add(i);
            *sgp.add(i) = dyj.mul_add(xhj, *sgp.add(i));
            let dxh = dyj * *gp.add(i);
            s2 = dxh.mul_add(xhj, s2);
            i += 1;
        }
        s2
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut acc2 = vdupq_n_f32(0.0);
        let mut acc3 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
            acc2 = vfmaq_f32(acc2, vld1q_f32(ap.add(i + 8)), vld1q_f32(bp.add(i + 8)));
            acc3 = vfmaq_f32(acc3, vld1q_f32(ap.add(i + 12)), vld1q_f32(bp.add(i + 12)));
            i += 16;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
            i += 4;
        }
        let acc = vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3));
        let mut s = vaddvq_f32(acc);
        while i < n {
            s = (*ap.add(i)).mul_add(*bp.add(i), s);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dots4(
        x: &[f32],
        w0: &[f32],
        w1: &[f32],
        w2: &[f32],
        w3: &[f32],
        out: &mut [f32; 4],
    ) {
        let k = x.len();
        let xp = x.as_ptr();
        let (p0, p1, p2, p3) = (w0.as_ptr(), w1.as_ptr(), w2.as_ptr(), w3.as_ptr());
        let mut a0 = vdupq_n_f32(0.0);
        let mut a1 = vdupq_n_f32(0.0);
        let mut a2 = vdupq_n_f32(0.0);
        let mut a3 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= k {
            let xv = vld1q_f32(xp.add(i));
            a0 = vfmaq_f32(a0, xv, vld1q_f32(p0.add(i)));
            a1 = vfmaq_f32(a1, xv, vld1q_f32(p1.add(i)));
            a2 = vfmaq_f32(a2, xv, vld1q_f32(p2.add(i)));
            a3 = vfmaq_f32(a3, xv, vld1q_f32(p3.add(i)));
            i += 4;
        }
        let mut s0 = vaddvq_f32(a0);
        let mut s1 = vaddvq_f32(a1);
        let mut s2 = vaddvq_f32(a2);
        let mut s3 = vaddvq_f32(a3);
        while i < k {
            let xv = *xp.add(i);
            s0 = xv.mul_add(*p0.add(i), s0);
            s1 = xv.mul_add(*p1.add(i), s1);
            s2 = xv.mul_add(*p2.add(i), s2);
            s3 = xv.mul_add(*p3.add(i), s3);
            i += 1;
        }
        *out = [s0, s1, s2, s3];
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let va = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(yp.add(i), vfmaq_f32(vld1q_f32(yp.add(i)), va, vld1q_f32(xp.add(i))));
            i += 4;
        }
        while i < n {
            *yp.add(i) = a.mul_add(*xp.add(i), *yp.add(i));
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum(a: &[f32]) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            acc = vaddq_f32(acc, vld1q_f32(ap.add(i)));
            i += 4;
        }
        let mut s = vaddvq_f32(acc);
        while i < n {
            s += *ap.add(i);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sq_dev_sum(a: &[f32], mean: f32) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let vm = vdupq_n_f32(mean);
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            let d = vsubq_f32(vld1q_f32(ap.add(i)), vm);
            acc = vfmaq_f32(acc, d, d);
            i += 4;
        }
        let mut s = vaddvq_f32(acc);
        while i < n {
            let d = *ap.add(i) - mean;
            s = d.mul_add(d, s);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn ln_fwd_row(
        row: &[f32],
        gamma: &[f32],
        beta: &[f32],
        mean: f32,
        rs: f32,
        xhat: &mut [f32],
        out: &mut [f32],
    ) {
        let d = row.len();
        let rp = row.as_ptr();
        let gp = gamma.as_ptr();
        let bp = beta.as_ptr();
        let xhp = xhat.as_mut_ptr();
        let op = out.as_mut_ptr();
        let vm = vdupq_n_f32(mean);
        let vrs = vdupq_n_f32(rs);
        let mut i = 0usize;
        while i + 4 <= d {
            let xh = vmulq_f32(vsubq_f32(vld1q_f32(rp.add(i)), vm), vrs);
            vst1q_f32(xhp.add(i), xh);
            let o = vfmaq_f32(vld1q_f32(bp.add(i)), vld1q_f32(gp.add(i)), xh);
            vst1q_f32(op.add(i), o);
            i += 4;
        }
        while i < d {
            let xh = (*rp.add(i) - mean) * rs;
            *xhp.add(i) = xh;
            *op.add(i) = (*gp.add(i)).mul_add(xh, *bp.add(i));
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn ln_bwd_row_acc(
        dy: &[f32],
        xh: &[f32],
        gamma: &[f32],
        slg: &mut [f32],
        slb: &mut [f32],
    ) -> (f32, f32) {
        let d = dy.len();
        let dp = dy.as_ptr();
        let xp = xh.as_ptr();
        let gp = gamma.as_ptr();
        let sgp = slg.as_mut_ptr();
        let sbp = slb.as_mut_ptr();
        let mut m1 = vdupq_n_f32(0.0);
        let mut m2 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= d {
            let vdy = vld1q_f32(dp.add(i));
            let vxh = vld1q_f32(xp.add(i));
            vst1q_f32(sgp.add(i), vfmaq_f32(vld1q_f32(sgp.add(i)), vdy, vxh));
            vst1q_f32(sbp.add(i), vaddq_f32(vld1q_f32(sbp.add(i)), vdy));
            let dxh = vmulq_f32(vdy, vld1q_f32(gp.add(i)));
            m1 = vaddq_f32(m1, dxh);
            m2 = vfmaq_f32(m2, dxh, vxh);
            i += 4;
        }
        let mut s1 = vaddvq_f32(m1);
        let mut s2 = vaddvq_f32(m2);
        while i < d {
            let dyj = *dp.add(i);
            let xhj = *xp.add(i);
            *sgp.add(i) = dyj.mul_add(xhj, *sgp.add(i));
            *sbp.add(i) += dyj;
            let dxh = dyj * *gp.add(i);
            s1 += dxh;
            s2 = dxh.mul_add(xhj, s2);
            i += 1;
        }
        (s1, s2)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn ln_dx_row(
        dy: &[f32],
        xh: &[f32],
        gamma: &[f32],
        rs: f32,
        m1: f32,
        m2: f32,
        dx: &mut [f32],
    ) {
        let d = dy.len();
        let dp = dy.as_ptr();
        let xp = xh.as_ptr();
        let gp = gamma.as_ptr();
        let op = dx.as_mut_ptr();
        let vm1 = vdupq_n_f32(m1);
        let vm2 = vdupq_n_f32(m2);
        let vrs = vdupq_n_f32(rs);
        let mut i = 0usize;
        while i + 4 <= d {
            let dxh = vmulq_f32(vld1q_f32(dp.add(i)), vld1q_f32(gp.add(i)));
            let t = vsubq_f32(vsubq_f32(dxh, vm1), vmulq_f32(vld1q_f32(xp.add(i)), vm2));
            vst1q_f32(op.add(i), vmulq_f32(vrs, t));
            i += 4;
        }
        while i < d {
            let dxh = *dp.add(i) * *gp.add(i);
            *op.add(i) = rs * (dxh - m1 - *xp.add(i) * m2);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn rms_fwd_row(
        row: &[f32],
        gamma: &[f32],
        r: f32,
        xhat: &mut [f32],
        out: &mut [f32],
    ) {
        let d = row.len();
        let rp = row.as_ptr();
        let gp = gamma.as_ptr();
        let xhp = xhat.as_mut_ptr();
        let op = out.as_mut_ptr();
        let vr = vdupq_n_f32(r);
        let mut i = 0usize;
        while i + 4 <= d {
            let xh = vmulq_f32(vld1q_f32(rp.add(i)), vr);
            vst1q_f32(xhp.add(i), xh);
            vst1q_f32(op.add(i), vmulq_f32(vld1q_f32(gp.add(i)), xh));
            i += 4;
        }
        while i < d {
            let xh = *rp.add(i) * r;
            *xhp.add(i) = xh;
            *op.add(i) = *gp.add(i) * xh;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn rms_bwd_row_acc(dy: &[f32], xh: &[f32], gamma: &[f32], slg: &mut [f32]) -> f32 {
        let d = dy.len();
        let dp = dy.as_ptr();
        let xp = xh.as_ptr();
        let gp = gamma.as_ptr();
        let sgp = slg.as_mut_ptr();
        let mut m2 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= d {
            let vdy = vld1q_f32(dp.add(i));
            let vxh = vld1q_f32(xp.add(i));
            vst1q_f32(sgp.add(i), vfmaq_f32(vld1q_f32(sgp.add(i)), vdy, vxh));
            let dxh = vmulq_f32(vdy, vld1q_f32(gp.add(i)));
            m2 = vfmaq_f32(m2, dxh, vxh);
            i += 4;
        }
        let mut s2 = vaddvq_f32(m2);
        while i < d {
            let dyj = *dp.add(i);
            let xhj = *xp.add(i);
            *sgp.add(i) = dyj.mul_add(xhj, *sgp.add(i));
            let dxh = dyj * *gp.add(i);
            s2 = dxh.mul_add(xhj, s2);
            i += 1;
        }
        s2
    }
}

// ---------------------------------------------------------------------------
// Tier dispatch
// ---------------------------------------------------------------------------

/// Dot product under the process-wide [`tier`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_tier(tier(), a, b)
}

/// Dot product under an explicit tier.
#[inline]
pub fn dot_tier(t: Tier, a: &[f32], b: &[f32]) -> f32 {
    match t {
        Tier::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `tier()`/`native_tier()` only yield Avx2Fma when the
        // CPU reports avx2+fma (same for Neon on aarch64 below).
        Tier::Avx2Fma => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Four dot products of one `x` row against four weight rows (register
/// blocking for the matmuls). Scalar tier degrades to four independent
/// [`dot_scalar`] calls, keeping it bitwise identical to the unblocked
/// kernel.
#[inline]
pub fn dots4(
    t: Tier,
    x: &[f32],
    w0: &[f32],
    w1: &[f32],
    w2: &[f32],
    w3: &[f32],
    out: &mut [f32; 4],
) {
    match t {
        Tier::Scalar => {
            out[0] = dot_scalar(x, w0);
            out[1] = dot_scalar(x, w1);
            out[2] = dot_scalar(x, w2);
            out[3] = dot_scalar(x, w3);
        }
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::dots4(x, w0, w1, w2, w3, out) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::dots4(x, w0, w1, w2, w3, out) },
        _ => {
            out[0] = dot_scalar(x, w0);
            out[1] = dot_scalar(x, w1);
            out[2] = dot_scalar(x, w2);
            out[3] = dot_scalar(x, w3);
        }
    }
}

/// `y[j] += a · x[j]`.
#[inline]
pub fn axpy(t: Tier, a: f32, x: &[f32], y: &mut [f32]) {
    match t {
        Tier::Scalar => axpy_scalar(a, x, y),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::axpy(a, x, y) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::axpy(a, x, y) },
        _ => axpy_scalar(a, x, y),
    }
}

/// `Σ a[j]` (LayerNorm mean numerator).
#[inline]
pub fn sum(t: Tier, a: &[f32]) -> f32 {
    match t {
        Tier::Scalar => sum_scalar(a),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::sum(a) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::sum(a) },
        _ => sum_scalar(a),
    }
}

/// `Σ (a[j] − mean)²` (LayerNorm variance numerator).
#[inline]
pub fn sq_dev_sum(t: Tier, a: &[f32], mean: f32) -> f32 {
    match t {
        Tier::Scalar => sq_dev_sum_scalar(a, mean),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::sq_dev_sum(a, mean) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::sq_dev_sum(a, mean) },
        _ => sq_dev_sum_scalar(a, mean),
    }
}

/// LayerNorm forward for one row: writes `xhat` and `γ·xhat + β`.
#[inline]
pub fn ln_fwd_row(
    t: Tier,
    row: &[f32],
    gamma: &[f32],
    beta: &[f32],
    mean: f32,
    rs: f32,
    xhat: &mut [f32],
    out: &mut [f32],
) {
    debug_assert!(xhat.len() >= row.len() && out.len() >= row.len());
    debug_assert!(gamma.len() >= row.len() && beta.len() >= row.len());
    match t {
        Tier::Scalar => ln_fwd_row_scalar(row, gamma, beta, mean, rs, xhat, out),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::ln_fwd_row(row, gamma, beta, mean, rs, xhat, out) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::ln_fwd_row(row, gamma, beta, mean, rs, xhat, out) },
        _ => ln_fwd_row_scalar(row, gamma, beta, mean, rs, xhat, out),
    }
}

/// LayerNorm backward pass 1 for one row: accumulates the per-example
/// `dγ`/`dβ` partial sums and returns the raw `(Σ dxhat, Σ dxhat·xhat)`.
#[inline]
pub fn ln_bwd_row_acc(
    t: Tier,
    dy: &[f32],
    xh: &[f32],
    gamma: &[f32],
    slg: &mut [f32],
    slb: &mut [f32],
) -> (f32, f32) {
    debug_assert!(xh.len() >= dy.len() && gamma.len() >= dy.len());
    debug_assert!(slg.len() >= dy.len() && slb.len() >= dy.len());
    match t {
        Tier::Scalar => ln_bwd_row_acc_scalar(dy, xh, gamma, slg, slb),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::ln_bwd_row_acc(dy, xh, gamma, slg, slb) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::ln_bwd_row_acc(dy, xh, gamma, slg, slb) },
        _ => ln_bwd_row_acc_scalar(dy, xh, gamma, slg, slb),
    }
}

/// LayerNorm backward pass 2 for one row:
/// `dx = rs · (dy·γ − m1 − xhat·m2)`.
#[inline]
pub fn ln_dx_row(
    t: Tier,
    dy: &[f32],
    xh: &[f32],
    gamma: &[f32],
    rs: f32,
    m1: f32,
    m2: f32,
    dx: &mut [f32],
) {
    debug_assert!(xh.len() >= dy.len() && gamma.len() >= dy.len() && dx.len() >= dy.len());
    match t {
        Tier::Scalar => ln_dx_row_scalar(dy, xh, gamma, rs, m1, m2, dx),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::ln_dx_row(dy, xh, gamma, rs, m1, m2, dx) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::ln_dx_row(dy, xh, gamma, rs, m1, m2, dx) },
        _ => ln_dx_row_scalar(dy, xh, gamma, rs, m1, m2, dx),
    }
}

/// RMSNorm forward for one row: writes `xhat = x·r` and `γ·xhat`, where
/// `r = 1/√(mean(x²)+eps)` was computed by the caller (via
/// [`sq_dev_sum`] at `mean = 0`).
#[inline]
pub fn rms_fwd_row(
    t: Tier,
    row: &[f32],
    gamma: &[f32],
    r: f32,
    xhat: &mut [f32],
    out: &mut [f32],
) {
    debug_assert!(xhat.len() >= row.len() && out.len() >= row.len());
    debug_assert!(gamma.len() >= row.len());
    match t {
        Tier::Scalar => rms_fwd_row_scalar(row, gamma, r, xhat, out),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::rms_fwd_row(row, gamma, r, xhat, out) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::rms_fwd_row(row, gamma, r, xhat, out) },
        _ => rms_fwd_row_scalar(row, gamma, r, xhat, out),
    }
}

/// RMSNorm backward pass 1 for one row: accumulates the per-example `dγ`
/// partial sums and returns the raw `Σ (dy·γ)·xhat`. The `dx` pass
/// reuses [`ln_dx_row`] with `m1 = 0` (RMSNorm has no mean term).
#[inline]
pub fn rms_bwd_row_acc(t: Tier, dy: &[f32], xh: &[f32], gamma: &[f32], slg: &mut [f32]) -> f32 {
    debug_assert!(xh.len() >= dy.len() && gamma.len() >= dy.len() && slg.len() >= dy.len());
    match t {
        Tier::Scalar => rms_bwd_row_acc_scalar(dy, xh, gamma, slg),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { avx2::rms_bwd_row_acc(dy, xh, gamma, slg) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::rms_bwd_row_acc(dy, xh, gamma, slg) },
        _ => rms_bwd_row_acc_scalar(dy, xh, gamma, slg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Tiers to exercise: the scalar oracle always, plus the native tier
    /// when the CPU has one (regardless of NANOGNS_FORCE_SCALAR — the
    /// instructions are still executable, only the dispatch is pinned).
    fn tiers() -> Vec<Tier> {
        let mut v = vec![Tier::Scalar];
        if let Some(t) = native_tier() {
            v.push(t);
        }
        v
    }

    /// Lengths crossing every lane boundary: empty, sub-lane, 4/8/16/32
    /// multiples and their ±1 neighbours (the tails).
    const LENS: [usize; 18] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 100];

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1.0)
    }

    #[test]
    fn dot_all_tiers_match_f64_reference() {
        let mut rng = Rng::seed_from_u64(21);
        for n in LENS {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            for t in tiers() {
                let got = dot_tier(t, &a, &b) as f64;
                assert!(rel_close(got, want, 1e-4), "tier={} n={n}: {got} vs {want}", t.name());
            }
        }
    }

    #[test]
    fn dots4_matches_single_dots_per_tier() {
        let mut rng = Rng::seed_from_u64(22);
        for k in LENS {
            let x = randv(&mut rng, k);
            let ws: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut rng, k)).collect();
            for t in tiers() {
                let mut out = [0f32; 4];
                dots4(t, &x, &ws[0], &ws[1], &ws[2], &ws[3], &mut out);
                for c in 0..4 {
                    let single = dot_tier(t, &x, &ws[c]) as f64;
                    assert!(
                        rel_close(out[c] as f64, single, 1e-5),
                        "tier={} k={k} c={c}",
                        t.name()
                    );
                }
            }
        }
    }

    #[test]
    fn scalar_dots4_is_bitwise_single_dot() {
        let mut rng = Rng::seed_from_u64(23);
        for k in LENS {
            let x = randv(&mut rng, k);
            let ws: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut rng, k)).collect();
            let mut out = [0f32; 4];
            dots4(Tier::Scalar, &x, &ws[0], &ws[1], &ws[2], &ws[3], &mut out);
            for c in 0..4 {
                assert_eq!(out[c].to_bits(), dot_scalar(&x, &ws[c]).to_bits(), "k={k} c={c}");
            }
        }
    }

    #[test]
    fn axpy_all_tiers_match_f64_reference() {
        let mut rng = Rng::seed_from_u64(24);
        for n in LENS {
            let x = randv(&mut rng, n);
            let y0 = randv(&mut rng, n);
            let a = rng.normal() as f32;
            for t in tiers() {
                let mut y = y0.clone();
                axpy(t, a, &x, &mut y);
                for j in 0..n {
                    let want = y0[j] as f64 + a as f64 * x[j] as f64;
                    assert!(
                        rel_close(y[j] as f64, want, 1e-5),
                        "tier={} n={n} j={j}",
                        t.name()
                    );
                }
            }
        }
    }

    #[test]
    fn sums_all_tiers_match_f64_reference() {
        let mut rng = Rng::seed_from_u64(25);
        for n in LENS {
            let a = randv(&mut rng, n);
            let want: f64 = a.iter().map(|&v| v as f64).sum();
            let mean = if n == 0 { 0.0 } else { (want / n as f64) as f32 };
            let want_sq: f64 = a.iter().map(|&v| (v as f64 - mean as f64).powi(2)).sum();
            for t in tiers() {
                assert!(rel_close(sum(t, &a) as f64, want, 1e-4), "sum tier={} n={n}", t.name());
                assert!(
                    rel_close(sq_dev_sum(t, &a, mean) as f64, want_sq, 1e-4),
                    "sq_dev tier={} n={n}",
                    t.name()
                );
            }
        }
    }

    #[test]
    fn ln_rows_all_tiers_match_scalar_oracle() {
        let mut rng = Rng::seed_from_u64(26);
        for d in LENS {
            if d == 0 {
                continue;
            }
            let row = randv(&mut rng, d);
            let gamma: Vec<f32> = (0..d).map(|j| 1.0 + 0.05 * j as f32).collect();
            let beta = randv(&mut rng, d);
            let dy = randv(&mut rng, d);
            let mean = sum_scalar(&row) / d as f32;
            let rs = 1.0 / (sq_dev_sum_scalar(&row, mean) / d as f32 + 1e-5).sqrt();

            let mut xh_ref = vec![0f32; d];
            let mut out_ref = vec![0f32; d];
            ln_fwd_row_scalar(&row, &gamma, &beta, mean, rs, &mut xh_ref, &mut out_ref);
            let mut slg_ref = vec![0.1f32; d];
            let mut slb_ref = vec![0.2f32; d];
            let (s1_ref, s2_ref) =
                ln_bwd_row_acc_scalar(&dy, &xh_ref, &gamma, &mut slg_ref, &mut slb_ref);
            let mut dx_ref = vec![0f32; d];
            let (m1_ref, m2_ref) = (s1_ref / d as f32, s2_ref / d as f32);
            ln_dx_row_scalar(&dy, &xh_ref, &gamma, rs, m1_ref, m2_ref, &mut dx_ref);

            for t in tiers() {
                let mut xh = vec![0f32; d];
                let mut out = vec![0f32; d];
                ln_fwd_row(t, &row, &gamma, &beta, mean, rs, &mut xh, &mut out);
                let mut slg = vec![0.1f32; d];
                let mut slb = vec![0.2f32; d];
                let (s1, s2) = ln_bwd_row_acc(t, &dy, &xh, &gamma, &mut slg, &mut slb);
                let mut dx = vec![0f32; d];
                ln_dx_row(t, &dy, &xh, &gamma, rs, s1 / d as f32, s2 / d as f32, &mut dx);
                let checks: [(&str, &[f32], &[f32], f64); 5] = [
                    ("xh", &xh, &xh_ref, 1e-5),
                    ("out", &out, &out_ref, 1e-5),
                    ("slg", &slg, &slg_ref, 1e-4),
                    ("slb", &slb, &slb_ref, 1e-4),
                    ("dx", &dx, &dx_ref, 1e-3),
                ];
                for (what, got, want, tol) in checks {
                    for j in 0..d {
                        assert!(
                            rel_close(got[j] as f64, want[j] as f64, tol),
                            "{what} tier={} d={d} j={j}",
                            t.name()
                        );
                    }
                }
                assert!(rel_close(s1 as f64, s1_ref as f64, 1e-3), "s1 tier={} d={d}", t.name());
                assert!(rel_close(s2 as f64, s2_ref as f64, 1e-3), "s2 tier={} d={d}", t.name());
            }
        }
    }

    #[test]
    fn rms_rows_all_tiers_match_scalar_oracle() {
        let mut rng = Rng::seed_from_u64(27);
        for d in LENS {
            if d == 0 {
                continue;
            }
            let row = randv(&mut rng, d);
            let gamma: Vec<f32> = (0..d).map(|j| 1.0 + 0.05 * j as f32).collect();
            let dy = randv(&mut rng, d);
            // r = 1/sqrt(mean(x²)+eps): sq_dev_sum at mean=0 is Σ x².
            let r = 1.0 / (sq_dev_sum_scalar(&row, 0.0) / d as f32 + 1e-5).sqrt();

            let mut xh_ref = vec![0f32; d];
            let mut out_ref = vec![0f32; d];
            rms_fwd_row_scalar(&row, &gamma, r, &mut xh_ref, &mut out_ref);
            let mut slg_ref = vec![0.1f32; d];
            let s2_ref = rms_bwd_row_acc_scalar(&dy, &xh_ref, &gamma, &mut slg_ref);
            let mut dx_ref = vec![0f32; d];
            ln_dx_row_scalar(&dy, &xh_ref, &gamma, r, 0.0, s2_ref / d as f32, &mut dx_ref);
            // f64 reference for the same row (independent check of the math)
            for j in 0..d {
                let want = row[j] as f64 * r as f64 * gamma[j] as f64;
                assert!(rel_close(out_ref[j] as f64, want, 1e-5), "fwd d={d} j={j}");
            }

            for t in tiers() {
                let mut xh = vec![0f32; d];
                let mut out = vec![0f32; d];
                rms_fwd_row(t, &row, &gamma, r, &mut xh, &mut out);
                let mut slg = vec![0.1f32; d];
                let s2 = rms_bwd_row_acc(t, &dy, &xh, &gamma, &mut slg);
                let mut dx = vec![0f32; d];
                ln_dx_row(t, &dy, &xh, &gamma, r, 0.0, s2 / d as f32, &mut dx);
                let checks: [(&str, &[f32], &[f32], f64); 4] = [
                    ("xh", &xh, &xh_ref, 1e-5),
                    ("out", &out, &out_ref, 1e-5),
                    ("slg", &slg, &slg_ref, 1e-4),
                    ("dx", &dx, &dx_ref, 1e-3),
                ];
                for (what, got, want, tol) in checks {
                    for j in 0..d {
                        assert!(
                            rel_close(got[j] as f64, want[j] as f64, tol),
                            "{what} tier={} d={d} j={j}",
                            t.name()
                        );
                    }
                }
                assert!(rel_close(s2 as f64, s2_ref as f64, 1e-3), "s2 tier={} d={d}", t.name());
            }
        }
    }

    #[test]
    fn tier_detection_is_cached_and_valid() {
        let t = tier();
        assert_eq!(t, tier(), "tier must be stable across calls");
        match t {
            Tier::Scalar => {}
            native => assert_eq!(Some(native), native_tier(), "dispatched tier must be executable"),
        }
        assert!(!t.name().is_empty());
    }
}
