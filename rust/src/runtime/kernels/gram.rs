//! Goodfellow per-example gradient-norm contractions (paper Eqs. 4–5;
//! Goodfellow, arXiv:1510.01799).
//!
//! For a linear layer `y = x @ w`, example `b`'s weight gradient is
//! `dw_b = x_b^T δ_b` with `x_b: [T, K]`, `δ_b: [T, N]`. Its squared
//! Frobenius norm never needs the `[K, N]` matrix:
//!
//! ```text
//! ||x_b^T δ_b||_F^2 = Σ_{t,t'} (x_t · x_{t'}) (δ_t · δ_{t'})
//!                   = Σ_t ||x_t||²||δ_t||² + 2 Σ_{t<t'} (x_t·x_{t'})(δ_t·δ_{t'})
//! ```
//!
//! i.e. the elementwise contraction of the two `[T, T]` example Gram
//! matrices — `O(T²(K+N))` work and `O(1)` extra memory instead of an
//! `O(TKN)` materialization per example. This is the "simultaneous"
//! method of Gray et al. §3: the same `x` and `δ` the batched parameter
//! gradient contracts are reread for the norms, so the norms ride along
//! with the backward at near-zero extra cost.

use super::simd;
use super::threads::{par_row_blocks, WorkerPool};

/// Per-example squared weight-gradient norms via the Gram contraction.
/// `x: [bsz·t, k]`, `delta: [bsz·t, n]`; writes `||x_b^T δ_b||²` into
/// `out[b]`. Threaded over examples; cross terms accumulate in f64 and in
/// fixed `(t, t')` order, so results are worker-count invariant. Dot
/// products dispatch through the SIMD tier (see `simd`).
pub fn weight_sqnorms(
    pool: &WorkerPool,
    x: &[f32],
    delta: &[f32],
    bsz: usize,
    t: usize,
    k: usize,
    n: usize,
    out: &mut [f64],
) {
    assert!(x.len() >= bsz * t * k && delta.len() >= bsz * t * n && out.len() >= bsz);
    let tier = simd::tier();
    par_row_blocks(pool, bsz, 1, out, |b0, b1, ob| {
        for b in b0..b1 {
            let xb = &x[b * t * k..(b + 1) * t * k];
            let db = &delta[b * t * n..(b + 1) * t * n];
            let mut s = 0f64;
            for ti in 0..t {
                let xi = &xb[ti * k..(ti + 1) * k];
                let di = &db[ti * n..(ti + 1) * n];
                s += simd::dot_tier(tier, xi, xi) as f64 * simd::dot_tier(tier, di, di) as f64;
                for tj in ti + 1..t {
                    let gx = simd::dot_tier(tier, xi, &xb[tj * k..(tj + 1) * k]);
                    if gx != 0.0 {
                        let gd = simd::dot_tier(tier, di, &db[tj * n..(tj + 1) * n]);
                        s += 2.0 * gx as f64 * gd as f64;
                    }
                }
            }
            ob[b - b0] = s;
        }
    });
}

/// Per-example bias gradients and their squared norms. Example `b`'s bias
/// gradient is the column sum of its delta rows; this accumulates the
/// *batch* bias gradient into `db` (fixed example order — deterministic)
/// and, when `out` is `Some`, writes `||δ_b column-sum||²` into `out[b]`.
/// Passing `None` skips only the norm emission — the `db` accumulation
/// order is unchanged, so gradients stay bitwise identical (this is the
/// norms-off backward used to measure the paper's overhead claim).
/// `scratch` needs `n` elements. Serial: the whole pass is `O(bsz·t·n)`
/// adds.
pub fn bias_sqnorms_acc(
    delta: &[f32],
    bsz: usize,
    t: usize,
    n: usize,
    db: &mut [f32],
    scratch: &mut [f32],
    mut out: Option<&mut [f64]>,
) {
    assert!(delta.len() >= bsz * t * n && db.len() >= n && scratch.len() >= n);
    if let Some(o) = out.as_deref() {
        assert!(o.len() >= bsz);
    }
    for b in 0..bsz {
        let rows = &delta[b * t * n..(b + 1) * t * n];
        let acc = &mut scratch[..n];
        acc.copy_from_slice(&rows[..n]);
        for ti in 1..t {
            let r = &rows[ti * n..(ti + 1) * n];
            for j in 0..n {
                acc[j] += r[j];
            }
        }
        if let Some(o) = out.as_deref_mut() {
            let mut sq = 0f64;
            for j in 0..n {
                sq += acc[j] as f64 * acc[j] as f64;
                db[j] += acc[j];
            }
            o[b] = sq;
        } else {
            for j in 0..n {
                db[j] += acc[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Materialize dw_b = x_b^T δ_b and take its norm — the definition.
    fn naive_weight_sqnorm(xb: &[f32], db: &[f32], t: usize, k: usize, n: usize) -> f64 {
        let mut dw = vec![0f64; k * n];
        for ti in 0..t {
            for kk in 0..k {
                for j in 0..n {
                    dw[kk * n + j] += xb[ti * k + kk] as f64 * db[ti * n + j] as f64;
                }
            }
        }
        dw.iter().map(|v| v * v).sum()
    }

    #[test]
    fn gram_matches_materialized_norms() {
        let mut rng = Rng::seed_from_u64(7);
        let pool = WorkerPool::new(2);
        for (bsz, t, k, n) in [(1, 1, 3, 4), (2, 1, 5, 2), (3, 6, 4, 8), (4, 8, 7, 5)] {
            let x = randv(&mut rng, bsz * t * k);
            let d = randv(&mut rng, bsz * t * n);
            let mut out = vec![0f64; bsz];
            weight_sqnorms(&pool, &x, &d, bsz, t, k, n, &mut out);
            for b in 0..bsz {
                let want = naive_weight_sqnorm(
                    &x[b * t * k..(b + 1) * t * k],
                    &d[b * t * n..(b + 1) * t * n],
                    t,
                    k,
                    n,
                );
                assert!(
                    (out[b] - want).abs() <= 1e-4 * want.abs().max(1e-9),
                    "b={b}: {} vs {want}",
                    out[b]
                );
            }
        }
    }

    #[test]
    fn gram_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(8);
        let (bsz, t, k, n) = (5, 4, 6, 3);
        let x = randv(&mut rng, bsz * t * k);
        let d = randv(&mut rng, bsz * t * n);
        let mut a = vec![0f64; bsz];
        let mut b = vec![0f64; bsz];
        weight_sqnorms(&WorkerPool::new(1), &x, &d, bsz, t, k, n, &mut a);
        weight_sqnorms(&WorkerPool::new(4), &x, &d, bsz, t, k, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn bias_norms_match_naive_and_accumulate() {
        let mut rng = Rng::seed_from_u64(9);
        let (bsz, t, n) = (3, 5, 7);
        let d = randv(&mut rng, bsz * t * n);
        let mut db = vec![0.5f32; n]; // pre-seeded: must accumulate
        let mut scratch = vec![0f32; n];
        let mut out = vec![0f64; bsz];
        bias_sqnorms_acc(&d, bsz, t, n, &mut db, &mut scratch, Some(&mut out));
        for b in 0..bsz {
            let mut col = vec![0f64; n];
            for ti in 0..t {
                for j in 0..n {
                    col[j] += d[(b * t + ti) * n + j] as f64;
                }
            }
            let want: f64 = col.iter().map(|v| v * v).sum();
            assert!((out[b] - want).abs() <= 1e-4 * want.max(1e-9), "b={b}");
        }
        // db accumulated the batch column-sum on top of the seed value
        let mut total = vec![0.5f64; n];
        for b in 0..bsz {
            for ti in 0..t {
                for j in 0..n {
                    total[j] += d[(b * t + ti) * n + j] as f64;
                }
            }
        }
        for j in 0..n {
            assert!((db[j] as f64 - total[j]).abs() <= 1e-4 * total[j].abs().max(1.0));
        }
    }

    #[test]
    fn bias_norms_off_keeps_gradients_bitwise() {
        let mut rng = Rng::seed_from_u64(10);
        let (bsz, t, n) = (4, 3, 9);
        let d = randv(&mut rng, bsz * t * n);
        let mut db_on = vec![0.25f32; n];
        let mut db_off = vec![0.25f32; n];
        let mut scratch = vec![0f32; n];
        let mut out = vec![0f64; bsz];
        bias_sqnorms_acc(&d, bsz, t, n, &mut db_on, &mut scratch, Some(&mut out));
        bias_sqnorms_acc(&d, bsz, t, n, &mut db_off, &mut scratch, None);
        assert_eq!(db_on, db_off, "norm emission must not perturb the gradient");
        assert!(out.iter().all(|&v| v >= 0.0));
    }
}
