//! Batched RMSNorm forward and its §3-style fused backward.
//!
//! RMSNorm (`y = γ ⊙ x·r`, `r = 1/√(mean(x²)+ε)`) is LayerNorm without
//! the mean subtraction and without `β`. Its backward is the LayerNorm
//! backward at `m1 = 0`:
//!
//! `dx = r · (dy⊙γ − x̂ · m2)`, `m2 = (1/d) Σ_j (dy_j γ_j) x̂_j`,
//! `dγ = Σ rows dy ⊙ x̂`.
//!
//! As in `ln_bwd_fused`, the per-example `dγ_b = Σ_t dy_t ⊙ x̂_t` vectors
//! are exactly the partial sums the batch `dγ` reduction forms anyway, so
//! emitting per-example `||dγ_b||²` (the only norm-layer term — there is
//! no `β`) is free. `Option`-gating the emission gives the same norms-off
//! bitwise-identical baseline the overhead bench measures.
//!
//! Thread-determinism contract matches `layernorm`: workers own disjoint
//! example blocks; the `dγ` reduction and norm emission run on the
//! calling thread in fixed example order after the join.

use super::simd;
use super::threads::{par_row_blocks2, WorkerPool};

/// Row-wise RMSNorm over `rows` rows of width `d`. Writes the output,
/// the normalized activations `xhat = x·r` and the per-row reciprocal
/// RMS `rstd` (both needed by the backward). Serial over rows, SIMD
/// within each row: `O(rows·d)`.
pub fn rms_fwd(
    x: &[f32],
    gamma: &[f32],
    rows: usize,
    d: usize,
    eps: f32,
    out: &mut [f32],
    xhat: &mut [f32],
    rstd: &mut [f32],
) {
    assert!(x.len() >= rows * d && out.len() >= rows * d && xhat.len() >= rows * d);
    assert!(rstd.len() >= rows && gamma.len() >= d);
    let tier = simd::tier();
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        // Σ x² is the squared-deviation sum around a zero mean.
        let ms = simd::sq_dev_sum(tier, row, 0.0) / d as f32;
        let rs = 1.0 / (ms + eps).sqrt();
        rstd[r] = rs;
        simd::rms_fwd_row(
            tier,
            row,
            &gamma[..d],
            rs,
            &mut xhat[r * d..(r + 1) * d],
            &mut out[r * d..(r + 1) * d],
        );
    }
}

/// Fused RMSNorm backward over a `[bsz, t, d]` batch.
///
/// Computes `dx`, accumulates the batch `dgamma`, and — when `per_ex_sq`
/// is `Some` — writes each example's `||dγ_b||²` into `per_ex_sq[b]`.
/// Passing `None` skips only the norm emission; the `dγ` accumulation
/// order is unchanged, keeping gradients bitwise identical (the
/// norms-off backward the overhead bench compares against). `scratch`
/// needs `bsz * d` elements (per-example `dγ_b`).
#[allow(clippy::too_many_arguments)]
pub fn rms_bwd_fused(
    pool: &WorkerPool,
    dout: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    gamma: &[f32],
    bsz: usize,
    t: usize,
    d: usize,
    dx: &mut [f32],
    scratch: &mut [f32],
    dgamma: &mut [f32],
    per_ex_sq: Option<&mut [f64]>,
) {
    let m = bsz * t;
    assert!(dout.len() >= m * d && xhat.len() >= m * d && rstd.len() >= m);
    assert!(dx.len() >= m * d && scratch.len() >= bsz * d);
    assert!(dgamma.len() >= d);
    if let Some(pes) = per_ex_sq.as_deref() {
        assert!(pes.len() >= bsz);
    }
    let tier = simd::tier();
    par_row_blocks2(pool, bsz, t * d, dx, d, scratch, |b0, b1, dxb, scb| {
        for b in b0..b1 {
            let slg = &mut scb[(b - b0) * d..(b - b0 + 1) * d];
            slg.fill(0.0);
            for ti in 0..t {
                let r = b * t + ti;
                let dyr = &dout[r * d..(r + 1) * d];
                let xhr = &xhat[r * d..(r + 1) * d];
                let s2 = simd::rms_bwd_row_acc(tier, dyr, xhr, &gamma[..d], slg);
                let m2 = s2 / d as f32;
                let rs = rstd[r];
                let dxr = &mut dxb[((b - b0) * t + ti) * d..((b - b0) * t + ti + 1) * d];
                simd::ln_dx_row(tier, dyr, xhr, &gamma[..d], rs, 0.0, m2, dxr);
            }
        }
    });
    // Batch reduction + norm emission, fixed example order (deterministic).
    match per_ex_sq {
        Some(pes) => {
            for b in 0..bsz {
                let slg = &scratch[b * d..(b + 1) * d];
                let mut sq = 0f64;
                for j in 0..d {
                    dgamma[j] += slg[j];
                    sq += slg[j] as f64 * slg[j] as f64;
                }
                pes[b] = sq;
            }
        }
        None => {
            for b in 0..bsz {
                let slg = &scratch[b * d..(b + 1) * d];
                for j in 0..d {
                    dgamma[j] += slg[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const EPS: f32 = 1e-5;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Reference per-row backward (the definitional RMSNorm gradient).
    fn naive_bwd(
        dout: &[f32],
        xhat: &[f32],
        rstd: &[f32],
        g: &[f32],
        rows: usize,
        d: usize,
        dg: &mut [f32],
    ) -> Vec<f32> {
        let mut dx = vec![0f32; rows * d];
        for r in 0..rows {
            let mut m2 = 0f32;
            for j in 0..d {
                let dy = dout[r * d + j];
                let xh = xhat[r * d + j];
                dg[j] += dy * xh;
                m2 += dy * g[j] * xh;
            }
            m2 /= d as f32;
            for j in 0..d {
                let dxh = dout[r * d + j] * g[j];
                dx[r * d + j] = rstd[r] * (dxh - xhat[r * d + j] * m2);
            }
        }
        dx
    }

    #[test]
    fn forward_matches_f64_reference() {
        let mut rng = Rng::seed_from_u64(31);
        for (rows, d) in [(1, 1), (3, 5), (2, 8), (4, 17)] {
            let x = randv(&mut rng, rows * d);
            let gamma: Vec<f32> = (0..d).map(|j| 1.0 + 0.1 * j as f32).collect();
            let (mut out, mut xhat, mut rstd) =
                (vec![0f32; rows * d], vec![0f32; rows * d], vec![0f32; rows]);
            rms_fwd(&x, &gamma, rows, d, EPS, &mut out, &mut xhat, &mut rstd);
            for r in 0..rows {
                let ms: f64 =
                    x[r * d..(r + 1) * d].iter().map(|&v| v as f64 * v as f64).sum::<f64>()
                        / d as f64;
                let rr = 1.0 / (ms + EPS as f64).sqrt();
                assert!(
                    ((rstd[r] as f64) - rr).abs() <= 1e-5 * rr,
                    "rstd[{r}]: {} vs {rr}",
                    rstd[r]
                );
                for j in 0..d {
                    let want = x[r * d + j] as f64 * rr * gamma[j] as f64;
                    assert!(
                        ((out[r * d + j] as f64) - want).abs() <= 1e-5 * want.abs().max(1e-6),
                        "out[{r},{j}]"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_backward_matches_reference_and_emits_norms() {
        let mut rng = Rng::seed_from_u64(32);
        let pool = WorkerPool::new(2);
        // shapes include sub-lane and cross-lane tails
        for (bsz, t, d) in [(1, 1, 4), (2, 3, 8), (4, 5, 6), (3, 2, 17)] {
            let rows = bsz * t;
            let x = randv(&mut rng, rows * d);
            let gamma: Vec<f32> = (0..d).map(|j| 1.0 + 0.1 * j as f32).collect();
            let (mut out, mut xhat, mut rstd) =
                (vec![0f32; rows * d], vec![0f32; rows * d], vec![0f32; rows]);
            rms_fwd(&x, &gamma, rows, d, EPS, &mut out, &mut xhat, &mut rstd);
            let dout = randv(&mut rng, rows * d);

            let mut dg_ref = vec![0f32; d];
            let dx_ref = naive_bwd(&dout, &xhat, &rstd, &gamma, rows, d, &mut dg_ref);

            let mut dx = vec![0f32; rows * d];
            let mut scratch = vec![0f32; bsz * d];
            let mut dg = vec![0f32; d];
            let mut sq = vec![0f64; bsz];
            rms_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch, &mut dg,
                Some(&mut sq),
            );
            for (a, b) in dx.iter().zip(&dx_ref) {
                assert!((a - b).abs() <= 1e-5 * b.abs().max(1e-3));
            }
            for j in 0..d {
                assert!((dg[j] - dg_ref[j]).abs() <= 1e-4 * dg_ref[j].abs().max(1e-3));
            }
            // per-example norms: recompute ||dγ_b||² from scratch sums
            for b in 0..bsz {
                let mut want = 0f64;
                for j in 0..d {
                    let mut dgj = 0f64;
                    for ti in 0..t {
                        let r = b * t + ti;
                        dgj += dout[r * d + j] as f64 * xhat[r * d + j] as f64;
                    }
                    want += dgj * dgj;
                }
                assert!(
                    (sq[b] - want).abs() <= 1e-4 * want.max(1e-9),
                    "bsz={bsz} t={t} d={d} b={b}: {} vs {want}",
                    sq[b]
                );
            }
        }
    }

    #[test]
    fn fused_backward_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(33);
        let (bsz, t, d) = (5, 3, 8);
        let rows = bsz * t;
        let xhat = randv(&mut rng, rows * d);
        let rstd: Vec<f32> = (0..rows).map(|_| 1.0 + rng.f64() as f32).collect();
        let gamma = randv(&mut rng, d);
        let dout = randv(&mut rng, rows * d);
        let run = |workers: usize| {
            let pool = WorkerPool::new(workers);
            let mut dx = vec![0f32; rows * d];
            let mut scratch = vec![0f32; bsz * d];
            let mut dg = vec![0f32; d];
            let mut sq = vec![0f64; bsz];
            rms_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch, &mut dg,
                Some(&mut sq),
            );
            (dx, dg, sq)
        };
        assert_eq!(run(1), run(3));
    }

    #[test]
    fn norms_off_backward_keeps_gradients_bitwise() {
        let mut rng = Rng::seed_from_u64(34);
        let pool = WorkerPool::new(3);
        let (bsz, t, d) = (4, 2, 12);
        let rows = bsz * t;
        let xhat = randv(&mut rng, rows * d);
        let rstd: Vec<f32> = (0..rows).map(|_| 1.0 + rng.f64() as f32).collect();
        let gamma = randv(&mut rng, d);
        let dout = randv(&mut rng, rows * d);
        let run = |pes: bool| {
            let mut dx = vec![0f32; rows * d];
            let mut scratch = vec![0f32; bsz * d];
            let mut dg = vec![0f32; d];
            let mut sq = vec![0f64; bsz];
            rms_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch, &mut dg,
                if pes { Some(&mut sq) } else { None },
            );
            (dx, dg)
        };
        assert_eq!(run(true), run(false), "norm emission must not perturb gradients");
    }
}
