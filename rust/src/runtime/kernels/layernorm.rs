//! Batched LayerNorm forward and the paper's §3 fused backward.
//!
//! The fused backward computes `dx`, accumulates `dγ`/`dβ`, *and* emits
//! per-example `||dγ_b||² + ||dβ_b||²` from the same pass. The per-example
//! vectors `dγ_b = Σ_t dy_t ⊙ x̂_t` and `dβ_b = Σ_t dy_t` are exactly the
//! partial sums the batch reduction has to form anyway, so the norms are
//! free — this is the zero-overhead LN kernel of Gray et al. §3, in Rust.
//!
//! Row passes dispatch through [`super::simd`] (AVX2/FMA, NEON, or the
//! scalar oracle under `NANOGNS_FORCE_SCALAR=1`).
//!
//! Thread-determinism contract: workers own disjoint example blocks
//! (disjoint `dx` rows and per-example scratch slots); the `dγ`/`dβ`
//! accumulation and the norm emission run on the calling thread in fixed
//! example order after the join.

use super::simd;
use super::threads::{par_row_blocks2, WorkerPool};

/// Row-wise LayerNorm over `rows` rows of width `d`. Writes the output,
/// the normalized activations `xhat` and the per-row reciprocal stddev
/// `rstd` (both needed by the backward). Serial over rows, SIMD within
/// each row: `O(rows·d)`.
pub fn ln_fwd(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    d: usize,
    eps: f32,
    out: &mut [f32],
    xhat: &mut [f32],
    rstd: &mut [f32],
) {
    assert!(x.len() >= rows * d && out.len() >= rows * d && xhat.len() >= rows * d);
    assert!(rstd.len() >= rows && gamma.len() >= d && beta.len() >= d);
    let tier = simd::tier();
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mean = simd::sum(tier, row) / d as f32;
        let var = simd::sq_dev_sum(tier, row, mean) / d as f32;
        let rs = 1.0 / (var + eps).sqrt();
        rstd[r] = rs;
        simd::ln_fwd_row(
            tier,
            row,
            &gamma[..d],
            &beta[..d],
            mean,
            rs,
            &mut xhat[r * d..(r + 1) * d],
            &mut out[r * d..(r + 1) * d],
        );
    }
}

/// Fused LayerNorm backward over a `[bsz, t, d]` batch.
///
/// Computes `dx`, accumulates the batch `dgamma`/`dbeta`, and — when
/// `per_ex_sq` is `Some` — writes each example's `||dγ_b||² + ||dβ_b||²`
/// into `per_ex_sq[b]`; both LN parameters carry the `layernorm` stats
/// tag, so one slot per example covers the pair. Passing `None` skips
/// only the norm emission: the `dγ`/`dβ` accumulation order is
/// unchanged, keeping gradients bitwise identical (the norms-off
/// backward used to measure the paper's overhead claim). `scratch` needs
/// `bsz * 2d` elements (per-example `dγ_b` then `dβ_b`).
#[allow(clippy::too_many_arguments)]
pub fn ln_bwd_fused(
    pool: &WorkerPool,
    dout: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    gamma: &[f32],
    bsz: usize,
    t: usize,
    d: usize,
    dx: &mut [f32],
    scratch: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    per_ex_sq: Option<&mut [f64]>,
) {
    let m = bsz * t;
    assert!(dout.len() >= m * d && xhat.len() >= m * d && rstd.len() >= m);
    assert!(dx.len() >= m * d && scratch.len() >= bsz * 2 * d);
    assert!(dgamma.len() >= d && dbeta.len() >= d);
    if let Some(pes) = per_ex_sq.as_deref() {
        assert!(pes.len() >= bsz);
    }
    let tier = simd::tier();
    par_row_blocks2(pool, bsz, t * d, dx, 2 * d, scratch, |b0, b1, dxb, scb| {
        for b in b0..b1 {
            let sl = &mut scb[(b - b0) * 2 * d..(b - b0 + 1) * 2 * d];
            sl.fill(0.0);
            let (slg, slb) = sl.split_at_mut(d);
            for ti in 0..t {
                let r = b * t + ti;
                let dyr = &dout[r * d..(r + 1) * d];
                let xhr = &xhat[r * d..(r + 1) * d];
                let (s1, s2) = simd::ln_bwd_row_acc(tier, dyr, xhr, &gamma[..d], slg, slb);
                let m1 = s1 / d as f32;
                let m2 = s2 / d as f32;
                let rs = rstd[r];
                let dxr = &mut dxb[((b - b0) * t + ti) * d..((b - b0) * t + ti + 1) * d];
                simd::ln_dx_row(tier, dyr, xhr, &gamma[..d], rs, m1, m2, dxr);
            }
        }
    });
    // Batch reduction + norm emission, fixed example order (deterministic).
    match per_ex_sq {
        Some(pes) => {
            for b in 0..bsz {
                let sl = &scratch[b * 2 * d..(b + 1) * 2 * d];
                let mut sq = 0f64;
                for j in 0..d {
                    dgamma[j] += sl[j];
                    dbeta[j] += sl[d + j];
                    sq += sl[j] as f64 * sl[j] as f64 + sl[d + j] as f64 * sl[d + j] as f64;
                }
                pes[b] = sq;
            }
        }
        None => {
            for b in 0..bsz {
                let sl = &scratch[b * 2 * d..(b + 1) * 2 * d];
                for j in 0..d {
                    dgamma[j] += sl[j];
                    dbeta[j] += sl[d + j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const EPS: f32 = 1e-5;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Reference per-row backward (the pre-batched formula).
    #[allow(clippy::too_many_arguments)]
    fn naive_bwd(
        dout: &[f32],
        xhat: &[f32],
        rstd: &[f32],
        g: &[f32],
        rows: usize,
        d: usize,
        dg: &mut [f32],
        db: &mut [f32],
    ) -> Vec<f32> {
        let mut dx = vec![0f32; rows * d];
        for r in 0..rows {
            let mut m1 = 0f32;
            let mut m2 = 0f32;
            for j in 0..d {
                let dy = dout[r * d + j];
                let xh = xhat[r * d + j];
                dg[j] += dy * xh;
                db[j] += dy;
                let dxh = dy * g[j];
                m1 += dxh;
                m2 += dxh * xh;
            }
            m1 /= d as f32;
            m2 /= d as f32;
            for j in 0..d {
                let dxh = dout[r * d + j] * g[j];
                dx[r * d + j] = rstd[r] * (dxh - m1 - xhat[r * d + j] * m2);
            }
        }
        dx
    }

    #[test]
    fn fused_backward_matches_reference_and_emits_norms() {
        let mut rng = Rng::seed_from_u64(11);
        let pool = WorkerPool::new(2);
        for (bsz, t, d) in [(1, 1, 4), (2, 3, 8), (4, 5, 6)] {
            let rows = bsz * t;
            let x = randv(&mut rng, rows * d);
            let gamma: Vec<f32> = (0..d).map(|j| 1.0 + 0.1 * j as f32).collect();
            let beta = randv(&mut rng, d);
            let (mut out, mut xhat, mut rstd) =
                (vec![0f32; rows * d], vec![0f32; rows * d], vec![0f32; rows]);
            ln_fwd(&x, &gamma, &beta, rows, d, EPS, &mut out, &mut xhat, &mut rstd);
            let dout = randv(&mut rng, rows * d);

            let mut dg_ref = vec![0f32; d];
            let mut db_ref = vec![0f32; d];
            let dx_ref = naive_bwd(&dout, &xhat, &rstd, &gamma, rows, d, &mut dg_ref, &mut db_ref);

            let mut dx = vec![0f32; rows * d];
            let mut scratch = vec![0f32; bsz * 2 * d];
            let mut dg = vec![0f32; d];
            let mut db = vec![0f32; d];
            let mut sq = vec![0f64; bsz];
            ln_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch, &mut dg,
                &mut db, Some(&mut sq),
            );
            for (a, b) in dx.iter().zip(&dx_ref) {
                assert!((a - b).abs() <= 1e-5 * b.abs().max(1e-3));
            }
            for j in 0..d {
                assert!((dg[j] - dg_ref[j]).abs() <= 1e-4 * dg_ref[j].abs().max(1e-3));
                assert!((db[j] - db_ref[j]).abs() <= 1e-4 * db_ref[j].abs().max(1e-3));
            }
            // per-example norms: recompute from per-example partial sums
            for b in 0..bsz {
                let mut want = 0f64;
                for j in 0..d {
                    let mut dgj = 0f64;
                    let mut dbj = 0f64;
                    for ti in 0..t {
                        let r = b * t + ti;
                        dgj += dout[r * d + j] as f64 * xhat[r * d + j] as f64;
                        dbj += dout[r * d + j] as f64;
                    }
                    want += dgj * dgj + dbj * dbj;
                }
                assert!(
                    (sq[b] - want).abs() <= 1e-4 * want.max(1e-9),
                    "bsz={bsz} t={t} d={d} b={b}: {} vs {want}",
                    sq[b]
                );
            }
        }
    }

    #[test]
    fn fused_backward_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(12);
        let (bsz, t, d) = (5, 3, 8);
        let rows = bsz * t;
        let xhat = randv(&mut rng, rows * d);
        let rstd: Vec<f32> = (0..rows).map(|_| 1.0 + rng.f64() as f32).collect();
        let gamma = randv(&mut rng, d);
        let dout = randv(&mut rng, rows * d);
        let run = |workers: usize| {
            let pool = WorkerPool::new(workers);
            let mut dx = vec![0f32; rows * d];
            let mut scratch = vec![0f32; bsz * 2 * d];
            let mut dg = vec![0f32; d];
            let mut db = vec![0f32; d];
            let mut sq = vec![0f64; bsz];
            ln_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch,
                &mut dg, &mut db, Some(&mut sq),
            );
            (dx, dg, db, sq)
        };
        assert_eq!(run(1), run(3));
    }

    #[test]
    fn norms_off_backward_keeps_gradients_bitwise() {
        let mut rng = Rng::seed_from_u64(13);
        let pool = WorkerPool::new(3);
        let (bsz, t, d) = (4, 2, 12);
        let rows = bsz * t;
        let xhat = randv(&mut rng, rows * d);
        let rstd: Vec<f32> = (0..rows).map(|_| 1.0 + rng.f64() as f32).collect();
        let gamma = randv(&mut rng, d);
        let dout = randv(&mut rng, rows * d);
        let run = |pes: bool| {
            let mut dx = vec![0f32; rows * d];
            let mut scratch = vec![0f32; bsz * 2 * d];
            let mut dg = vec![0f32; d];
            let mut db = vec![0f32; d];
            let mut sq = vec![0f64; bsz];
            ln_bwd_fused(
                &pool, &dout, &xhat, &rstd, &gamma, bsz, t, d, &mut dx, &mut scratch,
                &mut dg, &mut db, if pes { Some(&mut sq) } else { None },
            );
            (dx, dg, db)
        };
        assert_eq!(run(true), run(false), "norm emission must not perturb gradients");
    }
}
