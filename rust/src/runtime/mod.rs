//! Execution runtimes behind the [`Backend`] abstraction.
//!
//! * [`backend`] — the [`Backend`]/[`BackendFactory`] traits and the
//!   [`Buffer`] tensor handle the coordinator is written against;
//! * [`reference`] — hermetic pure-Rust CPU transformer (default);
//! * [`kernels`] — the fused batched matmul / Gram-norm / LayerNorm
//!   kernels behind the reference backend's hot path (paper §3);
//! * [`pjrt`] — AOT HLO artifacts through the PJRT C API (feature
//!   `pjrt`; requires `make artifacts` and the real `xla` crate);
//! * [`manifest`] — the L2→L3 artifact/model-metadata contract;
//! * [`tensor`] — the host tensor value type.

pub mod backend;
pub mod kernels;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod tensor;

pub use backend::{Backend, BackendFactory, Buffer, GradOut};
pub use manifest::{AdamHypers, LnBenchEntry, Manifest, ModelEntry, ParamSpec};
pub use reference::{ReferenceBackend, ReferenceFactory, ReferenceVariantFactory, RefModelConfig};
pub use tensor::Tensor;

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, PjrtBackend, PjrtFactory, Runtime};
