//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`). One [`Runtime`] owns the client and a
//! compile cache so each artifact is compiled exactly once per process.
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod manifest;
pub mod tensor;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub use manifest::{AdamHypers, LnBenchEntry, Manifest, ModelEntry, ParamSpec};
pub use tensor::Tensor;

/// A compiled artifact. All lowered functions return a single tuple (the
/// AOT path lowers with `return_tuple=True`), which [`Executable::run`]
/// flattens back into a `Vec<Literal>`.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub path: PathBuf,
    pub compile_ms: u128,
}

impl Executable {
    /// Execute with host literals; returns the untupled outputs.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute(args)
            .map_err(|e| anyhow!("execute {:?}: {e:?}", self.path))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {:?}: {e:?}", self.path))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {:?}: {e:?}", self.path))
    }

    /// Execute expecting exactly one output.
    pub fn run1<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Literal> {
        let mut v = self.run(args)?;
        anyhow::ensure!(v.len() == 1, "expected 1 output, got {}", v.len());
        Ok(v.pop().unwrap())
    }
}

/// PJRT client + executable cache. Cheap to clone (shared internals).
#[derive(Clone)]
pub struct Runtime {
    client: Rc<PjRtClient>,
    cache: Rc<RefCell<HashMap<PathBuf, Rc<Executable>>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Self { client: Rc::new(client), cache: Rc::new(RefCell::new(HashMap::new())) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Rc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache.borrow().get(&path) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?} (run `make artifacts`)"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        let exe = Rc::new(Executable { exe, path: path.clone(), compile_ms: t0.elapsed().as_millis() });
        self.cache.borrow_mut().insert(path, exe.clone());
        Ok(exe)
    }

    /// Load every artifact of a model config, keyed by artifact name.
    pub fn load_model(
        &self,
        manifest: &Manifest,
        config: &str,
    ) -> Result<HashMap<String, Rc<Executable>>> {
        let entry = manifest.config(config)?;
        let mut out = HashMap::new();
        for name in entry.artifacts.keys() {
            out.insert(name.clone(), self.load(entry.artifact_path(&manifest.root, name)?)?);
        }
        Ok(out)
    }
}
