//! PJRT backend: load AOT artifacts (HLO text) and execute them
//! (feature `pjrt`).
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`). One [`Runtime`] owns the client and a
//! compile cache so each artifact is compiled exactly once per process.
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! [`PjrtBackend`] adapts the artifact dispatch to the [`Backend`] trait;
//! the offline workspace compiles this module against the `vendor/xla`
//! stub, so it type-checks everywhere but executes only when the real
//! `xla` crate is patched in (DESIGN.md §6).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::data::Batch;
use crate::runtime::backend::{Backend, BackendFactory, Buffer, GradOut};
use crate::runtime::manifest::{Manifest, ModelEntry};
use crate::runtime::tensor::Tensor;
use crate::N_TYPES;

// ---------------------------------------------------------------------------
// Literal <-> host conversions
// ---------------------------------------------------------------------------

pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape to {:?}: {e:?}", t.shape))
}

pub fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e:?}"))?;
    Tensor::new(dims, data)
}

/// Build an i32 literal of the given shape (token id batches).
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<Literal> {
    ensure!(shape.iter().product::<usize>() == data.len(), "i32 literal shape mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e:?}"))
}

/// Scalar literals for artifact hyper-parameter inputs.
pub fn f32_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn i32_scalar(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Read a scalar f32 out of a literal.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))
}

/// Read an f32 vector (e.g. the (5,) stats vector).
pub fn vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    ensure!(lit.ty().map_err(|e| anyhow!("{e:?}"))? == ElementType::F32, "expected f32 literal");
    lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}

// ---------------------------------------------------------------------------
// Runtime: client + compile cache
// ---------------------------------------------------------------------------

/// A compiled artifact. All lowered functions return a single tuple (the
/// AOT path lowers with `return_tuple=True`), which [`Executable::run`]
/// flattens back into a `Vec<Literal>`.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub path: PathBuf,
    pub compile_ms: u128,
}

impl Executable {
    /// Execute with host literals; returns the untupled outputs.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute(args)
            .map_err(|e| anyhow!("execute {:?}: {e:?}", self.path))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {:?}: {e:?}", self.path))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {:?}: {e:?}", self.path))
    }

    /// Execute expecting exactly one output.
    pub fn run1<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Literal> {
        let mut v = self.run(args)?;
        anyhow::ensure!(v.len() == 1, "expected 1 output, got {}", v.len());
        Ok(v.pop().unwrap())
    }
}

/// PJRT client + executable cache. Cheap to clone (shared internals,
/// thread-safe: `Backend` requires `Send + Sync`).
#[derive(Clone)]
pub struct Runtime {
    client: Arc<PjRtClient>,
    cache: Arc<Mutex<HashMap<PathBuf, Arc<Executable>>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Self { client: Arc::new(client), cache: Arc::new(Mutex::new(HashMap::new())) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn cache(&self) -> Result<std::sync::MutexGuard<'_, HashMap<PathBuf, Arc<Executable>>>> {
        self.cache.lock().map_err(|_| anyhow!("pjrt compile cache mutex poisoned"))
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache()?.get(&path) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?} (run `make artifacts`)"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        let exe = Arc::new(Executable {
            exe,
            path: path.clone(),
            compile_ms: t0.elapsed().as_millis(),
        });
        self.cache()?.insert(path, exe.clone());
        Ok(exe)
    }

    /// Load every artifact of a model config, keyed by artifact name.
    pub fn load_model(
        &self,
        manifest: &Manifest,
        config: &str,
    ) -> Result<HashMap<String, Arc<Executable>>> {
        let entry = manifest.config(config)?;
        let mut out = HashMap::new();
        for name in entry.artifacts.keys() {
            out.insert(name.clone(), self.load(entry.artifact_path(&manifest.root, name)?)?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Backend adapter
// ---------------------------------------------------------------------------

/// Run an executable over buffer groups + trailing scalar literals
/// without copying device-resident literals: `Buffer::Pjrt` is passed by
/// reference; only `Buffer::Host` tensors are materialized.
fn run_buffers(exe: &Executable, groups: &[&[Buffer]], extra: &[Literal]) -> Result<Vec<Literal>> {
    let mut owned: Vec<Literal> = Vec::new();
    for bufs in groups {
        for b in bufs.iter() {
            if let Buffer::Host(t) = b {
                owned.push(tensor_to_literal(t)?);
            }
        }
    }
    let mut oi = 0;
    let n_args = groups.iter().map(|g| g.len()).sum::<usize>() + extra.len();
    let mut args: Vec<&Literal> = Vec::with_capacity(n_args);
    for bufs in groups {
        for b in bufs.iter() {
            match b {
                Buffer::Host(_) => {
                    args.push(&owned[oi]);
                    oi += 1;
                }
                Buffer::Pjrt(l) => args.push(l),
            }
        }
    }
    args.extend(extra.iter());
    exe.run(&args)
}

fn wrap(lits: Vec<Literal>) -> Vec<Buffer> {
    lits.into_iter().map(Buffer::Pjrt).collect()
}

/// [`Backend`] over the compiled artifacts of one model config.
pub struct PjrtBackend {
    entry: ModelEntry,
    exes: HashMap<String, Arc<Executable>>,
}

impl PjrtBackend {
    pub fn new(rt: &Runtime, manifest: &Manifest, config: &str) -> Result<Self> {
        let entry = manifest.config(config)?.clone();
        let exes = rt.load_model(manifest, config)?;
        Ok(Self { entry, exes })
    }

    fn exe(&self, name: &str) -> Result<&Arc<Executable>> {
        self.exes.get(name).ok_or_else(|| anyhow!("artifact {name} not loaded"))
    }

    fn batch_literals(&self, batch: &Batch) -> Result<(Literal, Literal)> {
        let shape = [batch.batch, batch.seq_len];
        Ok((i32_literal(&shape, &batch.inputs)?, i32_literal(&shape, &batch.targets)?))
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn init(&self, seed: i32) -> Result<Vec<Buffer>> {
        let out = self.exe("init")?.run(&[i32_scalar(seed)])?;
        ensure!(
            out.len() == self.entry.params.len(),
            "init returned {} tensors, manifest says {}",
            out.len(),
            self.entry.params.len()
        );
        Ok(wrap(out))
    }

    fn grad_step(&self, params: &[Buffer], batch: &Batch) -> Result<GradOut> {
        let (ids, tgt) = self.batch_literals(batch)?;
        let mut out = run_buffers(self.exe("grad_step")?, &[params], &[ids, tgt])?;
        let n = self.entry.params.len();
        ensure!(out.len() == n + 2, "grad_step returned {} outputs", out.len());
        let stats_lit = out.pop().unwrap();
        let stats_v = vec_f32(&stats_lit)?;
        ensure!(stats_v.len() == N_TYPES, "stats len {}", stats_v.len());
        let mut stats = [0f32; N_TYPES];
        stats.copy_from_slice(&stats_v);
        let grads = out.split_off(1);
        let loss = scalar_f32(&out[0])?;
        Ok(GradOut { loss, grads: wrap(grads), stats })
    }

    fn accumulate(&self, acc: Vec<Buffer>, grads: &[Buffer]) -> Result<Vec<Buffer>> {
        Ok(wrap(run_buffers(self.exe("accumulate")?, &[&acc, grads], &[])?))
    }

    fn grad_sqnorms(&self, grads: &[Buffer]) -> Result<[f64; N_TYPES]> {
        let mut out = run_buffers(self.exe("grad_sqnorms")?, &[grads], &[])?;
        ensure!(out.len() == 1, "grad_sqnorms returned {} outputs", out.len());
        let out = out.pop().unwrap();
        let v = vec_f32(&out)?;
        ensure!(v.len() == N_TYPES);
        let mut a = [0f64; N_TYPES];
        for (d, s) in a.iter_mut().zip(v) {
            *d = s as f64;
        }
        Ok(a)
    }

    fn adamw_update(
        &self,
        params: Vec<Buffer>,
        m: Vec<Buffer>,
        v: Vec<Buffer>,
        grads: &[Buffer],
        step: u64,
        lr: f64,
        grad_scale: f64,
    ) -> Result<(Vec<Buffer>, Vec<Buffer>, Vec<Buffer>)> {
        let n = self.entry.params.len();
        let scalars =
            [f32_scalar(step as f32), f32_scalar(lr as f32), f32_scalar(grad_scale as f32)];
        let mut out =
            run_buffers(self.exe("adamw_update")?, &[&params, &m, &v, grads], &scalars)?;
        ensure!(out.len() == 3 * n, "adamw_update returned {} outputs", out.len());
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        Ok((wrap(out), wrap(new_m), wrap(new_v)))
    }

    fn eval(&self, params: &[Buffer], batch: &Batch) -> Result<f32> {
        let (ids, tgt) = self.batch_literals(batch)?;
        let mut out = run_buffers(self.exe("eval_step")?, &[params], &[ids, tgt])?;
        ensure!(out.len() == 1, "eval_step returned {} outputs", out.len());
        scalar_f32(&out.pop().unwrap())
    }
}

/// [`BackendFactory`] over a manifest + PJRT runtime.
pub struct PjrtFactory {
    rt: Runtime,
    manifest: Manifest,
}

impl PjrtFactory {
    pub fn new(artifacts: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts)?;
        let rt = Runtime::cpu()?;
        Ok(Self { rt, manifest })
    }

    pub fn from_parts(rt: Runtime, manifest: Manifest) -> Self {
        Self { rt, manifest }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

impl BackendFactory for PjrtFactory {
    fn create(&self, model: &str) -> Result<Box<dyn Backend>> {
        Ok(Box::new(PjrtBackend::new(&self.rt, &self.manifest, model)?))
    }

    fn describe(&self, model: &str) -> Result<ModelEntry> {
        Ok(self.manifest.config(model)?.clone())
    }

    fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.manifest.configs.keys().cloned().collect();
        names.sort();
        names
    }

    fn platform(&self) -> String {
        self.rt.platform()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let l = tensor_to_literal(&t).unwrap();
        let t2 = literal_to_tensor(&l).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn i32_literal_round_trip() {
        let l = i32_literal(&[2, 3], &[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
    }
}
