//! Training-run configuration (JSON), the launcher's contract.
//!
//! Model *shape* lives in the artifact manifest (baked into the HLO); this
//! config selects a model by name and sets everything the coordinator
//! owns: schedules, seeds, ranks, telemetry paths. Example configs live in
//! `configs/*.json`. Parsed by the in-tree JSON substrate (no serde in
//! this offline build).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::norms::{NormKind, NormPlacement};
use crate::schedule::{BatchSizeSchedule, LrSchedule};
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model config name in artifacts/manifest.json.
    pub model: String,
    /// Directory holding the AOT artifacts.
    pub artifacts: String,
    pub steps: u64,
    pub seed: u64,
    /// Simulated DDP ranks (1 = single worker).
    pub ranks: usize,
    pub lr: LrSchedule,
    pub batch_size: BatchSizeSchedule,
    /// EMA alpha for GNS component smoothing.
    pub gns_alpha: f64,
    /// Corpus size in bytes (generated deterministically from `seed`).
    pub corpus_bytes: usize,
    /// Evaluate every N optimizer steps (0 = never).
    pub eval_every: u64,
    /// Metrics CSV path ("" = stdout summary only).
    pub metrics_path: String,
    /// Directory for full-state checkpoints ("" = no checkpointing).
    pub checkpoint_dir: String,
    /// Checkpoint every N optimizer steps (0 = never).
    pub checkpoint_every: u64,
    /// Keep only the newest N `step-*.ckpt` files after each publish
    /// (0 = keep everything). `latest.ckpt` is always kept. Retaining
    /// more than one gives `resume` a fallback chain when the newest
    /// checkpoint fails integrity verification.
    pub checkpoint_keep_last: usize,
    /// Resume from this full-state checkpoint file ("" = fresh run).
    pub resume: String,
    /// Intra-op kernel worker threads (0 = derive from `NANOGNS_THREADS`
    /// or the machine's available parallelism).
    pub threads: usize,
    /// Pin every kernel to the scalar oracle tier (`NANOGNS_FORCE_SCALAR`),
    /// e.g. to cross-check a SIMD result on the same machine.
    pub force_scalar: bool,
    /// How rank workers execute: scoped threads in-process (default) or
    /// supervised child processes (`coordinator::elastic`).
    pub rank_mode: RankMode,
    /// Process-mode supervision knobs; inert in thread mode.
    pub elastic: ElasticConfig,
    /// Telemetry daemon settings (`repro serve`); inert for plain `train`.
    pub serve: ServeConfig,
    /// Normalization kind (`"norm_kind"` key). `None` = key absent; the
    /// launcher resolves it against `--norm`/`NANOGNS_NORM` (conflicts
    /// are rejected) and the default cell is LayerNorm.
    pub norm_kind: Option<NormKind>,
    /// Normalization placement (`"norm_placement"` key); same resolution
    /// story via `--placement`/`NANOGNS_PLACEMENT`, defaulting to Pre-LN.
    pub norm_placement: Option<NormPlacement>,
}

/// Rank-worker execution mode. Both modes are bitwise interchangeable at
/// equal rank count; process mode additionally survives a rank dying
/// mid-run (drop to survivors and continue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RankMode {
    /// Scoped threads in one process (`coordinator::parallel`).
    #[default]
    Threads,
    /// Supervised child processes (`coordinator::elastic`).
    Process,
}

impl RankMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threads" | "thread" => Ok(RankMode::Threads),
            "process" => Ok(RankMode::Process),
            other => bail!("unknown rank mode {other:?} (threads|process)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            RankMode::Threads => "threads",
            RankMode::Process => "process",
        }
    }
}

/// Supervision knobs for elastic process mode (`"elastic"` config
/// object). Defaults suit local runs; CI fault injection tightens them.
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticConfig {
    /// Worker heartbeat period in milliseconds.
    pub heartbeat_ms: u64,
    /// Per-step wall-clock deadline in seconds; a rank that blows it is
    /// declared dead and dropped.
    pub step_timeout_s: f64,
    /// How long to wait for a spawned worker to connect and handshake.
    pub spawn_timeout_s: f64,
    /// Executable to spawn as `rank-worker` ("" = the current
    /// executable). Integration tests point this at the `repro` binary,
    /// since their own test binary has no `rank-worker` subcommand.
    pub worker_exe: String,
    /// Consecutive *failed* spawn attempts tolerated per dead worker
    /// before it is permanently retired (0 = never respawn; dead ranks
    /// stay dropped). Successful respawns reset the counter.
    pub max_respawns: u32,
    /// Backoff floor between respawn attempts, in milliseconds. Also
    /// paces re-admission of a crash-looping worker whose spawns keep
    /// succeeding. Must be positive.
    pub respawn_backoff_ms: u64,
    /// Backoff ceiling for the capped exponential respawn schedule, in
    /// milliseconds. Must be >= the floor.
    pub respawn_backoff_max_ms: u64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self {
            heartbeat_ms: 250,
            step_timeout_s: 300.0,
            spawn_timeout_s: 30.0,
            worker_exe: String::new(),
            max_respawns: 3,
            respawn_backoff_ms: 500,
            respawn_backoff_max_ms: 30_000,
        }
    }
}

fn parse_elastic(v: &Value) -> Result<ElasticConfig> {
    let d = ElasticConfig::default();
    let respawn_backoff_ms = match v.opt("respawn_backoff_ms") {
        Some(b) => {
            let b = b.as_u64()?;
            anyhow::ensure!(b > 0, "elastic.respawn_backoff_ms must be positive");
            b
        }
        None => d.respawn_backoff_ms,
    };
    let respawn_backoff_max_ms = match v.opt("respawn_backoff_max_ms") {
        Some(b) => {
            let b = b.as_u64()?;
            anyhow::ensure!(
                b >= respawn_backoff_ms,
                "elastic.respawn_backoff_max_ms ({b}) must be >= respawn_backoff_ms \
                 ({respawn_backoff_ms})"
            );
            b
        }
        None => d.respawn_backoff_max_ms.max(respawn_backoff_ms),
    };
    Ok(ElasticConfig {
        heartbeat_ms: match v.opt("heartbeat_ms") {
            Some(h) => {
                let h = h.as_u64()?;
                anyhow::ensure!(h > 0, "elastic.heartbeat_ms must be positive");
                h
            }
            None => d.heartbeat_ms,
        },
        step_timeout_s: match v.opt("step_timeout_s") {
            Some(t) => {
                let t = t.as_f64()?;
                anyhow::ensure!(t > 0.0, "elastic.step_timeout_s must be positive");
                t
            }
            None => d.step_timeout_s,
        },
        spawn_timeout_s: match v.opt("spawn_timeout_s") {
            Some(t) => {
                let t = t.as_f64()?;
                anyhow::ensure!(t > 0.0, "elastic.spawn_timeout_s must be positive");
                t
            }
            None => d.spawn_timeout_s,
        },
        worker_exe: match v.opt("worker_exe") {
            Some(w) => w.as_str()?.to_string(),
            None => d.worker_exe,
        },
        max_respawns: match v.opt("max_respawns") {
            Some(m) => {
                let m = m.as_u64()?;
                anyhow::ensure!(
                    m <= u32::MAX as u64,
                    "elastic.max_respawns {m} out of range"
                );
                m as u32
            }
            None => d.max_respawns,
        },
        respawn_backoff_ms,
        respawn_backoff_max_ms,
    })
}

/// `repro serve` daemon settings, settable from the `"serve"` config
/// object and overridable per-flag (`--port`, `--bind`,
/// `--ring-capacity`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// TCP port to listen on (0 = kernel-assigned ephemeral port).
    pub port: u16,
    /// Bind address (loopback by default: the daemon is unauthenticated).
    pub bind: String,
    /// Capacity of the in-memory `StepRecord` ring served by `/records`.
    pub ring_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { port: 7878, bind: "127.0.0.1".into(), ring_capacity: 4096 }
    }
}

fn parse_serve(v: &Value) -> Result<ServeConfig> {
    let d = ServeConfig::default();
    let port = match v.opt("port") {
        Some(p) => {
            let p = p.as_u64()?;
            anyhow::ensure!(p <= u16::MAX as u64, "serve.port {p} out of range");
            p as u16
        }
        None => d.port,
    };
    Ok(ServeConfig {
        port,
        bind: match v.opt("bind") {
            Some(b) => b.as_str()?.to_string(),
            None => d.bind,
        },
        ring_capacity: match v.opt("ring_capacity") {
            Some(r) => {
                let r = r.as_usize()?;
                anyhow::ensure!(r > 0, "serve.ring_capacity must be positive");
                r
            }
            None => d.ring_capacity,
        },
    })
}

impl TrainConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json_text(&text).context("parsing train config JSON")
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let lr = parse_lr(v.get("lr")?)?;
        let batch_size = parse_batch_size(v.get("batch_size")?)?;
        Ok(Self {
            model: v.get("model")?.as_str()?.to_string(),
            artifacts: match v.opt("artifacts") {
                Some(a) => a.as_str()?.to_string(),
                None => "artifacts".into(),
            },
            steps: v.get("steps")?.as_u64()?,
            seed: v.get("seed")?.as_u64()?,
            ranks: match v.opt("ranks") {
                Some(r) => r.as_usize()?,
                None => 1,
            },
            lr,
            batch_size,
            gns_alpha: match v.opt("gns_alpha") {
                Some(a) => a.as_f64()?,
                None => 0.05,
            },
            corpus_bytes: match v.opt("corpus_bytes") {
                Some(c) => c.as_usize()?,
                None => 1 << 20,
            },
            eval_every: match v.opt("eval_every") {
                Some(e) => e.as_u64()?,
                None => 0,
            },
            metrics_path: match v.opt("metrics_path") {
                Some(m) => m.as_str()?.to_string(),
                None => String::new(),
            },
            checkpoint_dir: match v.opt("checkpoint_dir") {
                Some(c) => c.as_str()?.to_string(),
                None => String::new(),
            },
            checkpoint_every: match v.opt("checkpoint_every") {
                Some(c) => c.as_u64()?,
                None => 0,
            },
            checkpoint_keep_last: match v.opt("checkpoint_keep_last") {
                Some(k) => {
                    let k = k.as_usize()?;
                    anyhow::ensure!(
                        k > 0,
                        "checkpoint_keep_last must be positive when given \
                         (omit the key to keep every checkpoint)"
                    );
                    k
                }
                None => 0,
            },
            resume: match v.opt("resume") {
                Some(r) => r.as_str()?.to_string(),
                None => String::new(),
            },
            threads: match v.opt("threads") {
                Some(t) => t.as_usize()?,
                None => 0,
            },
            force_scalar: match v.opt("force_scalar") {
                Some(f) => f.as_bool()?,
                None => false,
            },
            rank_mode: match v.opt("rank_mode") {
                Some(m) => RankMode::parse(m.as_str()?)?,
                None => RankMode::Threads,
            },
            elastic: match v.opt("elastic") {
                Some(e) => parse_elastic(e)?,
                None => ElasticConfig::default(),
            },
            serve: match v.opt("serve") {
                Some(s) => parse_serve(s)?,
                None => ServeConfig::default(),
            },
            norm_kind: match v.opt("norm_kind") {
                Some(n) => Some(n.as_str()?.parse()?),
                None => None,
            },
            norm_placement: match v.opt("norm_placement") {
                Some(p) => Some(p.as_str()?.parse()?),
                None => None,
            },
        })
    }

    /// The resolved normalization kind (default cell when unset).
    pub fn norm(&self) -> NormKind {
        self.norm_kind.unwrap_or_default()
    }

    /// The resolved normalization placement (default cell when unset).
    pub fn placement(&self) -> NormPlacement {
        self.norm_placement.unwrap_or_default()
    }

    /// A small default used by tests and the quickstart example.
    pub fn quickstart(model: &str, steps: u64) -> Self {
        Self {
            model: model.to_string(),
            artifacts: "artifacts".into(),
            steps,
            seed: 0,
            ranks: 1,
            lr: LrSchedule { max_lr: 1e-3, min_lr: 1e-4, warmup_steps: 10, decay_steps: steps },
            batch_size: BatchSizeSchedule::Fixed { accum: 2 },
            gns_alpha: 0.05,
            corpus_bytes: 1 << 18,
            eval_every: 0,
            metrics_path: String::new(),
            checkpoint_dir: String::new(),
            checkpoint_every: 0,
            checkpoint_keep_last: 0,
            resume: String::new(),
            threads: 0,
            force_scalar: false,
            rank_mode: RankMode::Threads,
            elastic: ElasticConfig::default(),
            serve: ServeConfig::default(),
            norm_kind: None,
            norm_placement: None,
        }
    }
}

fn parse_lr(v: &Value) -> Result<LrSchedule> {
    Ok(LrSchedule {
        max_lr: v.get("max_lr")?.as_f64()?,
        min_lr: v.get("min_lr")?.as_f64()?,
        warmup_steps: v.get("warmup_steps")?.as_u64()?,
        decay_steps: v.get("decay_steps")?.as_u64()?,
    })
}

/// `{"kind": "fixed", "accum": 4}` |
/// `{"kind": "linear", "min_accum": 1, "max_accum": 8, "ramp_tokens": 1e6}` |
/// `{"kind": "adaptive", "min_accum": 1, "max_accum": 8, "gain": 0.5}`
fn parse_batch_size(v: &Value) -> Result<BatchSizeSchedule> {
    match v.get("kind")?.as_str()? {
        "fixed" => Ok(BatchSizeSchedule::Fixed { accum: v.get("accum")?.as_usize()? }),
        "linear" => Ok(BatchSizeSchedule::Linear {
            min_accum: v.get("min_accum")?.as_usize()?,
            max_accum: v.get("max_accum")?.as_usize()?,
            ramp_tokens: v.get("ramp_tokens")?.as_u64()?,
        }),
        "adaptive" => Ok(BatchSizeSchedule::Adaptive {
            min_accum: v.get("min_accum")?.as_usize()?,
            max_accum: v.get("max_accum")?.as_usize()?,
            gain: v.get("gain")?.as_f64()?,
        }),
        k => bail!("unknown batch_size kind {k:?} (fixed|linear|adaptive)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let text = r#"{
            "model": "small",
            "steps": 100,
            "seed": 1,
            "ranks": 2,
            "lr": {"max_lr": 6e-4, "min_lr": 6e-5, "warmup_steps": 10, "decay_steps": 90},
            "batch_size": {"kind": "linear", "min_accum": 1, "max_accum": 8, "ramp_tokens": 100000},
            "gns_alpha": 0.02,
            "metrics_path": "results/run.csv",
            "threads": 4,
            "force_scalar": true
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.model, "small");
        assert_eq!(cfg.ranks, 2);
        assert!((cfg.gns_alpha - 0.02).abs() < 1e-12);
        assert!(matches!(cfg.batch_size, BatchSizeSchedule::Linear { max_accum: 8, .. }));
        assert_eq!(cfg.threads, 4);
        assert!(cfg.force_scalar);
    }

    #[test]
    fn defaults_applied() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.ranks, 1);
        assert_eq!(cfg.corpus_bytes, 1 << 20);
        assert_eq!(cfg.metrics_path, "");
        assert_eq!(cfg.threads, 0);
        assert!(!cfg.force_scalar);
    }

    #[test]
    fn rejects_unknown_schedule() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "exponential", "accum": 2}
        }"#;
        assert!(TrainConfig::from_json_text(text).is_err());
    }

    #[test]
    fn serve_keys_parse_and_default() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "serve": {"port": 9000, "bind": "0.0.0.0", "ring_capacity": 128}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.serve.port, 9000);
        assert_eq!(cfg.serve.bind, "0.0.0.0");
        assert_eq!(cfg.serve.ring_capacity, 128);

        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.serve, ServeConfig::default());
        assert_eq!(cfg.serve.bind, "127.0.0.1");
    }

    #[test]
    fn serve_keys_rejected_out_of_range() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "serve": {"port": 70000}
        }"#;
        assert!(TrainConfig::from_json_text(text).is_err());
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "serve": {"ring_capacity": 0}
        }"#;
        assert!(TrainConfig::from_json_text(text).is_err());
    }

    #[test]
    fn rank_mode_and_elastic_keys_parse() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "rank_mode": "process",
            "elastic": {"heartbeat_ms": 50, "step_timeout_s": 12.5, "spawn_timeout_s": 5.0}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.rank_mode, RankMode::Process);
        assert_eq!(cfg.elastic.heartbeat_ms, 50);
        assert!((cfg.elastic.step_timeout_s - 12.5).abs() < 1e-12);
        assert!((cfg.elastic.spawn_timeout_s - 5.0).abs() < 1e-12);
        assert_eq!(cfg.elastic.worker_exe, "");

        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.rank_mode, RankMode::Threads);
        assert_eq!(cfg.elastic, ElasticConfig::default());
    }

    #[test]
    fn rank_mode_rejects_unknown_and_bad_elastic() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "rank_mode": "fibers"
        }"#;
        assert!(TrainConfig::from_json_text(text).is_err());
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "elastic": {"heartbeat_ms": 0}
        }"#;
        assert!(TrainConfig::from_json_text(text).is_err());
    }

    #[test]
    fn respawn_and_retention_keys_parse() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "checkpoint_keep_last": 3,
            "elastic": {"max_respawns": 5, "respawn_backoff_ms": 100, "respawn_backoff_max_ms": 2000}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.checkpoint_keep_last, 3);
        assert_eq!(cfg.elastic.max_respawns, 5);
        assert_eq!(cfg.elastic.respawn_backoff_ms, 100);
        assert_eq!(cfg.elastic.respawn_backoff_max_ms, 2000);

        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.checkpoint_keep_last, 0);
        assert_eq!(cfg.elastic.max_respawns, 3);
        assert_eq!(cfg.elastic.respawn_backoff_ms, 500);
    }

    #[test]
    fn respawn_and_retention_keys_rejected_when_degenerate() {
        // An explicit keep_last of 0 is ambiguous (looks like "keep
        // nothing") and is rejected; omit the key to keep everything.
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "checkpoint_keep_last": 0
        }"#;
        let err = TrainConfig::from_json_text(text).unwrap_err().to_string();
        assert!(err.contains("checkpoint_keep_last"), "got: {err}");

        // Backoff floor of zero would spin respawn attempts.
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "elastic": {"respawn_backoff_ms": 0}
        }"#;
        assert!(TrainConfig::from_json_text(text).is_err());

        // Ceiling below the floor is a contradiction, not a clamp.
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "elastic": {"respawn_backoff_ms": 1000, "respawn_backoff_max_ms": 100}
        }"#;
        let err = TrainConfig::from_json_text(text).unwrap_err().to_string();
        assert!(err.contains("respawn_backoff_max_ms"), "got: {err}");

        // Zero/negative deadlines were already rejected; keep proving it.
        for bad in ["\"step_timeout_s\": 0.0", "\"step_timeout_s\": -1.5", "\"spawn_timeout_s\": 0"]
        {
            let text = format!(
                r#"{{
                "model": "nano", "steps": 5, "seed": 0,
                "lr": {{"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5}},
                "batch_size": {{"kind": "fixed", "accum": 2}},
                "elastic": {{{bad}}}
            }}"#
            );
            assert!(TrainConfig::from_json_text(&text).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn norm_variant_keys_parse_and_default() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "norm_kind": "rms", "norm_placement": "peri-ln"
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.norm_kind, Some(NormKind::RmsNorm));
        assert_eq!(cfg.norm_placement, Some(NormPlacement::PeriLn));
        assert_eq!(cfg.norm(), NormKind::RmsNorm);
        assert_eq!(cfg.placement(), NormPlacement::PeriLn);

        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.norm_kind, None);
        assert_eq!(cfg.norm(), NormKind::LayerNorm);
        assert_eq!(cfg.placement(), NormPlacement::PreLn);

        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "fixed", "accum": 2},
            "norm_kind": "rmsnrom"
        }"#;
        let err = TrainConfig::from_json_text(text).unwrap_err();
        assert!(format!("{err:#}").contains("rmsnorm"), "{err:#}");
    }

    #[test]
    fn adaptive_schedule_parses() {
        let text = r#"{
            "model": "nano", "steps": 5, "seed": 0,
            "lr": {"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": 5},
            "batch_size": {"kind": "adaptive", "min_accum": 1, "max_accum": 16, "gain": 0.5}
        }"#;
        let cfg = TrainConfig::from_json_text(text).unwrap();
        assert!(matches!(cfg.batch_size, BatchSizeSchedule::Adaptive { .. }));
    }
}
