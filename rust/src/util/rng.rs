//! Deterministic PRNG: splitmix64 seeding a xoshiro256** core, plus
//! uniform/range/Gaussian sampling (Box–Muller). Replaces `rand`/
//! `rand_distr` in this offline build; statistical quality is more than
//! sufficient for corpus generation and the GNS simulator.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

/// Full serializable state of an [`Rng`] (checkpoint/resume): the
/// xoshiro256** words plus the cached Box–Muller variate. Restoring this
/// state resumes the stream bitwise-exactly where it left off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare: None }
    }

    /// Capture the full generator state (checkpointing).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare: self.spare }
    }

    /// Rebuild a generator from a captured [`RngState`].
    pub fn from_state(st: RngState) -> Self {
        Self { s: st.s, spare: st.spare }
    }

    /// xoshiro256** next.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.f64() * (hi - lo) as f64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            let n = r.range(5, 17);
            assert!((5..17).contains(&n));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "{var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let kurt = xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n as f64 / var.powi(2);
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
        assert!((kurt - 3.0).abs() < 0.15, "{kurt}");
    }

    #[test]
    fn state_round_trip_resumes_stream() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..17 {
            r.next_u64();
        }
        r.normal(); // populate the Box–Muller spare
        let st = r.state();
        let a: Vec<u64> = (0..10).map(|_| r.next_u64()).collect();
        let na = r.normal();
        let mut q = Rng::from_state(st);
        let b: Vec<u64> = (0..10).map(|_| q.next_u64()).collect();
        let nb = q.normal();
        assert_eq!(a, b);
        assert_eq!(na.to_bits(), nb.to_bits());
    }

    #[test]
    fn bool_probability() {
        let mut r = Rng::seed_from_u64(6);
        let hits = (0..100_000).filter(|_| r.bool(0.3)).count();
        assert!((hits as f64 / 1e5 - 0.3).abs() < 0.01);
    }
}
