//! Self-contained substrates that would normally come from crates.io.
//!
//! This build is fully offline: only the `xla` crate's vendored dependency
//! closure is available, so the usual ecosystem crates (serde, rand,
//! clap, criterion, proptest) are re-implemented here at the scale this
//! project needs. Each is small, tested, and deterministic.

pub mod benchkit;
pub mod crc;
pub mod faultkit;
pub mod json;
pub mod prop;
pub mod rng;
