//! Tiny property-testing harness (the offline stand-in for proptest).
//!
//! `forall(seed, cases, gen, check)` generates `cases` random inputs with
//! a deterministic [`Rng`] and asserts the property on each; on failure it
//! panics with the case index and a Debug dump of the failing input, which
//! together with the fixed seed makes every failure reproducible. No
//! shrinking — inputs are kept small by construction instead.

use std::fmt::Debug;

use super::rng::Rng;

pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, check: C)
where
    T: Debug,
    G: FnMut(&mut Rng) -> T,
    C: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::seed_from_u64(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!("property failed at case {case} (seed {seed}): {msg}\ninput: {input:#?}");
        }
    }
}

/// Convenience: build a Vec of `len` items from a generator.
pub fn vec_of<T>(rng: &mut Rng, len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    (0..len).map(|_| f(rng)).collect()
}

/// Assert-style helper returning Result for `forall` checks.
#[macro_export]
macro_rules! prop_check {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            0,
            200,
            |r| (r.range(0, 100), r.range(0, 100)),
            |&(a, b)| {
                prop_check!(a + b >= a, "overflowed");
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_invalid_property() {
        forall(
            0,
            200,
            |r| r.range(0, 100),
            |&x| {
                prop_check!(x < 50, "x = {x} not < 50");
                Ok(())
            },
        );
    }

    #[test]
    fn deterministic_inputs() {
        let mut seen1 = Vec::new();
        forall(7, 10, |r| r.next_u64(), |&x| {
            // collect via side effect is awkward; regenerate instead
            let _ = x;
            Ok(())
        });
        let mut rng = Rng::seed_from_u64(7);
        for _ in 0..10 {
            seen1.push(rng.next_u64());
        }
        let mut rng2 = Rng::seed_from_u64(7);
        let seen2: Vec<u64> = (0..10).map(|_| rng2.next_u64()).collect();
        assert_eq!(seen1, seen2);
    }
}
