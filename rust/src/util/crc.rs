//! Hand-rolled CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) —
//! the integrity primitive for elastic frames and checkpoint payloads in
//! this offline build (no `crc32fast`). Uses the slice-by-8 table method
//! so checksumming a parameter-sized buffer stays far below 1% of a
//! training step (the train_step bench asserts this).

use std::sync::OnceLock;

const POLY: u32 = 0xEDB8_8320;

/// 8 tables × 256 entries: `TABLES[k][b]` advances the CRC by one byte
/// `b` that sits `k` positions ahead in the 8-byte block.
fn tables() -> &'static [[u32; 256]; 8] {
    static TABLES: OnceLock<[[u32; 256]; 8]> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for (b, slot) in t[0].iter_mut().enumerate() {
            let mut crc = b as u32;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            }
            *slot = crc;
        }
        for k in 1..8 {
            for b in 0..256 {
                let prev = t[k - 1][b];
                t[k][b] = (prev >> 8) ^ t[0][(prev & 0xff) as usize];
            }
        }
        t
    })
}

/// Streaming CRC-32: feed bytes incrementally, then [`Crc32::finish`].
/// Used to checksum checkpoint payload groups without a second buffer.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        let t = tables();
        let mut crc = self.state;
        while data.len() >= 8 {
            let lo = crc ^ u32::from_le_bytes([data[0], data[1], data[2], data[3]]);
            crc = t[7][(lo & 0xff) as usize]
                ^ t[6][((lo >> 8) & 0xff) as usize]
                ^ t[5][((lo >> 16) & 0xff) as usize]
                ^ t[4][(lo >> 24) as usize]
                ^ t[3][data[4] as usize]
                ^ t[2][data[5] as usize]
                ^ t[1][data[6] as usize]
                ^ t[0][data[7] as usize];
            data = &data[8..];
        }
        for &b in data {
            crc = (crc >> 8) ^ t[0][((crc ^ b as u32) & 0xff) as usize];
        }
        self.state = crc;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bitwise-at-a-time reference implementation (the oracle).
    fn crc32_naive(data: &[u8]) -> u32 {
        let mut crc = 0xFFFF_FFFFu32;
        for &b in data {
            crc ^= b as u32;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            }
        }
        crc ^ 0xFFFF_FFFF
    }

    #[test]
    fn known_vectors() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn slice_by_8_matches_naive_on_random_inputs() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..200 {
            let len = rng.range(0, 257);
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            assert_eq!(crc32(&data), crc32_naive(&data), "len={len}");
        }
    }

    #[test]
    fn streaming_split_points_agree() {
        let data: Vec<u8> = (0..1024).map(|i| (i * 37 % 251) as u8).collect();
        let whole = crc32(&data);
        for split in [0usize, 1, 7, 8, 9, 511, 1024] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), whole, "split={split}");
        }
    }

    #[test]
    fn single_bit_flips_always_change_the_crc() {
        let data: Vec<u8> = (0..256).map(|i| i as u8).collect();
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut m = data.clone();
                m[byte] ^= 1 << bit;
                assert_ne!(crc32(&m), base, "flip at {byte}:{bit} undetected");
            }
        }
    }
}
