//! Deterministic, seedable fault injection for the failure-domain tests
//! and the CI chaos matrix.
//!
//! Disarmed (the default — `NANOGNS_FAULT_PLAN` unset) every query is a
//! single cached atomic load returning "no fault", so the hooks compiled
//! into the elastic and checkpoint hot paths cost nothing measurable
//! (the train_step bench asserts the integrity paths stay under 1% of a
//! step). Armed, the plan drives *deterministic* faults: every rule
//! counts its own trigger events with an atomic counter, so "the 3rd
//! checkpoint write" or "every 13th frame" means the same thing on every
//! run, and the corruption byte position is derived from the plan seed —
//! never from wall-clock or OS randomness.
//!
//! ## Plan DSL
//!
//! `NANOGNS_FAULT_PLAN` is a `;`-separated list of clauses, each
//! `site@spec` where `spec` is a comma-separated list of `key:value`
//! items (a bare integer is the site's primary count `n`):
//!
//! | site            | meaning                                              |
//! |-----------------|------------------------------------------------------|
//! | `ckpt.enospc@N` | the Nth checkpoint publish fails like ENOSPC         |
//! | `ckpt.torn@N`   | the Nth checkpoint publish writes a torn (truncated) payload but still renames it into place |
//! | `frame.drop@every:K`  | drop every Kth outgoing protocol frame         |
//! | `frame.corrupt@N`     | corrupt the Nth outgoing protocol frame        |
//! | `hb.delay@F`    | multiply the worker heartbeat period by F            |
//! | `worker.exit@step:N`  | exit(86) while serving the Nth step command    |
//! | `step.stall@N,ms:M`   | sleep M ms before serving the Nth step command |
//! | `connect.fail@N`      | fail the first N transport connect attempts    |
//! | `seed@S`        | seed for corruption-position choices (default 0)     |
//!
//! Any clause may carry `worker:W` to scope it to rank-worker process
//! `W` (the supervisor's worker slot index, which workers learn from
//! `--worker` and register via [`set_scope`]); unscoped clauses apply in
//! every process that inherits the environment variable, coordinator
//! included. Example:
//!
//! ```text
//! NANOGNS_FAULT_PLAN="frame.corrupt@4,worker:1;ckpt.enospc@3;seed@7"
//! ```
//!
//! A malformed plan aborts the process immediately with a parse error on
//! stderr — a chaos run that silently ignores its plan would "pass" by
//! testing nothing.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Scope value meaning "this process is the coordinator, not a worker".
const COORD: usize = usize::MAX;

static SCOPE: AtomicUsize = AtomicUsize::new(COORD);
static PLAN: OnceLock<Option<Plan>> = OnceLock::new();

/// Checkpoint-publish faults (queried by `checkpoint::publish_bytes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptFault {
    /// Fail the write as if the filesystem returned ENOSPC.
    Enospc,
    /// Write only half the payload, then publish it anyway (torn write).
    Torn,
}

/// Outgoing-frame faults (queried by the protocol write path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameFault {
    /// Skip sending the frame entirely.
    Drop,
    /// Send the frame with a corrupted CRC trailer.
    Corrupt,
}

/// Step-command faults (queried by the rank worker's serve loop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepFault {
    /// `process::exit(86)` before replying.
    Exit,
    /// Sleep this many milliseconds before serving the step.
    StallMs(u64),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SiteKind {
    CkptEnospc,
    CkptTorn,
    FrameDrop,
    FrameCorrupt,
    HbDelay,
    WorkerExit,
    StepStall,
    ConnectFail,
}

#[derive(Debug)]
struct Rule {
    site: SiteKind,
    /// Primary count: the Nth event, every-Kth period, or delay factor.
    n: u64,
    /// Millisecond argument (`step.stall` only).
    ms: u64,
    /// Only fire in the process whose [`set_scope`] matches.
    worker: Option<usize>,
    hits: AtomicU64,
}

/// A parsed fault plan. Constructed once per process from
/// `NANOGNS_FAULT_PLAN`; all counters live for the process lifetime.
#[derive(Debug)]
pub struct Plan {
    text: String,
    seed: u64,
    rules: Vec<Rule>,
}

impl Plan {
    fn parse(text: &str) -> Result<Self, String> {
        let mut rules = Vec::new();
        let mut seed = 0u64;
        for clause in text.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (site, spec) = clause
                .split_once('@')
                .ok_or_else(|| format!("clause {clause:?} is missing '@'"))?;
            let mut n: Option<u64> = None;
            let mut ms = 0u64;
            let mut worker = None;
            for item in spec.split(',').map(str::trim).filter(|i| !i.is_empty()) {
                let (key, val) = match item.split_once(':') {
                    Some((k, v)) => (k.trim(), v.trim()),
                    None => ("", item),
                };
                let parsed: u64 = val
                    .parse()
                    .map_err(|_| format!("clause {clause:?}: {val:?} is not an integer"))?;
                match key {
                    // Bare integers and the site-specific count aliases
                    // all set the primary count.
                    "" | "every" | "step" => n = Some(parsed),
                    "ms" => ms = parsed,
                    "worker" => worker = Some(parsed as usize),
                    other => return Err(format!("clause {clause:?}: unknown key {other:?}")),
                }
            }
            if site.trim() == "seed" {
                seed = n.ok_or_else(|| format!("clause {clause:?}: seed needs a value"))?;
                continue;
            }
            let kind = match site.trim() {
                "ckpt.enospc" => SiteKind::CkptEnospc,
                "ckpt.torn" => SiteKind::CkptTorn,
                "frame.drop" => SiteKind::FrameDrop,
                "frame.corrupt" => SiteKind::FrameCorrupt,
                "hb.delay" => SiteKind::HbDelay,
                "worker.exit" => SiteKind::WorkerExit,
                "step.stall" => SiteKind::StepStall,
                "connect.fail" => SiteKind::ConnectFail,
                other => return Err(format!("unknown fault site {other:?}")),
            };
            let n = n.ok_or_else(|| format!("clause {clause:?} needs a count"))?;
            if n == 0 {
                return Err(format!("clause {clause:?}: count must be >= 1"));
            }
            if kind == SiteKind::StepStall && ms == 0 {
                return Err(format!("clause {clause:?}: step.stall needs ms:<delay>"));
            }
            rules.push(Rule { site: kind, n, ms, worker, hits: AtomicU64::new(0) });
        }
        Ok(Self { text: text.to_string(), seed, rules })
    }

    /// The raw plan text (surfaced on `/ranks` as the run's fault state).
    pub fn text(&self) -> &str {
        &self.text
    }

    /// Iterate rules in `family` that apply in `scope`, bumping each
    /// matching rule's hit counter, and return the first that fires.
    /// Rules outside the family are untouched: each accessor counts only
    /// its own event stream, so (say) frame traffic can never consume a
    /// `ckpt.enospc` clause's "nth publish" counter.
    fn fire<T>(
        &self,
        scope: usize,
        family: &[SiteKind],
        mut f: impl FnMut(&Rule, u64) -> Option<T>,
    ) -> Option<T> {
        let mut fired = None;
        for rule in &self.rules {
            if !family.contains(&rule.site) || rule.worker.is_some_and(|w| w != scope) {
                continue;
            }
            let hit = rule.hits.fetch_add(1, Ordering::Relaxed) + 1;
            if fired.is_none() {
                fired = f(rule, hit);
            }
        }
        fired
    }

    fn ckpt_fault(&self, scope: usize) -> Option<CkptFault> {
        self.fire(scope, &[SiteKind::CkptEnospc, SiteKind::CkptTorn], |r, hit| match r.site {
            SiteKind::CkptEnospc if hit == r.n => Some(CkptFault::Enospc),
            SiteKind::CkptTorn if hit == r.n => Some(CkptFault::Torn),
            _ => None,
        })
    }

    fn frame_fault(&self, scope: usize) -> Option<FrameFault> {
        self.fire(scope, &[SiteKind::FrameDrop, SiteKind::FrameCorrupt], |r, hit| match r.site {
            SiteKind::FrameDrop if hit % r.n == 0 => Some(FrameFault::Drop),
            SiteKind::FrameCorrupt if hit == r.n => Some(FrameFault::Corrupt),
            _ => None,
        })
    }

    fn step_fault(&self, scope: usize) -> Option<StepFault> {
        self.fire(scope, &[SiteKind::WorkerExit, SiteKind::StepStall], |r, hit| match r.site {
            SiteKind::WorkerExit if hit == r.n => Some(StepFault::Exit),
            SiteKind::StepStall if hit == r.n => Some(StepFault::StallMs(r.ms)),
            _ => None,
        })
    }

    fn connect_fails(&self, scope: usize) -> bool {
        self.fire(scope, &[SiteKind::ConnectFail], |r, hit| match r.site {
            SiteKind::ConnectFail if hit <= r.n => Some(()),
            _ => None,
        })
        .is_some()
    }

    fn hb_factor(&self, scope: usize) -> u64 {
        self.rules
            .iter()
            .filter(|r| r.site == SiteKind::HbDelay && !r.worker.is_some_and(|w| w != scope))
            .map(|r| r.n)
            .max()
            .unwrap_or(1)
    }
}

fn init_from_env() -> Option<Plan> {
    let text = std::env::var("NANOGNS_FAULT_PLAN").ok()?;
    if text.trim().is_empty() {
        return None;
    }
    match Plan::parse(&text) {
        Ok(p) => {
            eprintln!("faultkit: armed with plan {text:?}");
            Some(p)
        }
        Err(e) => {
            // A chaos run with an ignored plan would pass by testing
            // nothing — fail the process instead.
            eprintln!("faultkit: invalid NANOGNS_FAULT_PLAN: {e}");
            std::process::exit(2);
        }
    }
}

/// The process-wide plan, or `None` when disarmed. First call parses the
/// environment; later calls are one atomic load.
pub fn plan() -> Option<&'static Plan> {
    PLAN.get_or_init(init_from_env).as_ref()
}

/// Cheap hot-path guard: is any fault plan armed in this process?
#[inline]
pub fn armed() -> bool {
    plan().is_some()
}

/// Register this process as rank-worker `w` so `worker:W`-scoped clauses
/// can target it (the coordinator never calls this).
pub fn set_scope(worker: usize) {
    SCOPE.store(worker, Ordering::Relaxed);
}

fn scope() -> usize {
    SCOPE.load(Ordering::Relaxed)
}

/// Should this checkpoint publish fail, and how? Counts publish attempts.
pub fn on_ckpt_write() -> Option<CkptFault> {
    plan()?.ckpt_fault(scope())
}

/// Should this outgoing frame be dropped or corrupted? Counts frames.
pub fn on_frame_send() -> Option<FrameFault> {
    plan()?.frame_fault(scope())
}

/// Should this step command stall or kill the worker? Counts commands.
pub fn on_step_command() -> Option<StepFault> {
    plan()?.step_fault(scope())
}

/// Should this transport connect attempt fail? Counts attempts.
pub fn on_connect_attempt() -> bool {
    plan().is_some_and(|p| p.connect_fails(scope()))
}

/// Multiplier for the worker heartbeat period (1 = no delay).
pub fn heartbeat_factor() -> u64 {
    plan().map_or(1, |p| p.hb_factor(scope()))
}

/// Deterministic corruption position in a buffer of `len` bytes, derived
/// from the plan seed and a per-call salt (e.g. the frame counter).
pub fn corrupt_index(len: usize, salt: u64) -> usize {
    let seed = plan().map_or(0, |p| p.seed);
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed ^ salt.wrapping_mul(0x9E37_79B9));
    rng.range(0, len.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_plan() {
        let p = Plan::parse(
            "ckpt.enospc@3; frame.drop@every:13,worker:2; hb.delay@20; \
             worker.exit@step:5,worker:1; step.stall@2,ms:1500; connect.fail@2; seed@9",
        )
        .unwrap();
        assert_eq!(p.rules.len(), 6);
        assert_eq!(p.seed, 9);
        assert_eq!(p.rules[1].n, 13);
        assert_eq!(p.rules[1].worker, Some(2));
        assert_eq!(p.rules[4].ms, 1500);
    }

    #[test]
    fn rejects_malformed_plans() {
        for bad in [
            "ckpt.enospc",           // missing '@'
            "ckpt.enospc@zero",      // non-integer
            "ckpt.enospc@0",         // zero count
            "nosuch.site@1",         // unknown site
            "ckpt.enospc@1,foo:2",   // unknown key
            "step.stall@2",          // stall without ms
            "seed@",                 // empty seed
        ] {
            assert!(Plan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn nth_event_semantics_are_deterministic() {
        let p = Plan::parse("ckpt.enospc@3;ckpt.torn@5").unwrap();
        let fired: Vec<Option<CkptFault>> = (0..6).map(|_| p.ckpt_fault(COORD)).collect();
        assert_eq!(
            fired,
            vec![None, None, Some(CkptFault::Enospc), None, Some(CkptFault::Torn), None]
        );
    }

    #[test]
    fn every_kth_frame_drop_and_nth_corrupt() {
        let p = Plan::parse("frame.drop@every:3;frame.corrupt@4").unwrap();
        let fired: Vec<Option<FrameFault>> = (0..7).map(|_| p.frame_fault(COORD)).collect();
        assert_eq!(
            fired,
            vec![
                None,
                None,
                Some(FrameFault::Drop),
                Some(FrameFault::Corrupt),
                None,
                Some(FrameFault::Drop),
                None,
            ]
        );
    }

    #[test]
    fn worker_scoping_filters_rules_and_counters() {
        let p = Plan::parse("frame.corrupt@2,worker:1").unwrap();
        // Coordinator-scope queries neither fire nor consume the counter.
        assert_eq!(p.frame_fault(COORD), None);
        assert_eq!(p.frame_fault(COORD), None);
        assert_eq!(p.frame_fault(1), None);
        assert_eq!(p.frame_fault(1), Some(FrameFault::Corrupt));
        assert_eq!(p.frame_fault(1), None);
    }

    #[test]
    fn families_keep_independent_counters() {
        // A frame clause and a ckpt clause in one plan: frame traffic
        // must not advance the ckpt clause's "nth publish" counter, and
        // vice versa (process-mode runs interleave both event streams).
        let p = Plan::parse("ckpt.enospc@2;frame.corrupt@2").unwrap();
        assert_eq!(p.frame_fault(COORD), None);
        assert_eq!(p.ckpt_fault(COORD), None);
        assert_eq!(p.frame_fault(COORD), Some(FrameFault::Corrupt));
        assert_eq!(p.ckpt_fault(COORD), Some(CkptFault::Enospc));
    }

    #[test]
    fn step_and_connect_and_heartbeat_sites() {
        let p = Plan::parse("step.stall@1,ms:250;worker.exit@step:2;connect.fail@2;hb.delay@8")
            .unwrap();
        assert_eq!(p.step_fault(COORD), Some(StepFault::StallMs(250)));
        assert_eq!(p.step_fault(COORD), Some(StepFault::Exit));
        assert_eq!(p.step_fault(COORD), None);
        assert!(p.connect_fails(COORD));
        assert!(p.connect_fails(COORD));
        assert!(!p.connect_fails(COORD));
        assert_eq!(p.hb_factor(COORD), 8);
        assert_eq!(Plan::parse("ckpt.torn@1").unwrap().hb_factor(COORD), 1);
    }
}
