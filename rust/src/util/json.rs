//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact manifest and run configs; no \u surrogate pairs beyond BMP).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, ensure, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        ensure!(p.i == p.b.len(), "trailing junk at byte {}", p.i);
        Ok(v)
    }

    /// `Num` for finite floats, `Null` otherwise. JSON has no NaN/inf
    /// literal, so telemetry serializers must degrade to null rather
    /// than emit unparseable output.
    pub fn finite_or_null(x: f64) -> Value {
        if x.is_finite() {
            Value::Num(x)
        } else {
            Value::Null
        }
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (getting {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        ensure!(f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64, "not a u64: {f}");
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek()? == c, "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let n = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(n).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: find the full sequence
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        0xf0..=0xf7 => 4,
                        _ => bail!("bad utf8 byte"),
                    };
                    let start = self.i - 1;
                    ensure!(start + len <= self.b.len(), "truncated utf8");
                    s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
        assert!(!v.get("c").unwrap().as_bool().unwrap());
        assert!(v.get("zzz").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("'single'").is_err());
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null}"#,
            r#"[true,false,null,0.5]"#,
            r#""quote \" backslash \\ newline \n""#,
        ];
        for c in cases {
            let v = Value::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Value::parse(&s).unwrap(), v, "{c}");
        }
    }

    #[test]
    fn unicode() {
        let v = Value::parse(r#""café naïve""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café naïve");
    }

    #[test]
    fn u64_accessor_checks() {
        assert_eq!(Value::parse("42").unwrap().as_u64().unwrap(), 42);
        assert!(Value::parse("1.5").unwrap().as_u64().is_err());
        assert!(Value::parse("-1").unwrap().as_u64().is_err());
    }
}
