//! Tiny benchmark harness (the offline stand-in for criterion).
//!
//! Auto-calibrates iteration counts to a target measurement time, runs
//! warmup + timed samples, and reports mean / stddev / median / min per
//! iteration. Results are also appended to `results/bench.csv` so figure
//! harnesses (Fig. 8) can consume them, and can be collected into a
//! machine-readable `BENCH_*.json` via [`BenchJson`] so the perf
//! trajectory is comparable across PRs.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Value;

pub struct Bench {
    group: String,
    /// target wall time per measurement batch
    target: Duration,
    samples: usize,
    csv: Option<std::fs::File>,
}

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    /// Median of the per-sample means — the robust per-PR trajectory
    /// number `BENCH_*.json` records.
    pub median_ns: f64,
    pub min_ns: f64,
    pub iters: u64,
    /// Number of timed samples behind the statistics.
    pub samples: usize,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        std::fs::create_dir_all("results").ok();
        let csv = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open("results/bench.csv")
            .ok();
        Self {
            group: group.to_string(),
            target: Duration::from_millis(200),
            samples: 10,
            csv,
        }
    }

    pub fn with_target_ms(mut self, ms: u64) -> Self {
        self.target = Duration::from_millis(ms);
        self
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f`, printing and returning per-iteration stats.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // calibrate: how many iterations fit in the target time?
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= self.target / 4 || iters >= 1 << 24 {
                let per = dt.as_nanos().max(1) as f64 / iters as f64;
                iters = ((self.target.as_nanos() as f64 / per).ceil() as u64).max(1);
                break;
            }
            iters *= 4;
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let var = samples_ns.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / samples_ns.len() as f64;
        let min = samples_ns.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut sorted = samples_ns.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if sorted.len() % 2 == 1 {
            sorted[sorted.len() / 2]
        } else {
            0.5 * (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2])
        };
        let stats = Stats {
            name: name.to_string(),
            mean_ns: mean,
            std_ns: var.sqrt(),
            median_ns: median,
            min_ns: min,
            iters,
            samples: samples_ns.len(),
        };
        println!(
            "{:<40} {:>12} ± {:>10}  (min {:>12}, {} iters/sample)",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.std_ns),
            fmt_ns(stats.min_ns),
            iters
        );
        if let Some(fcsv) = self.csv.as_mut() {
            use std::io::Write;
            let _ = writeln!(
                fcsv,
                "{},{},{:.1},{:.1},{:.1},{}",
                self.group, name, stats.mean_ns, stats.std_ns, stats.min_ns, iters
            );
        }
        stats
    }
}

/// Machine-readable bench report: `name → {median_ns, samples,
/// throughput}`, written as a `BENCH_*.json` file at the workspace root
/// so the perf trajectory is diffable across PRs.
///
/// `throughput` is items/sec when the caller supplies an items-per-
/// iteration count (tokens for train steps), else iterations/sec.
#[derive(Default)]
pub struct BenchJson {
    entries: BTreeMap<String, (f64, usize, f64)>,
    meta: BTreeMap<String, Value>,
}

impl BenchJson {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stamp the report as a *recorded* baseline (written by the
    /// record-baseline workflow on real CI hardware). Absolute-median
    /// gates in [`compare_bench_reports`] only arm against recorded
    /// baselines; hand-written floors leave this off.
    pub fn set_recorded(&mut self, source: &str) {
        self.meta.insert("recorded".to_string(), Value::Bool(true));
        self.meta.insert("source".to_string(), Value::Str(source.to_string()));
    }

    /// Record one benchmark under `name` (conventionally
    /// `"group/entry"`). `items_per_iter` scales the throughput figure.
    pub fn record(&mut self, name: &str, stats: &Stats, items_per_iter: Option<f64>) {
        let per_iter = items_per_iter.unwrap_or(1.0);
        let throughput =
            if stats.median_ns > 0.0 { per_iter * 1e9 / stats.median_ns } else { 0.0 };
        self.entries.insert(name.to_string(), (stats.median_ns, stats.samples, throughput));
    }

    pub fn to_value(&self) -> Value {
        let mut top = BTreeMap::new();
        if !self.meta.is_empty() {
            top.insert("_meta".to_string(), Value::Obj(self.meta.clone()));
        }
        for (name, (median, samples, thr)) in &self.entries {
            let mut e = BTreeMap::new();
            e.insert("median_ns".to_string(), Value::Num(*median));
            e.insert("samples".to_string(), Value::Num(*samples as f64));
            e.insert("throughput".to_string(), Value::Num(*thr));
            top.insert(name.clone(), Value::Obj(e));
        }
        Value::Obj(top)
    }

    /// Write the report. Relative paths are resolved against the
    /// *workspace* root (cargo runs bench binaries with CWD = package
    /// dir, which would scatter `BENCH_*.json` under `rust/` instead of
    /// the documented repo-root location). Returns the resolved path.
    pub fn write(&self, path: &str) -> std::io::Result<std::path::PathBuf> {
        let mut target = std::path::PathBuf::from(path);
        if target.is_relative() {
            target = workspace_root().join(target);
        }
        std::fs::write(&target, self.to_value().to_string())?;
        Ok(target)
    }

    /// [`Self::write`] for bench binaries: prints the destination on
    /// success and exits the process with code 1 on failure, so a CI
    /// gate on any bench cannot silently pass over an unwritable report.
    pub fn write_or_exit(&self, path: &str) {
        match self.write(path) {
            Ok(p) => println!("wrote {}", p.display()),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Bench-report comparison (the CI bench-regression gate)
// ---------------------------------------------------------------------------

/// One entry's baseline-vs-current median comparison (informational: raw
/// medians are machine-dependent, so they never gate).
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
    /// `(current - baseline) / baseline`, in percent (positive = slower).
    pub delta_pct: f64,
}

/// One group's fused-path gate verdict. The gated metric is the
/// *within-run* speedup `per_example_median / fused_median`: both runs
/// measure it on their own machine, so the ratio-of-ratios comparison is
/// portable across CI hardware, unlike absolute nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchGate {
    pub group: String,
    pub baseline_speedup: f64,
    pub current_speedup: f64,
    /// Relative speedup loss in percent (positive = fused path regressed).
    pub regress_pct: f64,
    pub pass: bool,
}

/// One kernel entry's absolute-median gate verdict. Unlike the portable
/// ratio gate, absolute medians only mean something against a baseline
/// recorded on the same CI hardware pool, so these gates arm only when
/// the baseline carries `_meta.recorded = true` (stamped by the
/// record-baseline workflow).
#[derive(Debug, Clone)]
pub struct BenchAbsGate {
    pub name: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
    /// `(current - baseline) / baseline`, in percent (positive = slower).
    pub regress_pct: f64,
    pub pass: bool,
}

/// Full outcome of comparing two `BENCH_*.json` reports.
#[derive(Debug, Clone)]
pub struct BenchCompare {
    pub deltas: Vec<BenchDelta>,
    pub gates: Vec<BenchGate>,
    pub abs_gates: Vec<BenchAbsGate>,
    /// Whether the baseline was a recorded run (arms the absolute gates).
    pub baseline_recorded: bool,
}

impl BenchCompare {
    pub fn all_pass(&self) -> bool {
        self.gates.iter().all(|g| g.pass) && self.abs_gates.iter().all(|g| g.pass)
    }
}

const FUSED_ENTRY: &str = "grad_microbatch";
const ORACLE_ENTRY: &str = "grad_microbatch_per_example";
/// Bench groups gated on absolute medians (kernel microbenches: small,
/// allocation-free, low-variance — the only entries where an absolute
/// wall-clock budget is meaningful on fixed CI hardware).
const ABS_GATE_PREFIX: &str = "kernel_";

fn median_of(report: &Value, name: &str) -> Option<f64> {
    let m = report.opt(name)?.opt("median_ns")?.as_f64().ok()?;
    (m.is_finite() && m > 0.0).then_some(m)
}

/// Compare two bench reports: per-entry median deltas for every name
/// present in both, plus the fused-path speedup gate per `step_*` group
/// carrying both the fused and per-example entries in the baseline.
/// A gate fails when the current speedup falls more than
/// `max_regress_pct` percent below the baseline speedup.
///
/// When the baseline carries `_meta.recorded = true` (i.e. it came from
/// a real run on the CI hardware pool, not a hand-written floor), every
/// `kernel_*` entry is additionally gated on its *absolute* median:
/// current may be at most `max_abs_regress_pct` percent slower.
///
/// Every gateable baseline group **must** be present in the current
/// report — a missing group is an error, not a silent pass, so a bench
/// that crashes or renames entries cannot quietly weaken the gate.
pub fn compare_bench_reports(
    baseline: &Value,
    current: &Value,
    max_regress_pct: f64,
    max_abs_regress_pct: f64,
) -> anyhow::Result<BenchCompare> {
    let base_obj = baseline.as_obj()?;
    let baseline_recorded = baseline
        .opt("_meta")
        .and_then(|m| m.opt("recorded"))
        .and_then(|v| v.as_bool().ok())
        .unwrap_or(false);
    let mut deltas = Vec::new();
    let mut gates = Vec::new();
    let mut abs_gates = Vec::new();
    let mut missing: Vec<String> = Vec::new();
    for (name, entry) in base_obj {
        if name.starts_with('_') {
            continue; // _meta / _note annotations, not bench entries
        }
        let Ok(b) = entry.get("median_ns").and_then(|v| v.as_f64()) else { continue };
        if !(b.is_finite() && b > 0.0) {
            continue;
        }
        if let Some(c) = median_of(current, name) {
            deltas.push(BenchDelta {
                name: name.clone(),
                baseline_ns: b,
                current_ns: c,
                delta_pct: 100.0 * (c - b) / b,
            });
        }
        // Absolute gate: kernel microbench medians vs a recorded baseline.
        if baseline_recorded && name.starts_with(ABS_GATE_PREFIX) {
            match median_of(current, name) {
                Some(c) => {
                    let regress_pct = 100.0 * (c - b) / b;
                    abs_gates.push(BenchAbsGate {
                        name: name.clone(),
                        baseline_ns: b,
                        current_ns: c,
                        regress_pct,
                        pass: regress_pct <= max_abs_regress_pct,
                    });
                }
                None => missing.push(name.clone()),
            }
        }
        // Ratio gate accounting: driven by the *baseline's* fused/oracle
        // pairs.
        let Some(group) = name.strip_suffix(&format!("/{FUSED_ENTRY}")) else { continue };
        let oracle = format!("{group}/{ORACLE_ENTRY}");
        let Some(bo) = median_of(baseline, &oracle) else { continue };
        let (Some(c), Some(co)) = (median_of(current, name), median_of(current, &oracle)) else {
            missing.push(group.to_string());
            continue;
        };
        let baseline_speedup = bo / b;
        let current_speedup = co / c;
        let regress_pct = 100.0 * (baseline_speedup - current_speedup) / baseline_speedup;
        gates.push(BenchGate {
            group: group.to_string(),
            baseline_speedup,
            current_speedup,
            regress_pct,
            pass: regress_pct <= max_regress_pct,
        });
    }
    anyhow::ensure!(
        missing.is_empty(),
        "current report is missing gated entries {missing:?}: the bench dropped or renamed \
         entries the baseline gates on"
    );
    anyhow::ensure!(
        !gates.is_empty(),
        "no gateable groups: baseline has no {FUSED_ENTRY}/{ORACLE_ENTRY} pairs"
    );
    Ok(BenchCompare { deltas, gates, abs_gates, baseline_recorded })
}

/// Nearest ancestor of `CARGO_MANIFEST_DIR` whose Cargo.toml declares
/// `[workspace]` (the workspace root — anchoring on the declaration
/// avoids over-climbing into an unrelated outer Rust project); falls
/// back to the manifest dir, or the current directory outside cargo.
fn workspace_root() -> std::path::PathBuf {
    let start = std::env::var_os("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let mut dir = start.as_path();
    while let Some(parent) = dir.parent() {
        let manifest = parent.join("Cargo.toml");
        if !manifest.exists() {
            break;
        }
        if let Ok(s) = std::fs::read_to_string(&manifest) {
            if s.contains("[workspace]") {
                return parent.to_path_buf();
            }
        }
        dir = parent;
    }
    start
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").with_target_ms(5).with_samples(3);
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0 && s.mean_ns.is_finite());
        assert!(s.min_ns <= s.mean_ns);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn median_is_ordered_and_finite() {
        let mut b = Bench::new("test").with_target_ms(5).with_samples(4);
        let mut acc = 0u64;
        let s = b.run("median", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.median_ns.is_finite() && s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert_eq!(s.samples, 4);
    }

    fn report(entries: &[(&str, f64)]) -> Value {
        let mut j = BenchJson::new();
        for (name, median) in entries {
            let stats = Stats {
                name: name.to_string(),
                mean_ns: *median,
                std_ns: 0.0,
                median_ns: *median,
                min_ns: *median,
                iters: 1,
                samples: 3,
            };
            j.record(name, &stats, None);
        }
        j.to_value()
    }

    #[test]
    fn compare_passes_when_speedup_holds() {
        // baseline: 4x speedup; current: 3.8x on a machine 2x slower —
        // absolute medians regress, the portable ratio barely moves.
        let base = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
        ]);
        let cur = report(&[
            ("step_small/grad_microbatch", 2_000.0),
            ("step_small/grad_microbatch_per_example", 7_600.0),
        ]);
        let out = compare_bench_reports(&base, &cur, 15.0, 50.0).unwrap();
        assert!(out.all_pass(), "{:?}", out.gates);
        assert_eq!(out.gates.len(), 1);
        assert!(!out.baseline_recorded && out.abs_gates.is_empty());
        let g = &out.gates[0];
        assert_eq!(g.group, "step_small");
        assert!((g.baseline_speedup - 4.0).abs() < 1e-9);
        assert!((g.current_speedup - 3.8).abs() < 1e-9);
        assert!((g.regress_pct - 5.0).abs() < 1e-9);
        // the informational deltas still show the absolute 2x slowdown
        let d = out.deltas.iter().find(|d| d.name.ends_with(FUSED_ENTRY)).unwrap();
        assert!((d.delta_pct - 100.0).abs() < 1e-9);
    }

    #[test]
    fn compare_fails_on_fused_path_regression() {
        // fused path got 2x slower relative to the oracle: 4x -> 2x
        let base = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
        ]);
        let cur = report(&[
            ("step_small/grad_microbatch", 2_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
        ]);
        let out = compare_bench_reports(&base, &cur, 15.0, 50.0).unwrap();
        assert!(!out.all_pass());
        assert!((out.gates[0].regress_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn compare_rejects_reports_with_no_gateable_pairs() {
        let base = report(&[("step_small/eval_step", 500.0)]);
        let cur = report(&[("step_small/eval_step", 510.0)]);
        assert!(compare_bench_reports(&base, &cur, 15.0, 50.0).is_err());
    }

    #[test]
    fn compare_rejects_current_missing_a_gated_group() {
        // a bench that drops entries the baseline gates on must fail the
        // gate loudly, not silently narrow its coverage
        let base = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
            ("step_gone/grad_microbatch", 1_000.0),
            ("step_gone/grad_microbatch_per_example", 4_000.0),
        ]);
        let cur = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
        ]);
        let err = compare_bench_reports(&base, &cur, 15.0, 50.0).unwrap_err();
        assert!(format!("{err}").contains("step_gone"), "{err}");
    }

    #[test]
    fn compare_ignores_extra_current_entries() {
        // new bench entries (e.g. parallel_rank_step_*) without baseline
        // counterparts are informational, never gated
        let base = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
        ]);
        let cur = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
            ("step_small/parallel_rank_step_w4", 2_000.0),
        ]);
        let out = compare_bench_reports(&base, &cur, 15.0, 50.0).unwrap();
        assert_eq!(out.gates.len(), 1);
        assert!(out.all_pass());
    }

    /// Same entries, baseline stamped as recorded: kernel_* medians gate
    /// on absolute time, step_* entries never do.
    fn recorded_report(entries: &[(&str, f64)]) -> Value {
        let mut v = report(entries);
        if let Value::Obj(m) = &mut v {
            let mut meta = std::collections::BTreeMap::new();
            meta.insert("recorded".to_string(), Value::Bool(true));
            meta.insert("source".to_string(), Value::Str("test".to_string()));
            m.insert("_meta".to_string(), Value::Obj(meta));
        }
        v
    }

    #[test]
    fn abs_gates_arm_only_against_recorded_baselines() {
        let entries: &[(&str, f64)] = &[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
            ("kernel_matmul/xwt_64x64", 10_000.0),
        ];
        // kernel entry 3x slower in current
        let cur = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
            ("kernel_matmul/xwt_64x64", 30_000.0),
        ]);
        // unrecorded baseline: informational only, still passes
        let out = compare_bench_reports(&report(entries), &cur, 15.0, 50.0).unwrap();
        assert!(out.abs_gates.is_empty() && out.all_pass());
        // recorded baseline: the 200% regression trips the 50% budget
        let out = compare_bench_reports(&recorded_report(entries), &cur, 15.0, 50.0).unwrap();
        assert!(out.baseline_recorded);
        assert_eq!(out.abs_gates.len(), 1);
        assert!(!out.all_pass());
        assert!((out.abs_gates[0].regress_pct - 200.0).abs() < 1e-9);
        // within budget passes
        let ok = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
            ("kernel_matmul/xwt_64x64", 12_000.0),
        ]);
        let out = compare_bench_reports(&recorded_report(entries), &ok, 15.0, 50.0).unwrap();
        assert!(out.all_pass());
    }

    #[test]
    fn abs_gates_error_on_missing_kernel_entry() {
        let base = recorded_report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
            ("kernel_gram/weight_sqnorms_8x16", 5_000.0),
        ]);
        let cur = report(&[
            ("step_small/grad_microbatch", 1_000.0),
            ("step_small/grad_microbatch_per_example", 4_000.0),
        ]);
        let err = compare_bench_reports(&base, &cur, 15.0, 50.0).unwrap_err();
        assert!(format!("{err}").contains("kernel_gram"), "{err}");
    }

    #[test]
    fn set_recorded_round_trips_through_json() {
        let mut j = BenchJson::new();
        j.set_recorded("ci-ubuntu-latest");
        let stats = Stats {
            name: "x".into(),
            mean_ns: 1.0,
            std_ns: 0.0,
            median_ns: 1.0,
            min_ns: 1.0,
            iters: 1,
            samples: 1,
        };
        j.record("kernel_matmul/xwt_64x64", &stats, None);
        let v = Value::parse(&j.to_value().to_string()).unwrap();
        assert!(v.get("_meta").unwrap().get("recorded").unwrap().as_bool().unwrap());
        assert!(v.opt("kernel_matmul/xwt_64x64").is_some());
    }

    #[test]
    fn json_report_round_trips() {
        let stats = Stats {
            name: "grad_microbatch".to_string(),
            mean_ns: 2e6,
            std_ns: 1e4,
            median_ns: 2e6,
            min_ns: 1.9e6,
            iters: 10,
            samples: 5,
        };
        let mut j = BenchJson::new();
        j.record("step_small/grad_microbatch", &stats, Some(256.0));
        let v = Value::parse(&j.to_value().to_string()).unwrap();
        let e = v.get("step_small/grad_microbatch").unwrap();
        assert_eq!(e.get("median_ns").unwrap().as_f64().unwrap(), 2e6);
        assert_eq!(e.get("samples").unwrap().as_f64().unwrap(), 5.0);
        // 256 items every 2ms = 128k items/sec
        let thr = e.get("throughput").unwrap().as_f64().unwrap();
        assert!((thr - 128_000.0).abs() < 1.0, "{thr}");
    }
}
