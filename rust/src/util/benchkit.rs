//! Tiny benchmark harness (the offline stand-in for criterion).
//!
//! Auto-calibrates iteration counts to a target measurement time, runs
//! warmup + timed samples, and reports mean / stddev / min per iteration.
//! Results are also appended to `results/bench.csv` so figure harnesses
//! (Fig. 8) can consume them.

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    /// target wall time per measurement batch
    target: Duration,
    samples: usize,
    csv: Option<std::fs::File>,
}

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub iters: u64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        std::fs::create_dir_all("results").ok();
        let csv = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open("results/bench.csv")
            .ok();
        Self {
            group: group.to_string(),
            target: Duration::from_millis(200),
            samples: 10,
            csv,
        }
    }

    pub fn with_target_ms(mut self, ms: u64) -> Self {
        self.target = Duration::from_millis(ms);
        self
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f`, printing and returning per-iteration stats.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // calibrate: how many iterations fit in the target time?
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= self.target / 4 || iters >= 1 << 24 {
                let per = dt.as_nanos().max(1) as f64 / iters as f64;
                iters = ((self.target.as_nanos() as f64 / per).ceil() as u64).max(1);
                break;
            }
            iters *= 4;
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let var = samples_ns.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / samples_ns.len() as f64;
        let min = samples_ns.iter().cloned().fold(f64::INFINITY, f64::min);
        let stats = Stats {
            name: name.to_string(),
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: min,
            iters,
        };
        println!(
            "{:<40} {:>12} ± {:>10}  (min {:>12}, {} iters/sample)",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.std_ns),
            fmt_ns(stats.min_ns),
            iters
        );
        if let Some(fcsv) = self.csv.as_mut() {
            use std::io::Write;
            let _ = writeln!(
                fcsv,
                "{},{},{:.1},{:.1},{:.1},{}",
                self.group, name, stats.mean_ns, stats.std_ns, stats.min_ns, iters
            );
        }
        stats
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").with_target_ms(5).with_samples(3);
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0 && s.mean_ns.is_finite());
        assert!(s.min_ns <= s.mean_ns);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
