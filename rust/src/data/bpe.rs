//! Minimal byte-pair encoding tokenizer (train + encode + decode).
//!
//! The shipped models use the byte-level tokenizer (vocab 256 baked into
//! the artifacts), but the data pipeline is tokenizer-agnostic; this BPE
//! exists so larger-vocab configs can be exported without new Rust code,
//! and as the natural upgrade path a downstream user would reach for.

use std::collections::HashMap;

/// A trained BPE vocabulary: 256 byte tokens + learned merges.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// merge list in training order: (left, right) -> new token id
    merges: Vec<(i32, i32)>,
    /// rank lookup for encoding
    ranks: HashMap<(i32, i32), usize>,
}

impl Bpe {
    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    /// Train `n_merges` merges on `text` (greedy most-frequent pair).
    pub fn train(text: &str, n_merges: usize) -> Self {
        let mut ids: Vec<i32> = text.bytes().map(|b| b as i32).collect();
        let mut merges = Vec::with_capacity(n_merges);
        let mut ranks = HashMap::new();
        for m in 0..n_merges {
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // deterministic argmax: highest count, ties by smallest pair
            let Some((&pair, &count)) = counts
                .iter()
                .max_by_key(|(pair, count)| (**count, std::cmp::Reverse(**pair)))
            else {
                break;
            };
            if count < 2 {
                break; // nothing worth merging
            }
            let new_id = 256 + m as i32;
            merges.push(pair);
            ranks.insert(pair, m);
            ids = Self::apply_merge(&ids, pair, new_id);
        }
        Self { merges, ranks }
    }

    fn apply_merge(ids: &[i32], pair: (i32, i32), new_id: i32) -> Vec<i32> {
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                out.push(new_id);
                i += 2;
            } else {
                out.push(ids[i]);
                i += 1;
            }
        }
        out
    }

    /// Encode text by repeatedly applying the lowest-rank applicable merge.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids: Vec<i32> = text.bytes().map(|b| b as i32).collect();
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, position)
            for (i, w) in ids.windows(2).enumerate() {
                if let Some(&rank) = self.ranks.get(&(w[0], w[1])) {
                    if best.is_none() || rank < best.unwrap().0 {
                        best = Some((rank, i));
                    }
                }
            }
            let Some((rank, _)) = best else { break };
            let pair = self.merges[rank];
            ids = Self::apply_merge(&ids, pair, 256 + rank as i32);
        }
        ids
    }

    /// Expand one token id to its byte sequence.
    fn expand(&self, id: i32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else {
            let (l, r) = self.merges[(id - 256) as usize];
            self.expand(l, out);
            self.expand(r, out);
        }
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.expand(id, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> String {
        crate::data::corpus::CorpusGenerator::new(3).generate(1 << 15)
    }

    #[test]
    fn round_trip() {
        let text = corpus();
        let bpe = Bpe::train(&text, 100);
        assert_eq!(bpe.vocab_size(), 356);
        let sample = &text[..512];
        assert_eq!(bpe.decode(&bpe.encode(sample)), sample);
    }

    #[test]
    fn compresses_repetitive_text() {
        let text = corpus();
        let bpe = Bpe::train(&text, 200);
        let ids = bpe.encode(&text[..4096]);
        let ratio = ids.len() as f64 / 4096.0;
        assert!(ratio < 0.6, "compression ratio {ratio}");
    }

    #[test]
    fn deterministic_training() {
        let text = corpus();
        let a = Bpe::train(&text, 50);
        let b = Bpe::train(&text, 50);
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn encode_respects_merge_order() {
        // train on "abab...": first merge must be ('a','b')
        let text = "ab".repeat(64);
        let bpe = Bpe::train(&text, 4);
        assert_eq!(bpe.merges[0], (b'a' as i32, b'b' as i32));
        let ids = bpe.encode("abab");
        assert!(ids.iter().all(|&i| i >= 256), "{ids:?}");
    }

    #[test]
    fn handles_text_with_no_merges() {
        let bpe = Bpe::train("abcdefg", 10); // all pairs unique -> no merges
        assert_eq!(bpe.vocab_size(), 256);
        assert_eq!(bpe.decode(&bpe.encode("xyz")), "xyz");
    }

    #[test]
    fn prop_round_trip_ascii() {
        let text = corpus();
        let bpe = Bpe::train(&text, 64);
        crate::util::prop::forall(
            93,
            100,
            |r| {
                let n = r.range(0, 120);
                (0..n).map(|_| (r.range(0x20, 0x7f) as u8) as char).collect::<String>()
            },
            |s| {
                crate::prop_check!(bpe.decode(&bpe.encode(s)) == *s, "round trip failed");
                Ok(())
            },
        );
    }
}
