//! Data pipeline: synthetic corpus generation, byte-level tokenization and
//! deterministic batch loading.
//!
//! The paper trains on OpenWebText; this substrate replaces it (DESIGN.md
//! §Substitutions) with a procedurally generated corpus that has natural-
//! language-like statistics — Zipfian unigrams with Markov bigram structure
//! and sentence/paragraph punctuation — so the model has real structure to
//! learn and the loss curve and GNS dynamics behave qualitatively like a
//! text run.

pub mod bpe;
pub mod corpus;
pub mod loader;
pub mod tokenizer;

pub use corpus::CorpusGenerator;
pub use loader::{Batch, Loader};
pub use bpe::Bpe;
pub use tokenizer::ByteTokenizer;
