//! Procedural text corpus with natural-language-like statistics.
//!
//! Generates sentences from a fixed synthetic vocabulary sampled with a
//! Zipfian unigram distribution, chained through a sparse Markov bigram
//! model (each word prefers a small set of successors), with punctuation,
//! capitalization and paragraph breaks. Deterministic given a seed.
//!
//! This gives the byte-level model several things to learn in sequence —
//! character statistics, word spellings, bigram structure — which produces
//! the staged loss-curve and rising-GNS dynamics the paper's OpenWebText
//! runs show.

use crate::util::rng::Rng;

/// Synthetic word stems; inflections are generated per word.
const STEMS: [&str; 60] = [
    "gradient", "noise", "scale", "batch", "layer", "norm", "model", "train",
    "loss", "step", "token", "data", "parameter", "update", "learning",
    "rate", "estimate", "variance", "sample", "example", "measure", "signal",
    "kernel", "tensor", "matrix", "vector", "linear", "embed", "attention",
    "network", "compute", "memory", "schedule", "optimal", "critical",
    "small", "large", "deep", "wide", "fast", "slow", "true", "mean",
    "sum", "ratio", "curve", "phase", "track", "guide", "save", "cost",
    "time", "run", "seed", "plot", "fit", "slope", "error", "bound", "work",
];

const SUFFIXES: [&str; 6] = ["", "s", "ed", "ing", "ly", "er"];

#[derive(Debug, Clone)]
pub struct CorpusGenerator {
    words: Vec<String>,
    /// Zipf CDF over words.
    cdf: Vec<f64>,
    /// successors[i] = preferred next-word indices for word i.
    successors: Vec<Vec<usize>>,
    rng: Rng,
}

impl CorpusGenerator {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut words = Vec::new();
        for stem in STEMS {
            for suf in SUFFIXES {
                words.push(format!("{stem}{suf}"));
            }
        }
        // Zipf(1.1) over the word list
        let s = 1.1;
        let weights: Vec<f64> = (1..=words.len()).map(|r| 1.0 / (r as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut cdf = Vec::with_capacity(words.len());
        let mut acc = 0.0;
        for w in &weights {
            acc += w / total;
            cdf.push(acc);
        }
        // sparse bigram structure: 4 preferred successors per word
        let n = words.len();
        let successors = (0..n)
            .map(|_| (0..4).map(|_| rng.range(0, n)).collect())
            .collect();
        Self { words, cdf, successors, rng }
    }

    fn sample_unigram(&mut self) -> usize {
        let u: f64 = self.rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(self.words.len() - 1),
        }
    }

    fn next_word(&mut self, prev: Option<usize>) -> usize {
        match prev {
            // 70% of the time follow the bigram structure
            Some(p) if self.rng.bool(0.7) => {
                let succ = &self.successors[p];
                succ[self.rng.range(0, succ.len())]
            }
            _ => self.sample_unigram(),
        }
    }

    fn sentence(&mut self) -> String {
        let len = self.rng.range(4, 14);
        let mut prev = None;
        let mut parts: Vec<String> = Vec::with_capacity(len);
        for _ in 0..len {
            let w = self.next_word(prev);
            parts.push(self.words[w].clone());
            prev = Some(w);
        }
        let mut s = parts.join(" ");
        // capitalize
        if let Some(c) = s.get_mut(0..1) {
            let up = c.to_uppercase();
            s.replace_range(0..1, &up);
        }
        let punct = if self.rng.bool(0.85) { "." } else { "?" };
        s.push_str(punct);
        s
    }

    /// Generate at least `n_bytes` of text.
    pub fn generate(&mut self, n_bytes: usize) -> String {
        let mut out = String::with_capacity(n_bytes + 128);
        let mut sentences_in_par = 0;
        while out.len() < n_bytes {
            out.push_str(&self.sentence());
            sentences_in_par += 1;
            if sentences_in_par >= self.rng.range(3, 7) {
                out.push_str("\n\n");
                sentences_in_par = 0;
            } else {
                out.push(' ');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn deterministic_given_seed() {
        let a = CorpusGenerator::new(7).generate(4096);
        let b = CorpusGenerator::new(7).generate(4096);
        assert_eq!(a, b);
        let c = CorpusGenerator::new(8).generate(4096);
        assert_ne!(a, c);
    }

    #[test]
    fn produces_requested_length() {
        let text = CorpusGenerator::new(0).generate(10_000);
        assert!(text.len() >= 10_000);
        assert!(text.len() < 11_000);
    }

    #[test]
    fn unigram_distribution_is_skewed() {
        // Zipf: the most common word should appear much more often than
        // the median word.
        let text = CorpusGenerator::new(1).generate(200_000);
        let mut counts: HashMap<&str, usize> = HashMap::new();
        for w in text.split_whitespace() {
            let w = w.trim_matches(|c: char| !c.is_alphanumeric());
            if !w.is_empty() {
                *counts.entry(w).or_default() += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().cloned().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] > 10 * freqs[freqs.len() / 2], "{:?}", &freqs[..5]);
    }

    #[test]
    fn text_is_ascii_printable() {
        let text = CorpusGenerator::new(2).generate(8192);
        assert!(text.bytes().all(|b| b == b'\n' || (0x20..0x7f).contains(&b)));
    }

    #[test]
    fn bigram_structure_present() {
        // With 70% bigram-following, some bigrams repeat far above chance.
        let text = CorpusGenerator::new(3).generate(200_000);
        let words: Vec<&str> = text.split_whitespace().collect();
        let mut bigrams: HashMap<(&str, &str), usize> = HashMap::new();
        for w in words.windows(2) {
            *bigrams.entry((w[0], w[1])).or_default() += 1;
        }
        let max = bigrams.values().max().copied().unwrap_or(0);
        assert!(max > 20, "max bigram count {max}");
    }
}
