//! Byte-level tokenizer: token id == byte value (vocab 256).
//!
//! The models are exported with vocab = 256, so tokenization is the
//! identity on bytes. Kept as a type (rather than inlining `as u8`) so the
//! loader/corpus code is tokenizer-agnostic and a BPE could be dropped in.

#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|&i| (i.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_ascii() {
        let t = ByteTokenizer;
        let s = "the quick brown fox. 123!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn ids_in_vocab() {
        let t = ByteTokenizer;
        for id in t.encode("hello \u{00e9}") {
            assert!((0..256).contains(&id));
        }
    }

    #[test]
    fn prop_round_trip_any_ascii() {
        crate::util::prop::forall(
            61,
            300,
            |r| {
                let n = r.range(0, 200);
                (0..n).map(|_| (r.range(0x20, 0x7f) as u8) as char).collect::<String>()
            },
            |s| {
                let t = ByteTokenizer;
                crate::prop_check!(t.decode(&t.encode(s)) == *s, "round trip failed");
                Ok(())
            },
        );
    }
}
