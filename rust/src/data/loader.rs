//! Deterministic sequence-packing batch loader.
//!
//! Tokenizes the corpus once, then serves `(B, T)` input/target windows
//! sampled at random offsets (seeded). Distinct DDP ranks get disjoint
//! sample streams by deriving their seeds from (seed, rank).

use crate::util::rng::{Rng, RngState};

use super::tokenizer::ByteTokenizer;

/// One training batch of token ids (row-major `(B, T)`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    pub inputs: Vec<i32>,
    pub targets: Vec<i32>,
}

#[derive(Debug, Clone)]
pub struct Loader {
    tokens: std::sync::Arc<Vec<i32>>,
    seq_len: usize,
    rng: Rng,
}

impl Loader {
    pub fn new(text: &str, seq_len: usize, seed: u64) -> Self {
        let tokens = std::sync::Arc::new(ByteTokenizer.encode(text));
        assert!(tokens.len() > seq_len + 1, "corpus shorter than one sequence");
        Self { tokens, seq_len, rng: Rng::seed_from_u64(seed) }
    }

    /// A loader over the same corpus with a rank-specific stream.
    pub fn for_rank(&self, rank: u64) -> Self {
        let mut rng = Rng::seed_from_u64(0x9e3779b97f4a7c15 ^ rank);
        let reseed: u64 = rng.next_u64();
        Self {
            tokens: self.tokens.clone(),
            seq_len: self.seq_len,
            rng: Rng::seed_from_u64(reseed),
        }
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Sampling cursor (the loader's RNG state). Together with the corpus
    /// seed this pins the exact batch stream, so a checkpointed run can
    /// resume on bitwise-identical data.
    pub fn cursor(&self) -> RngState {
        self.rng.state()
    }

    /// Restore a cursor captured by [`Self::cursor`].
    pub fn restore_cursor(&mut self, st: RngState) {
        self.rng = Rng::from_state(st);
    }

    /// Next `(B, T)` batch: inputs are windows, targets the same windows
    /// shifted by one token.
    pub fn next_batch(&mut self, batch: usize) -> Batch {
        let t = self.seq_len;
        let mut inputs = Vec::with_capacity(batch * t);
        let mut targets = Vec::with_capacity(batch * t);
        for _ in 0..batch {
            let start = self.rng.range(0, self.tokens.len() - t - 1);
            inputs.extend_from_slice(&self.tokens[start..start + t]);
            targets.extend_from_slice(&self.tokens[start + 1..start + t + 1]);
        }
        Batch { batch, seq_len: t, inputs, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> String {
        crate::data::corpus::CorpusGenerator::new(0).generate(8192)
    }

    #[test]
    fn batch_shapes() {
        let mut l = Loader::new(&corpus(), 32, 0);
        let b = l.next_batch(4);
        assert_eq!(b.inputs.len(), 4 * 32);
        assert_eq!(b.targets.len(), 4 * 32);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut l = Loader::new(&corpus(), 16, 1);
        let b = l.next_batch(2);
        for row in 0..2 {
            let i = &b.inputs[row * 16..(row + 1) * 16];
            let t = &b.targets[row * 16..(row + 1) * 16];
            assert_eq!(&i[1..], &t[..15]);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let text = corpus();
        let mut a = Loader::new(&text, 32, 42);
        let mut b = Loader::new(&text, 32, 42);
        assert_eq!(a.next_batch(3), b.next_batch(3));
        let mut c = Loader::new(&text, 32, 43);
        assert_ne!(a.next_batch(3), c.next_batch(3));
    }

    #[test]
    fn ranks_get_distinct_streams() {
        let text = corpus();
        let base = Loader::new(&text, 32, 0);
        let mut r0 = base.for_rank(0);
        let mut r1 = base.for_rank(1);
        assert_ne!(r0.next_batch(2), r1.next_batch(2));
    }

    #[test]
    fn cursor_round_trip_resumes_stream() {
        let text = corpus();
        let mut l = Loader::new(&text, 32, 11);
        l.next_batch(3);
        let cur = l.cursor();
        let a = l.next_batch(3);
        let mut m = Loader::new(&text, 32, 11);
        m.restore_cursor(cur);
        assert_eq!(a, m.next_batch(3));
    }

    #[test]
    #[should_panic]
    fn rejects_tiny_corpus() {
        Loader::new("abc", 32, 0);
    }

    #[test]
    fn prop_all_ids_in_vocab() {
        let text = corpus();
        crate::util::prop::forall(
            62,
            50,
            |r| (r.range(1, 5), r.next_u64() % 100),
            |&(bsz, seed)| {
                let mut l = Loader::new(&text, 24, seed);
                let b = l.next_batch(bsz);
                crate::prop_check!(
                    b.inputs.iter().chain(&b.targets).all(|&i| (0..256).contains(&i)),
                    "id out of vocab"
                );
                Ok(())
            },
        );
    }
}
