//! Per-linear-layer FLOP/IO formulae (paper Appendix E, Tables 1 & 2).
//!
//! Notation: B = batch, T = sequence length, K = input dim, L = output dim.
//! "Simultaneous" is the paper's Algorithm 1; "Li" is Li et al. [36]'s
//! O(T^2) contraction; "LnOnly" is the LayerNorm-only tracking of §5
//! (per-layer cost shown for the normalization layers' K-vectors).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Simultaneous,
    Li,
    LnOnly,
}

#[derive(Debug, Clone, Copy)]
pub struct LinearCost {
    pub weight_grad_flops: u128,
    pub norm_flops: u128,
    pub weight_grad_io: u128,
    pub norm_io: u128,
}

impl LinearCost {
    pub fn total_flops(&self) -> u128 {
        self.weight_grad_flops + self.norm_flops
    }
    pub fn total_io(&self) -> u128 {
        self.weight_grad_io + self.norm_io
    }
}

/// Table 1 + Table 2 rows for one linear layer, 4-byte elements.
pub fn linear_cost(method: Method, b: u128, t: u128, k: u128, l: u128) -> LinearCost {
    let bytes = 4u128;
    match method {
        Method::Simultaneous => LinearCost {
            // BKL(2T-1) + KL(B-1)
            weight_grad_flops: b * k * l * (2 * t - 1) + k * l * (b - 1),
            // BKL + B(KL - 1)
            norm_flops: b * k * l + b * (k * l - 1),
            // BKL + BKT + BLT
            weight_grad_io: (b * k * l + b * k * t + b * l * t) * bytes,
            // BKL + B
            norm_io: (b * k * l + b) * bytes,
        },
        Method::Li => LinearCost {
            // KL(2BT - 1)
            weight_grad_flops: k * l * (2 * b * t - 1),
            // BT^2 (2K + 2L - 2) + BT^2
            norm_flops: b * t * t * (2 * k + 2 * l - 2) + b * t * t,
            // BKT + BLT + KL
            weight_grad_io: (b * k * t + b * l * t + k * l) * bytes,
            // 2BT^2 + B
            norm_io: (2 * b * t * t + b) * bytes,
        },
        // LayerNorm per-example norms: gradient vectors are K-sized; the
        // fused kernel touches x, g once (backward I/O) and adds B scalars.
        Method::LnOnly => LinearCost {
            weight_grad_flops: 2 * b * t * k,
            norm_flops: 2 * b * k,
            weight_grad_io: (2 * b * k * t + 2 * k) * bytes,
            norm_io: b * bytes,
        },
    }
}

/// Appendix E FLOP crossover: simultaneous becomes cheaper than Li for
/// `T > sqrt((2KL - 1) / (2K + 2L - 1))`.
pub fn flop_crossover_t(k: f64, l: f64) -> f64 {
    ((2.0 * k * l - 1.0) / (2.0 * k + 2.0 * l - 1.0)).sqrt()
}

/// Appendix E I/O crossover: `T = sqrt(2 K L) / 2`.
pub fn io_crossover_t(k: f64, l: f64) -> f64 {
    (2.0 * k * l).sqrt() / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simultaneous_norm_flops_independent_of_t() {
        // Fig. 3 (right) message: the extra FLOPs don't depend on T.
        let a = linear_cost(Method::Simultaneous, 8, 128, 512, 512).norm_flops;
        let b = linear_cost(Method::Simultaneous, 8, 4096, 512, 512).norm_flops;
        assert_eq!(a, b);
    }

    #[test]
    fn li_norm_flops_quadratic_in_t() {
        let f = |t| linear_cost(Method::Li, 1, t, 64, 64).norm_flops;
        let r = f(256) as f64 / f(128) as f64;
        assert!((r - 4.0).abs() < 0.05, "ratio {r}");
    }

    #[test]
    fn flop_crossover_matches_closed_form() {
        for (k, l) in [(256u128, 256u128), (512, 2048), (4096, 4096)] {
            let t_star = flop_crossover_t(k as f64, l as f64);
            let below = (t_star * 0.9) as u128;
            let above = (t_star * 1.1).ceil() as u128;
            let below_cost = |m| linear_cost(m, 1, below, k, l).norm_flops;
            let above_cost = |m| linear_cost(m, 1, above, k, l).norm_flops;
            assert!(below_cost(Method::Li) < below_cost(Method::Simultaneous));
            assert!(above_cost(Method::Li) > above_cost(Method::Simultaneous));
        }
    }

    #[test]
    fn io_crossover_matches_closed_form() {
        // Appendix E solves the norm-I/O terms: BKL + B vs 2BT^2 + B.
        for (k, l) in [(256u128, 256u128), (1024, 4096)] {
            let t_star = io_crossover_t(k as f64, l as f64);
            let below = (t_star * 0.8) as u128;
            let above = (t_star * 1.25).ceil() as u128;
            let f = |m, t| linear_cost(m, 4, t, k, l).norm_io;
            assert!(f(Method::Li, below) < f(Method::Simultaneous, below));
            assert!(f(Method::Li, above) > f(Method::Simultaneous, above));
        }
    }

    #[test]
    fn ln_only_is_much_cheaper() {
        // Fig. 4: "The IO cost of LN per-example gradient norms alone is
        // much lower than either method."
        let d = 2048;
        let ln = linear_cost(Method::LnOnly, 8, 2048, d, d).norm_io;
        let sim = linear_cost(Method::Simultaneous, 8, 2048, d, d).norm_io;
        let li = linear_cost(Method::Li, 8, 2048, d, d).norm_io;
        assert!(ln * 100 < sim && ln * 100 < li);
    }

    /// Table 1 identity: BKL(2T-1) + KL(B-1) == KL(2BT-1) — the
    /// simultaneous method computes the weight gradient with exactly the
    /// same FLOPs as the standard contraction (the paper's Section 3
    /// headline: only the cheap norm reduction is additional).
    #[test]
    fn prop_weight_grad_flops_identical() {
        crate::util::prop::forall(
            51,
            500,
            |r| {
                (
                    r.range(1, 64) as u128,
                    r.range(1, 1024) as u128,
                    r.range(1, 512) as u128,
                    r.range(1, 512) as u128,
                )
            },
            |&(b, t, k, l)| {
                let sim = linear_cost(Method::Simultaneous, b, t, k, l).weight_grad_flops;
                let li = linear_cost(Method::Li, b, t, k, l).weight_grad_flops;
                crate::prop_check!(sim == li, "sim {sim} != li {li}");
                Ok(())
            },
        );
    }

    /// Costs are monotone in every dimension.
    #[test]
    fn prop_monotone() {
        crate::util::prop::forall(
            52,
            500,
            |r| {
                (
                    r.range(1, 32) as u128,
                    r.range(2, 512) as u128,
                    r.range(2, 256) as u128,
                    r.range(2, 256) as u128,
                )
            },
            |&(b, t, k, l)| {
                for m in [Method::Simultaneous, Method::Li] {
                    crate::prop_check!(
                        linear_cost(m, b + 1, t, k, l).total_flops()
                            >= linear_cost(m, b, t, k, l).total_flops(),
                        "not monotone in b"
                    );
                    crate::prop_check!(
                        linear_cost(m, b, t + 1, k, l).total_io()
                            >= linear_cost(m, b, t, k, l).total_io(),
                        "not monotone in t"
                    );
                }
                Ok(())
            },
        );
    }
}
