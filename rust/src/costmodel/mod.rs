//! Analytic FLOP and I/O cost model for per-example gradient-norm methods
//! (paper Section 3.1, Appendix E — Tables 1 & 2, Figures 3 & 4).

pub mod linear;
pub mod mfu;
pub mod transformer;

pub use linear::{LinearCost, Method};
pub use mfu::{achieved_flops, mfu, Device};
pub use transformer::{transformer_cost, TransformerCost, TransformerShape};
