//! Model FLOPs Utilization (MFU) accounting, for the paper's Section 5.1 /
//! Appendix D.3 throughput claims (57% MFU with LN-only tracking vs 40%
//! with all-layer norms on H100s).

/// Peak dense-f32 (or bf16 where noted) throughput of referenced devices,
/// in FLOP/s. CPU entry is a nominal single-core AVX2 figure used to put
/// this testbed's throughput on the same axis.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Device {
    A10,
    H100Bf16,
    CpuCore,
    Custom(f64),
}

impl Device {
    pub fn peak_flops(&self) -> f64 {
        match self {
            // A10: 31.2 TFLOP/s fp32-TF32 tensor
            Device::A10 => 31.2e12,
            // H100 SXM bf16 tensor core (dense): 989 TFLOP/s
            Device::H100Bf16 => 989e12,
            // one modern x86 core, AVX2 FMA f32: ~1e11
            Device::CpuCore => 1e11,
            Device::Custom(p) => *p,
        }
    }
}

/// Achieved model FLOP/s for a training run: 6 * N * tokens/sec.
pub fn achieved_flops(n_params: u64, tokens_per_sec: f64) -> f64 {
    6.0 * n_params as f64 * tokens_per_sec
}

/// MFU = achieved / peak, in [0, 1+).
pub fn mfu(n_params: u64, tokens_per_sec: f64, device: Device) -> f64 {
    achieved_flops(n_params, tokens_per_sec) / device.peak_flops()
}

/// Tokens/sec needed to hit a target MFU on a device.
pub fn tokens_per_sec_for_mfu(n_params: u64, target_mfu: f64, device: Device) -> f64 {
    target_mfu * device.peak_flops() / (6.0 * n_params as f64)
}

/// Throughput penalty of measurement overhead: given the relative extra
/// FLOPs `rel` of an instrumentation scheme (e.g. from
/// `costmodel::transformer_cost(...).rel_flops`), the best-case MFU ratio
/// instrumented/uninstrumented is `1 / (1 + rel)`.
pub fn instrumented_mfu_ratio(rel_extra_flops: f64) -> f64 {
    1.0 / (1.0 + rel_extra_flops.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mfu_round_trip() {
        let n = 111_000_000u64;
        let tps = tokens_per_sec_for_mfu(n, 0.4, Device::A10);
        assert!((mfu(n, tps, Device::A10) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn paper_scale_sanity() {
        // 1.3B on 8 H100s at 57% MFU -> ~578k tok/s; per-device ~72k.
        let tps = tokens_per_sec_for_mfu(1_300_000_000, 0.57, Device::H100Bf16);
        assert!(tps > 5e4 && tps < 5e5, "{tps}");
    }

    #[test]
    fn ln_only_tracking_keeps_mfu() {
        use crate::costmodel::{transformer_cost, Method, TransformerShape};
        let shape = TransformerShape::from_params(1_300_000_000, 2048, 8);
        let ln = transformer_cost(&shape, Method::LnOnly);
        let sim = transformer_cost(&shape, Method::Simultaneous);
        // LN-only measurement costs essentially nothing; all-layer costs more
        assert!(instrumented_mfu_ratio(ln.rel_flops) > 0.999);
        assert!(instrumented_mfu_ratio(sim.rel_flops) < instrumented_mfu_ratio(ln.rel_flops));
    }

    #[test]
    fn cpu_testbed_axis() {
        // our e2e small run: 2.79M params; 100 tok/s would be ~1.7% of a core's peak
        let m = mfu(2_790_000, 100.0, Device::CpuCore);
        assert!(m > 0.0 && m < 1.0);
    }
}
