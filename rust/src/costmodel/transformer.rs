//! Whole-transformer cost aggregation for the Fig. 3 / Fig. 4 axes:
//! total per-example-gradient-norm cost vs model scale and context length,
//! and the proportional cost vs one forward+backward pass.

use super::linear::{linear_cost, LinearCost, Method};

/// GPT-family shape (decoder-only, 4x MLP, fused QKV).
#[derive(Debug, Clone, Copy)]
pub struct TransformerShape {
    pub d_model: u128,
    pub n_layers: u128,
    pub vocab: u128,
    pub seq_len: u128,
    pub batch: u128,
}

impl TransformerShape {
    /// Roughly 12 * d^2 per layer + embeddings, the usual estimate.
    pub fn n_params(&self) -> u128 {
        12 * self.d_model * self.d_model * self.n_layers
            + 2 * self.vocab * self.d_model
            + self.seq_len * self.d_model
    }

    /// Shape with d_model chosen to hit a parameter budget (layers scale
    /// as d/64, the GPT-3 family aspect ratio).
    pub fn from_params(target: u128, seq_len: u128, batch: u128) -> Self {
        let mut d = 128u128;
        loop {
            let s = TransformerShape {
                d_model: d,
                n_layers: (d / 64).max(2),
                vocab: 50_257,
                seq_len,
                batch,
            };
            if s.n_params() >= target || d > 65_536 {
                return s;
            }
            d += 64;
        }
    }

    /// The linear layers of one block: (K, L) pairs.
    fn block_linears(&self) -> [(u128, u128); 4] {
        let d = self.d_model;
        [(d, 3 * d), (d, d), (d, 4 * d), (4 * d, d)]
    }

    /// Model fwd+bwd FLOPs, 6 * params * tokens (the standard estimate the
    /// paper's FLOPCounterMode measurement approximates).
    pub fn train_flops(&self) -> u128 {
        6 * self.n_params() * self.batch * self.seq_len
    }
}

#[derive(Debug, Clone, Copy)]
pub struct TransformerCost {
    pub norm_flops: u128,
    pub norm_io: u128,
    /// Ratio of norm FLOPs to one full fwd+bwd pass.
    pub rel_flops: f64,
}

/// Total per-example-gradient-norm cost for a method over all linear
/// layers of the model (Fig. 3 left / Fig. 4). For `Method::LnOnly` the
/// cost covers the 2L+1 LayerNorm layers instead.
pub fn transformer_cost(shape: &TransformerShape, method: Method) -> TransformerCost {
    let (mut flops, mut io) = (0u128, 0u128);
    match method {
        Method::LnOnly => {
            let n_ln = 2 * shape.n_layers + 1;
            let c: LinearCost =
                linear_cost(Method::LnOnly, shape.batch, shape.seq_len, shape.d_model, 1);
            flops += n_ln * c.norm_flops;
            io += n_ln * c.norm_io;
        }
        m => {
            for (k, l) in shape.block_linears() {
                let c = linear_cost(m, shape.batch, shape.seq_len, k, l);
                flops += shape.n_layers * c.norm_flops;
                io += shape.n_layers * c.norm_io;
            }
            // LM head
            let c = linear_cost(m, shape.batch, shape.seq_len, shape.d_model, shape.vocab);
            flops += c.norm_flops;
            io += c.norm_io;
        }
    }
    TransformerCost {
        norm_flops: flops,
        norm_io: io,
        rel_flops: flops as f64 / shape.train_flops() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(d: u128, t: u128) -> TransformerShape {
        TransformerShape { d_model: d, n_layers: d / 64, vocab: 50_257, seq_len: t, batch: 8 }
    }

    #[test]
    fn param_count_sane() {
        // GPT-2 small-ish: d=768, 12 layers -> ~85M + embeddings
        let s = shape(768, 1024);
        let p = s.n_params();
        assert!(p > 100_000_000 && p < 200_000_000, "{p}");
    }

    #[test]
    fn from_params_hits_target() {
        for target in [125_000_000u128, 1_300_000_000, 13_000_000_000] {
            let s = TransformerShape::from_params(target, 2048, 8);
            let p = s.n_params();
            assert!(p >= target && p < target * 2, "target {target} got {p}");
        }
    }

    #[test]
    fn simultaneous_norm_flops_independent_of_context() {
        // Fig. 3: the simultaneous method's additional FLOPs are flat in T
        // (so its proportional cost never blows up with context length,
        // unlike Li et al.'s T^2 term).
        let a = transformer_cost(&shape(1024, 512), Method::Simultaneous).norm_flops;
        let b = transformer_cost(&shape(1024, 8192), Method::Simultaneous).norm_flops;
        assert_eq!(a, b);
        // and the relative cost is therefore non-increasing in T
        let ra = transformer_cost(&shape(1024, 512), Method::Simultaneous).rel_flops;
        let rb = transformer_cost(&shape(1024, 8192), Method::Simultaneous).rel_flops;
        assert!(rb <= ra, "{rb} > {ra}");
    }

    #[test]
    fn li_relative_flops_grow_with_context() {
        let a = transformer_cost(&shape(1024, 512), Method::Li).rel_flops;
        let b = transformer_cost(&shape(1024, 8192), Method::Li).rel_flops;
        assert!(b > 4.0 * a, "{a} vs {b}");
    }

    #[test]
    fn fig4_shape_io_tradeoff() {
        // Fig. 4: simultaneous wins at long context, loses at short
        // context for large models; LN-only is way below both.
        let big_short = shape(4096, 256);
        let big_long = shape(4096, 16384);
        let sim_s = transformer_cost(&big_short, Method::Simultaneous).norm_io;
        let li_s = transformer_cost(&big_short, Method::Li).norm_io;
        let sim_l = transformer_cost(&big_long, Method::Simultaneous).norm_io;
        let li_l = transformer_cost(&big_long, Method::Li).norm_io;
        assert!(li_s < sim_s, "short context: Li should win");
        assert!(li_l > sim_l, "long context: simultaneous should win");
        let ln = transformer_cost(&big_long, Method::LnOnly).norm_io;
        assert!(ln * 1000 < sim_l.min(li_l));
    }
}
