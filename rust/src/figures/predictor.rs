//! `repro figures --report predictor`: the normalization/architecture
//! matrix sweep (ROADMAP item 3).
//!
//! Trains every cell of [`NormKind::ALL`] × [`NormPlacement::ALL`] on the
//! reference backend and scores the paper's central claim per cell: the
//! norm-layer-only per-example GNS predicts the total GNS. For each cell
//! we fit total GNS on norm-only GNS over the post-warmup window and
//! report the OLS slope, `r²`, and the mean total/norm-only ratio, plus
//! a per-layer-type mean-GNS summary and a downsampled trajectory. The
//! machine-readable report lands at [`REPORT_PATH`]; a rendered verdict
//! table goes to stdout.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::trainer::StepRecord;
use crate::coordinator::Trainer;
use crate::gns::ema::ema_series;
use crate::gns::linreg;
use crate::norms::{NormKind, NormPlacement};
use crate::runtime::ReferenceVariantFactory;
use crate::schedule::LrSchedule;
use crate::util::json::Value;
use crate::STATS_ORDER;

/// Where [`report`] writes its JSON artifact.
pub const REPORT_PATH: &str = "results/predictor_report.json";

/// Offline smoothing constant for the per-layer summary (matches the
/// Fig. 7 mid-range alpha).
const ALPHA: f64 = 0.1;

/// Max points kept in each cell's serialized trajectory.
const TRAJ_POINTS: usize = 32;

/// One scored matrix cell.
struct Cell {
    norm: NormKind,
    placement: NormPlacement,
    final_loss: f64,
    /// OLS fit of total GNS on norm-only GNS (post-warmup window), or
    /// `None` when the window is degenerate (too short / zero variance).
    slope: Option<f64>,
    intercept: Option<f64>,
    r2: Option<f64>,
    /// mean(total GNS) / mean(norm-only GNS) over the window.
    ratio: Option<f64>,
    /// Window points used by the fit.
    n_fit: usize,
    /// Mean per-layer-type GNS over the window, in `STATS_ORDER`.
    per_layer: Vec<f64>,
    /// Downsampled `(step, gns_norm_only, gns_total)` trajectory.
    trajectory: Vec<(u64, f64, f64)>,
}

impl Cell {
    /// "holds" / "weak" / "breaks": does the norm-only predictor track
    /// the total GNS in this cell?
    fn verdict(&self) -> &'static str {
        match (self.r2, self.ratio) {
            (Some(r2), Some(ratio)) if r2 >= 0.6 && (0.1..=10.0).contains(&ratio) => "holds",
            (Some(r2), _) if r2 >= 0.3 => "weak",
            _ => "breaks",
        }
    }
}

/// Train and score every matrix cell, write [`REPORT_PATH`], and print
/// the verdict table. All cells share one seed and budget so the only
/// variable across rows is the normalization variant.
pub fn report(model: &str, steps: u64) -> Result<()> {
    println!("Predictor report: norm/placement matrix ({model}, {steps} steps per cell)");
    let mut cells = Vec::new();
    for norm in NormKind::ALL {
        for placement in NormPlacement::ALL {
            cells.push(run_cell(model, steps, norm, placement)?);
        }
    }

    println!(
        "{:>10} {:>8} {:>10} {:>8} {:>8} {:>8} {:>8}",
        "norm", "place", "final_loss", "slope", "r2", "ratio", "verdict"
    );
    for c in &cells {
        println!(
            "{:>10} {:>8} {:>10.4} {:>8} {:>8} {:>8} {:>8}",
            c.norm,
            c.placement,
            c.final_loss,
            fmt_opt(c.slope),
            fmt_opt(c.r2),
            fmt_opt(c.ratio),
            c.verdict()
        );
    }

    let path = super::results_path("predictor_report.json")?;
    std::fs::write(&path, report_json(model, steps, &cells).to_string())?;
    println!("(report -> {})", path.display());
    println!(
        "shape check (paper): preln/layernorm holds; the norm-only predictor should keep \
         tracking total GNS across the matrix"
    );
    Ok(())
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(v) => format!("{v:.3}"),
        None => "-".to_string(),
    }
}

/// Train one cell and score it from its step records.
fn run_cell(model: &str, steps: u64, norm: NormKind, placement: NormPlacement) -> Result<Cell> {
    let factory = ReferenceVariantFactory::new(norm, placement);
    let mut cfg = TrainConfig::quickstart(model, steps);
    cfg.seed = 7;
    cfg.lr = LrSchedule {
        max_lr: 1e-3,
        min_lr: 1e-4,
        warmup_steps: steps / 20 + 1,
        decay_steps: steps,
    };
    cfg.corpus_bytes = 1 << 19;
    cfg.norm_kind = Some(norm);
    cfg.norm_placement = Some(placement);
    let mut tr = Trainer::new(&factory, cfg)?;
    let out = tr.run()?;
    println!("  trained {norm}/{placement}: final loss {:.4}", out.final_loss);
    Ok(score_cell(norm, placement, out.final_loss, &out.records))
}

/// The scoring half, split from training so tests can feed synthetic
/// records.
fn score_cell(
    norm: NormKind,
    placement: NormPlacement,
    final_loss: f64,
    records: &[StepRecord],
) -> Cell {
    // Skip the estimator-seeding warmup, like the Fig. 7 analysis.
    let skip = records.len() / 10;
    let window = &records[skip.min(records.len())..];

    let pairs: Vec<(f64, f64)> = window
        .iter()
        .filter(|r| r.gns_layernorm.is_finite() && r.gns_total.is_finite())
        .map(|r| (r.gns_layernorm, r.gns_total))
        .collect();
    let x: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let y: Vec<f64> = pairs.iter().map(|p| p.1).collect();
    let fit = linreg(&x, &y);
    let mean_x = mean(&x);
    let mean_y = mean(&y);
    let ratio = match (mean_x, mean_y) {
        (Some(mx), Some(my)) if mx.abs() > 1e-300 => Some(my / mx),
        _ => None,
    };

    // Per-layer mean GNS: re-smooth the raw components offline at a
    // fixed alpha, take the ratio last, average the finite tail.
    let per_layer = (0..STATS_ORDER.len())
        .map(|t| {
            let g: Vec<f64> = window.iter().map(|r| r.raw_g_sq[t]).collect();
            let s: Vec<f64> = window.iter().map(|r| r.raw_s[t]).collect();
            let gns = ratio_series(&ema_series(&s, ALPHA), &ema_series(&g, ALPHA));
            let finite: Vec<f64> = gns.into_iter().filter(|v| v.is_finite()).collect();
            mean(&finite).unwrap_or(f64::NAN)
        })
        .collect();

    let stride = records.len().div_ceil(TRAJ_POINTS).max(1);
    let trajectory = records
        .iter()
        .filter(|r| r.step % stride as u64 == 0 || r.step == records.len() as u64)
        .map(|r| (r.step, r.gns_layernorm, r.gns_total))
        .collect();

    Cell {
        norm,
        placement,
        final_loss,
        slope: fit.as_ref().map(|f| f.slope),
        intercept: fit.as_ref().map(|f| f.intercept),
        r2: fit.as_ref().map(|f| f.r * f.r),
        ratio,
        n_fit: pairs.len(),
        per_layer,
        trajectory,
    }
}

fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        None
    } else {
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }
}

fn ratio_series(num: &[f64], den: &[f64]) -> Vec<f64> {
    num.iter()
        .zip(den)
        .map(|(&n, &d)| if d.abs() > 1e-300 { n / d } else { f64::NAN })
        .collect()
}

fn opt_num(v: Option<f64>) -> Value {
    v.map(Value::finite_or_null).unwrap_or(Value::Null)
}

/// The machine-readable report. Shape (checked by CI):
/// `{"report":"predictor","model","steps","cells":[{...}]}`.
fn report_json(model: &str, steps: u64, cells: &[Cell]) -> Value {
    let cell_values = cells
        .iter()
        .map(|c| {
            let mut m = BTreeMap::new();
            m.insert("norm_kind".into(), Value::Str(c.norm.name().into()));
            m.insert("norm_placement".into(), Value::Str(c.placement.name().into()));
            m.insert("final_loss".into(), Value::finite_or_null(c.final_loss));
            let mut fit = BTreeMap::new();
            fit.insert("slope".into(), opt_num(c.slope));
            fit.insert("intercept".into(), opt_num(c.intercept));
            fit.insert("r2".into(), opt_num(c.r2));
            fit.insert("ratio".into(), opt_num(c.ratio));
            fit.insert("n".into(), Value::Num(c.n_fit as f64));
            m.insert("fit".into(), Value::Obj(fit));
            m.insert("verdict".into(), Value::Str(c.verdict().into()));
            let per_layer = STATS_ORDER
                .iter()
                .zip(&c.per_layer)
                .map(|(name, &g)| ((*name).to_string(), Value::finite_or_null(g)))
                .collect();
            m.insert("per_layer_gns".into(), Value::Obj(per_layer));
            let mut traj = BTreeMap::new();
            traj.insert(
                "step".into(),
                Value::Arr(c.trajectory.iter().map(|t| Value::Num(t.0 as f64)).collect()),
            );
            traj.insert(
                "gns_norm_only".into(),
                Value::Arr(c.trajectory.iter().map(|t| Value::finite_or_null(t.1)).collect()),
            );
            traj.insert(
                "gns_total".into(),
                Value::Arr(c.trajectory.iter().map(|t| Value::finite_or_null(t.2)).collect()),
            );
            m.insert("trajectory".into(), Value::Obj(traj));
            Value::Obj(m)
        })
        .collect();

    let mut top = BTreeMap::new();
    top.insert("report".into(), Value::Str("predictor".into()));
    top.insert("model".into(), Value::Str(model.into()));
    top.insert("steps".into(), Value::Num(steps as f64));
    top.insert("alpha".into(), Value::Num(ALPHA));
    top.insert("cells".into(), Value::Arr(cell_values));
    Value::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::N_TYPES;

    fn rec(step: u64, ln: f64, tot: f64) -> StepRecord {
        StepRecord {
            step,
            tokens: step * 64,
            loss: 2.0,
            lr: 1e-3,
            accum: 1,
            b_big: 8.0,
            raw_g_sq: [1.0; N_TYPES],
            raw_s: [2.0; N_TYPES],
            raw_g_sq_total: 1.0,
            raw_s_total: 2.0,
            gns_layernorm: ln,
            gns_total: tot,
            step_ms: 0.0,
        }
    }

    #[test]
    fn exact_linear_relation_scores_holds() {
        // total = 2 * norm-only, exactly: slope 2, r2 1, ratio 2.
        let records: Vec<StepRecord> =
            (1..=40).map(|s| rec(s, s as f64 * 0.1, s as f64 * 0.2)).collect();
        let c = score_cell(NormKind::RmsNorm, NormPlacement::PeriLn, 1.5, &records);
        assert!((c.slope.unwrap() - 2.0).abs() < 1e-9);
        assert!((c.r2.unwrap() - 1.0).abs() < 1e-9);
        assert!((c.ratio.unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(c.verdict(), "holds");
        assert_eq!(c.per_layer.len(), N_TYPES);
        // raw s/g = 2 everywhere, so every per-layer mean GNS is 2.
        for &g in &c.per_layer {
            assert!((g - 2.0).abs() < 1e-9, "{g}");
        }
        assert!(!c.trajectory.is_empty() && c.trajectory.len() <= TRAJ_POINTS + 1);
    }

    #[test]
    fn degenerate_windows_break_without_panicking() {
        // All-NaN GNS: no pairs, no fit, verdict breaks.
        let records: Vec<StepRecord> = (1..=10).map(|s| rec(s, f64::NAN, f64::NAN)).collect();
        let c = score_cell(NormKind::LayerNorm, NormPlacement::PreLn, 2.0, &records);
        assert_eq!(c.n_fit, 0);
        assert!(c.slope.is_none() && c.r2.is_none() && c.ratio.is_none());
        assert_eq!(c.verdict(), "breaks");
        // Empty record set.
        let c = score_cell(NormKind::LayerNorm, NormPlacement::PostLn, 2.0, &[]);
        assert_eq!(c.verdict(), "breaks");
        assert!(c.trajectory.is_empty());
    }

    #[test]
    fn report_json_shape_matches_contract() {
        let records: Vec<StepRecord> =
            (1..=20).map(|s| rec(s, s as f64, s as f64 * 1.5)).collect();
        let cells = vec![
            score_cell(NormKind::LayerNorm, NormPlacement::PreLn, 2.0, &records),
            score_cell(NormKind::RmsNorm, NormPlacement::PeriLn, 2.1, &records),
        ];
        let v = report_json("nano", 20, &cells);
        let text = v.to_string();
        let back = Value::parse(&text).unwrap();
        assert_eq!(back.get("report").unwrap().as_str().unwrap(), "predictor");
        assert_eq!(back.get("steps").unwrap().as_u64().unwrap(), 20);
        let cells = back.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        let c0 = &cells[0];
        assert_eq!(c0.get("norm_kind").unwrap().as_str().unwrap(), "layernorm");
        assert_eq!(c0.get("norm_placement").unwrap().as_str().unwrap(), "preln");
        assert_eq!(c0.get("verdict").unwrap().as_str().unwrap(), "holds");
        let fit = c0.get("fit").unwrap();
        assert!((fit.get("slope").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-9);
        assert!(fit.get("n").unwrap().as_u64().unwrap() > 0);
        let pl = c0.get("per_layer_gns").unwrap().as_obj().unwrap();
        assert_eq!(pl.len(), crate::STATS_ORDER.len());
        let traj = c0.get("trajectory").unwrap();
        let steps = traj.get("step").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), traj.get("gns_total").unwrap().as_arr().unwrap().len());
    }
}
