//! Figs. 11–13: attention numerical-instability teacher–student harness.
//!
//! Two students (identical init: teacher + noise on the QKV bias) train to
//! match a frozen teacher; the "lowprec" student computes attention in
//! bfloat16 (the flash-kernel numerics proxy, DESIGN.md §Substitutions),
//! the "exact" student in float32. Fig. 12 tracks bias norms and distances;
//! Fig. 13 repeats with cosine attention and the divergence disappears.

use anyhow::{anyhow, Result};
use xla::Literal;

use crate::util::rng::Rng;

use crate::runtime::pjrt::{self, Runtime};
use crate::runtime::{Manifest, Tensor};
use crate::telemetry::CsvLogger;

struct TsHarness {
    teacher: Vec<Literal>,
    students: Vec<(String, Vec<Literal>)>,
    exes: std::collections::HashMap<String, std::sync::Arc<crate::runtime::Executable>>,
    n: usize,
    shape: (usize, usize, usize),
    rng: Rng,
}

impl TsHarness {
    fn new(rt: &Runtime, manifest: &Manifest, variants: &[&str], seed: i32) -> Result<Self> {
        let entry = manifest
            .instability
            .as_ref()
            .ok_or_else(|| {
                anyhow!("manifest has no instability artifacts (re-run make artifacts)")
            })?
            .clone();
        let mut exes = std::collections::HashMap::new();
        for (name, rel) in &entry.artifacts {
            exes.insert(name.clone(), rt.load(manifest.root.join(rel))?);
        }
        let n = entry.param_names.len();
        let mut init_out = exes
            .get("ts_init")
            .ok_or_else(|| anyhow!("ts_init missing"))?
            .run(&[pjrt::i32_scalar(seed)])?;
        let student0 = init_out.split_off(n);
        let teacher = init_out;
        let students = variants
            .iter()
            .map(|v| (v.to_string(), student0.clone()))
            .collect();
        Ok(Self {
            teacher,
            students,
            exes,
            n,
            shape: (entry.b, entry.t, entry.d),
            rng: Rng::seed_from_u64(seed as u64),
        })
    }

    fn random_input(&mut self) -> Result<Literal> {
        let (b, t, d) = self.shape;
        let data: Vec<f32> =
            (0..b * t * d).map(|_| self.rng.normal_f32()).collect();
        pjrt::tensor_to_literal(&Tensor::new(vec![b, t, d], data)?)
    }

    /// One step for every student on the *same* input; returns per-student
    /// (loss, dist_to_teacher, qkv_w_norm, qkv_b_norm).
    fn step(&mut self, lr: f32) -> Result<Vec<(f64, f64, f64, f64)>> {
        let x = self.random_input()?;
        let lr_l = pjrt::f32_scalar(lr);
        let mut out_metrics = Vec::new();
        for (variant, params) in self.students.iter_mut() {
            let exe = self
                .exes
                .get(&format!("ts_step_{variant}"))
                .ok_or_else(|| anyhow!("variant {variant} missing"))?;
            let mut args: Vec<&Literal> = self.teacher.iter().collect();
            args.extend(params.iter());
            args.push(&x);
            args.push(&lr_l);
            let mut out = exe.run(&args)?;
            anyhow::ensure!(out.len() == self.n + 4, "ts_step arity {}", out.len());
            let qkv_b_norm = pjrt::scalar_f32(&out.pop().unwrap())? as f64;
            let qkv_w_norm = pjrt::scalar_f32(&out.pop().unwrap())? as f64;
            let dist = pjrt::scalar_f32(&out.pop().unwrap())? as f64;
            let loss = pjrt::scalar_f32(&out.pop().unwrap())? as f64;
            *params = out;
            out_metrics.push((loss, dist, qkv_w_norm, qkv_b_norm));
        }
        Ok(out_metrics)
    }

    /// L2 distance between two students' parameters.
    fn student_distance(&self, a: usize, b: usize) -> Result<f64> {
        let mut sq = 0f64;
        for (pa, pb) in self.students[a].1.iter().zip(self.students[b].1.iter()) {
            let ta = pjrt::literal_to_tensor(pa)?;
            let tb = pjrt::literal_to_tensor(pb)?;
            sq += ta
                .data
                .iter()
                .zip(&tb.data)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>();
        }
        Ok(sq.sqrt())
    }
}

/// Figs. 11–12: exact-f32 vs lowprec(bf16-attention) students.
pub fn fig12(rt: &Runtime, manifest: &Manifest, steps: u64, lr: f32) -> Result<()> {
    let mut h = TsHarness::new(rt, manifest, &["exact", "lowprec"], 0)?;
    let path = super::results_path("fig12_teacher_student.csv")?;
    let mut csv = CsvLogger::to_file(&path, &[
        "step", "exact_loss", "lowprec_loss", "exact_dist", "lowprec_dist",
        "exact_bias_norm", "lowprec_bias_norm", "flash_to_nonflash_dist",
    ])?;
    println!("Fig. 12: teacher-student divergence, exact vs bf16-attention (lr={lr})");
    println!(
        "{:>6} {:>11} {:>11} {:>10} {:>10} {:>10}",
        "step", "exact_loss", "lowp_loss", "exact_d", "lowp_d", "stu_dist"
    );
    let every = (steps / 12).max(1);
    let mut final_row = (0.0, 0.0);
    for step in 1..=steps {
        let m = h.step(lr)?;
        let dist = h.student_distance(0, 1)?;
        csv.row(&[
            step as f64, m[0].0, m[1].0, m[0].1, m[1].1, m[0].3, m[1].3, dist,
        ])?;
        if step % every == 0 || step == steps {
            println!(
                "{:>6} {:>11.4e} {:>11.4e} {:>10.4} {:>10.4} {:>10.4}",
                step, m[0].0, m[1].0, m[0].1, m[1].1, dist
            );
        }
        final_row = (m[0].1, m[1].1);
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!(
        "final dist-to-teacher: exact={:.4} lowprec={:.4} (paper: lowprec student drifts away)",
        final_row.0, final_row.1
    );
    Ok(())
}

/// Fig. 13: the same experiment under cosine attention — no divergence.
pub fn fig13(rt: &Runtime, manifest: &Manifest, steps: u64, lr: f32) -> Result<()> {
    let mut h = TsHarness::new(rt, manifest, &["cosine", "exact"], 0)?;
    let path = super::results_path("fig13_cosine.csv")?;
    let mut csv = CsvLogger::to_file(&path, &[
        "step", "cosine_loss", "exact_loss", "cosine_dist", "exact_dist",
    ])?;
    println!("Fig. 13: cosine-attention mitigation (lr={lr})");
    let every = (steps / 12).max(1);
    for step in 1..=steps {
        let m = h.step(lr)?;
        csv.row(&[step as f64, m[0].0, m[1].0, m[0].1, m[1].1])?;
        if step % every == 0 || step == steps {
            println!(
                "step {:>5}: cosine loss {:.4e} dist {:.4} | exact loss {:.4e} dist {:.4}",
                step, m[0].0, m[0].1, m[1].0, m[1].1
            );
        }
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!("shape check: bounded q/k norms keep the students together");
    Ok(())
}
